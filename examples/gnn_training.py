"""Train a GIN graph classifier on batched molecule graphs with the full
production loop: deterministic data stream, checkpointing, preemption-safe
recovery, straggler monitor.

    PYTHONPATH=src python examples/gnn_training.py [steps]
"""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS
from repro.data.synthetic import batched_molecules
from repro.dist.fault_tolerance import CheckpointPolicy, StepMonitor, run_with_recovery
from repro.models.gnn import archs as gnn
from repro.train.optim import AdamWConfig
from repro.train.steps import init_train_state, make_gnn_train_step


def main():
    steps = int(sys.argv[1]) if len(sys.argv) > 1 else 200
    cfg = ARCHS["gin-tu"].smoke()
    ocfg = AdamWConfig(lr=3e-3, total_steps=steps, warmup_steps=10)

    def init_state():
        return init_train_state(gnn.init(jax.random.key(0), cfg, 16, 2), ocfg)

    train = jax.jit(make_gnn_train_step(cfg, ocfg, task="graph_class"))
    monitor = StepMonitor()
    losses = []

    def step_fn(state, i):
        batch, labels = batched_molecules(
            seed=1, n_graphs=32, nodes_per=16, edges_per=32, d_feat=16
        )
        # vary labels stream deterministically by step
        rng = np.random.default_rng(i)
        labels = ((labels + rng.integers(0, 2, labels.shape)) % 2).astype(np.int32)
        state, m = train(state, batch, jnp.asarray(labels))
        losses.append(float(m["loss"]))
        if i % 25 == 0:
            print(f"step {i:4d}  loss {float(m['loss']):.4f}")
        return state, m

    policy = CheckpointPolicy(directory="results/ckpt_gnn", every_steps=50)
    state, metrics = run_with_recovery(
        step_fn, init_state, steps, policy, monitor=monitor
    )
    print(f"done: {monitor.summary()}")
    print(f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
