"""Quickstart: solve BFS, WCC, and PageRank with the GraphScale engine on a
small real graph and a generated R-MAT graph, and verify against oracles.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import repro.core.graph as G
from repro.core.engine import EngineOptions, run
from repro.core.partition import PartitionConfig, partition_2d
from repro.core.problems import bfs, pagerank, wcc
from repro.core.reference import bfs_reference, pagerank_reference, wcc_reference


def main():
    for name, g0, root in [
        ("karate", G.karate_club(), 0),
        ("rmat-12-16", G.rmat(12, 16, seed=0), 11),
    ]:
        g = G.symmetrize(g0)
        # 4 graph cores x 4 scratch-pad phases, stride mapping on
        pg = partition_2d(g, PartitionConfig(p=4, l=4, lane=8, stride=100))
        print(f"\n=== {name}: |V|={g.num_vertices} |E|={g.num_edges} "
              f"imbalance={pg.imbalance:.2f} ===")

        r = run(bfs(root), g, pg, EngineOptions(immediate_updates=True))
        ref = bfs_reference(g, root)
        reached = int((r.labels["label"] != 0xFFFFFFFF).sum())
        print(f"BFS   : {r.iterations} iters (async), reached {reached} vertices, "
              f"correct={np.array_equal(r.labels['label'], ref)}")

        r_sync = run(bfs(root), g, pg, EngineOptions(immediate_updates=False))
        print(f"        sync needs {r_sync.iterations} iters "
              f"(async saves {r_sync.iterations - r.iterations})")

        rw = run(wcc(), g, pg, EngineOptions())
        ncomp = len(np.unique(rw.labels["label"]))
        print(f"WCC   : {rw.iterations} iters, {ncomp} components, "
              f"correct={np.array_equal(rw.labels['label'], wcc_reference(g0))}")

        pgd = partition_2d(g0, PartitionConfig(p=4, l=2, lane=8))
        rp = run(pagerank(), g0, pgd, EngineOptions())
        top = np.argsort(rp.labels["label"])[-3:][::-1]
        err = np.abs(rp.labels["label"] - pagerank_reference(g0)).max()
        print(f"PR    : {rp.iterations} iters, top vertices {top.tolist()}, "
              f"max err vs oracle {err:.2e}")


if __name__ == "__main__":
    main()
