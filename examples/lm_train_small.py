"""Train a reduced smollm-style LM on the synthetic token stream for a few
hundred steps on CPU — demonstrates the LM training path (scan-over-layers,
chunked attention, AdamW, checkpoint/restore).

    PYTHONPATH=src python examples/lm_train_small.py [steps]
"""
import sys

import jax
import jax.numpy as jnp

from repro.data.synthetic import lm_batch
from repro.dist.checkpoint import restore_checkpoint, save_checkpoint
from repro.models.transformer import LMConfig, count_params, init_params
from repro.train.optim import AdamWConfig
from repro.train.steps import init_train_state, make_lm_train_step


def main():
    steps = int(sys.argv[1]) if len(sys.argv) > 1 else 300
    cfg = LMConfig(
        name="smollm-nano", n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
        head_dim=32, d_ff=384, vocab=2048, dtype=jnp.float32, attn_chunk=64,
    )
    print(f"model: {count_params(cfg) / 1e6:.2f}M params")
    ocfg = AdamWConfig(lr=1e-3, total_steps=steps, warmup_steps=20)
    state = init_train_state(init_params(jax.random.key(0), cfg), ocfg)
    train = jax.jit(make_lm_train_step(cfg, ocfg), donate_argnums=0)

    first = None
    for i in range(steps):
        b = lm_batch(seed=0, step=i, batch=8, seq=128, vocab=cfg.vocab)
        state, m = train(state, {k: jnp.asarray(v) for k, v in b.items()})
        loss = float(m["loss"])
        first = first if first is not None else loss
        if i % 25 == 0 or i == steps - 1:
            print(f"step {i:4d}  loss {loss:.4f}")
    print(f"loss {first:.4f} -> {loss:.4f}")
    path = save_checkpoint("results/ckpt_lm", steps, state, meta={"next_step": steps})
    print(f"checkpoint saved: {path}")
    restored, meta = restore_checkpoint("results/ckpt_lm", state)
    print(f"restored at step {meta['next_step']} OK")


if __name__ == "__main__":
    main()
