"""End-to-end driver (the paper's kind): distributed graph analytics on a
mesh of graph cores — partition an R-MAT graph over 8 devices, run BFS / WCC /
PageRank to convergence through the shard_map crossbar engine, report MTEPS.

    PYTHONPATH=src python examples/distributed_pagerank.py [scale]
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
import time

import jax
import numpy as np

import repro.core.graph as G
from repro.core.distributed import run_distributed
from repro.core.engine import EngineOptions
from repro.core.partition import PartitionConfig, partition_2d
from repro.core.problems import bfs, pagerank, wcc
from repro.launch.mesh import make_graph_mesh


def main():
    scale = int(sys.argv[1]) if len(sys.argv) > 1 else 14
    p = 8
    mesh = make_graph_mesh(p)
    print(f"mesh: {p} graph cores (one per device)")

    g0 = G.rmat(scale, 16, seed=0)
    g = G.symmetrize(g0)
    t0 = time.perf_counter()
    pg = partition_2d(g, PartitionConfig(p=p, l=4, lane=8, stride=100))
    print(f"graph |V|={g.num_vertices} |E|={g.num_edges} "
          f"partitioned in {time.perf_counter() - t0:.2f}s "
          f"(imbalance {pg.imbalance:.2f}, padding {pg.padding_ratio:.2%})")

    for name, prob, graph, part in [
        ("bfs", bfs(11), g, pg),
        ("wcc", wcc(), g, pg),
        ("pagerank", pagerank(tol=1e-5), g0, partition_2d(g0, PartitionConfig(p=p, l=4, lane=8))),
    ]:
        t0 = time.perf_counter()
        res = run_distributed(prob, graph, part, mesh)
        dt = time.perf_counter() - t0  # includes compile
        t0 = time.perf_counter()
        res = run_distributed(prob, graph, part, mesh)
        dt_warm = time.perf_counter() - t0
        print(f"{name:9s}: {res.iterations:3d} iters, converged={res.converged}, "
              f"{dt_warm:.3f}s warm ({graph.num_edges / dt_warm / 1e6:.1f} MTEPS, "
              f"compile+run {dt:.1f}s)")


if __name__ == "__main__":
    main()
