"""DIN serving demo: train briefly on synthetic click data, then serve
pointwise batches and run retrieval scoring (one user vs many candidates)
with top-k output.

    PYTHONPATH=src python examples/recsys_serving.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS
from repro.data.synthetic import recsys_batch, retrieval_batch
from repro.models.recsys.din import init as din_init, score, score_candidates
from repro.train.optim import AdamWConfig
from repro.train.steps import init_train_state, make_din_serve, make_din_train_step


def main():
    cfg = ARCHS["din"].smoke()
    ocfg = AdamWConfig(lr=1e-3, total_steps=100, warmup_steps=5)
    state = init_train_state(din_init(jax.random.key(0), cfg), ocfg)
    train = jax.jit(make_din_train_step(cfg, ocfg), donate_argnums=0)
    for i in range(100):
        b = recsys_batch(0, i, 64, cfg.seq_len, cfg.item_vocab, cfg.cate_vocab,
                         cfg.profile_bag_len)
        state, m = train(state, {k: jnp.asarray(v) for k, v in b.items()})
        if i % 25 == 0:
            print(f"train step {i:3d}  loss {float(m['loss']):.4f}")

    serve = jax.jit(make_din_serve(cfg))
    sb = recsys_batch(1, 0, 256, cfg.seq_len, cfg.item_vocab, cfg.cate_vocab,
                      cfg.profile_bag_len)
    sb = {k: jnp.asarray(v) for k, v in sb.items() if k != "labels"}
    logits = serve(state["params"], sb)
    logits.block_until_ready()
    t0 = time.perf_counter()
    logits = serve(state["params"], sb).block_until_ready()
    dt = time.perf_counter() - t0
    print(f"serve: batch=256 in {dt * 1e3:.2f} ms "
          f"({256 / dt:.0f} QPS single-host), mean score {float(logits.mean()):.3f}")

    rb = retrieval_batch(2, cfg.seq_len, 4096, cfg.item_vocab, cfg.cate_vocab,
                         cfg.profile_bag_len)
    rb = {k: jnp.asarray(v) for k, v in rb.items()}
    scores = jax.jit(lambda p, b: score_candidates(p, b, cfg, chunk=1024))(
        state["params"], rb
    )
    top = np.argsort(np.asarray(scores))[-5:][::-1]
    print(f"retrieval: scored 4096 candidates; top-5 items "
          f"{np.asarray(rb['cand_items'])[top].tolist()}")


if __name__ == "__main__":
    main()
