#!/usr/bin/env bash
# CI entry point: tier-1 tests + engine bench smoke (same as `make check`).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -x -q

python -c "import benchmarks.bench_engine as b; b.main(lambda n, us, d='': print(f'{n},{us:.1f},{d}'))"
