#!/usr/bin/env bash
# CI entry point: tier-1 tests + engine bench smoke (same as `make check`).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
# the container ships libtpu; without a platform pin jax probes the (absent)
# TPU and multi-device collectives can hang. Honor a caller's explicit choice.
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

# repo hygiene: bytecode must never be tracked (PR 1 accidentally committed 10)
if git ls-files | grep -E '(^|/)__pycache__/|\.pyc$'; then
    echo "error: compiled Python files are tracked; git rm --cached them" >&2
    exit 1
fi

python -m pytest -x -q

# tiny-graph perf-path smoke: metric keys + Pallas/XLA agreement asserted,
# one high-diameter dynamic-skip point (mean dynamic skipped-tile fraction
# must beat the static padding skip), one multi-channel distributed point,
# and the full-size shuffled path-512 direction point — the only wall-clock
# threshold smoke carries (push/pull auto >= 1.3x over the PR 6 pull-only
# schedule); full timings are `make bench-engine`.
python -m benchmarks.bench_engine --smoke

# always-on serving smoke (docs/serving.md): a bounded mixed-op query stream
# with mid-stream delta ingest — asserts the delta-retiled resident partition
# answers (and BFS/WCC/SSSP labels) match a from-scratch repartition
# bit-for-bit, then the bench variant records serving metrics into
# BENCH_engine.json under "serving" and asserts the steady BFS batch budget.
python -m repro.launch.serve --arch graph --smoke
python -m benchmarks.bench_engine --serve-smoke

# streaming-partitioner smoke (make bench-scale, docs/scaling.md): scale-14
# RMAT through the out-of-core build in a cold child — asserts the RSS-delta
# ceiling (bounded memory), bit-identity with the in-memory partition_2d,
# and BFS label agreement across both builds.
python -m benchmarks.bench_engine --scale-smoke

# sharded job (make check-dist): distributed engine + repro.dist suites under
# 8 simulated memory channels — the un-skipped test_distributed /
# test_elastic / test_fault_tolerance files plus the equivalence suite and
# the direction-switch suite (its sharded jaxpr proof needs the devices).
XLA_FLAGS="--xla_force_host_platform_device_count=8" python -m pytest -x -q \
    tests/test_distributed.py tests/test_distributed_equiv.py \
    tests/test_elastic.py tests/test_fault_tolerance.py \
    tests/test_direction_switch.py
