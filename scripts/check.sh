#!/usr/bin/env bash
# CI entry point: tier-1 tests + engine bench smoke (same as `make check`).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# repo hygiene: bytecode must never be tracked (PR 1 accidentally committed 10)
if git ls-files | grep -E '(^|/)__pycache__/|\.pyc$'; then
    echo "error: compiled Python files are tracked; git rm --cached them" >&2
    exit 1
fi

python -m pytest -x -q

# tiny-graph perf-path smoke: metric keys + Pallas/XLA agreement asserted
# (no timing thresholds); full timings are `make bench-engine`.
python -m benchmarks.bench_engine --smoke
