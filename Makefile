PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
# pin the CPU backend unless the caller chose one (libtpu is installed; an
# unpinned probe of the absent TPU can hang multi-device collectives)
export JAX_PLATFORMS ?= cpu

.PHONY: test bench-smoke serve-smoke bench-scale bench-engine bench check check-dist

# tier-1 verify (ROADMAP.md)
test:
	$(PYTHON) -m pytest -x -q

# sharded job: the distributed engine + repro.dist suites under 8 simulated
# memory channels (subprocess tests force their own device counts; the outer
# flag covers the in-process multi-device cases)
check-dist:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" $(PYTHON) -m pytest -x -q \
		tests/test_distributed.py tests/test_distributed_equiv.py \
		tests/test_elastic.py tests/test_fault_tolerance.py \
		tests/test_direction_switch.py

# tiny-graph engine-path sanity: metric keys + Pallas/XLA agreement (CI)
bench-smoke:
	$(PYTHON) -m benchmarks.bench_engine --smoke

# always-on serving smoke: delta-retiled resident partition must match a
# from-scratch repartition bit-for-bit, then serving metrics (latency/QPS/
# steady batch budget) land in BENCH_engine.json under "serving"
serve-smoke:
	$(PYTHON) -m repro.launch.serve --arch graph --smoke
	$(PYTHON) -m benchmarks.bench_engine --serve-smoke

# streaming-partitioner smoke (docs/scaling.md): scale-14 RMAT through
# partition_2d_streaming in a cold child under an asserted RSS-delta ceiling,
# bit-identical to the in-memory build, BFS labels agreeing across both
bench-scale:
	$(PYTHON) -m benchmarks.bench_engine --scale-smoke

# full engine comparison incl. skew suite -> BENCH_engine.json
bench-engine:
	$(PYTHON) -m benchmarks.bench_engine

# full benchmark harness (all paper figures)
bench:
	$(PYTHON) -m benchmarks.run

check: test bench-smoke serve-smoke bench-scale check-dist
