PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench-smoke bench check

# tier-1 verify (ROADMAP.md)
test:
	$(PYTHON) -m pytest -x -q

# quick engine-path sanity: fused Pallas vs XLA timings -> BENCH_engine.json
bench-smoke:
	$(PYTHON) -c "import benchmarks.bench_engine as b; b.main(lambda n, us, d='': print(f'{n},{us:.1f},{d}'))"

# full benchmark harness (all paper figures)
bench:
	$(PYTHON) -m benchmarks.run

check: test bench-smoke
