PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench-smoke bench-engine bench check

# tier-1 verify (ROADMAP.md)
test:
	$(PYTHON) -m pytest -x -q

# tiny-graph engine-path sanity: metric keys + Pallas/XLA agreement (CI)
bench-smoke:
	$(PYTHON) -m benchmarks.bench_engine --smoke

# full engine comparison incl. skew suite -> BENCH_engine.json
bench-engine:
	$(PYTHON) -m benchmarks.bench_engine

# full benchmark harness (all paper figures)
bench:
	$(PYTHON) -m benchmarks.run

check: test bench-smoke
