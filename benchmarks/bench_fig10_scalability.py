"""Fig. 10 reproduction: memory-channel scalability, p = 1 -> 2 -> 4 graph
cores, speedup over single-channel for BFS / PR / WCC.

On this single-CPU container the p cores are the engine's vectorized core
dimension, so 'speedup' reflects convergence + padding effects (the real
parallel speedup is what the dry-run/roofline measures on the mesh); the
iteration counts and update-traffic reductions ARE the paper's effects."""
from __future__ import annotations

import repro.core.graph as G
from benchmarks.common import bench_graphs, mteps, time_call
from repro.core.engine import EngineOptions, run
from repro.core.partition import PartitionConfig, partition_2d
from repro.core.problems import bfs, pagerank, wcc


# backend pinned to the XLA oracle: these figures isolate the paper's
# algorithmic effects; fused-vs-XLA backend timings live in bench_engine.py
def main(emit):
    problems = {
        "bfs": lambda root: bfs(root),
        "wcc": lambda root: wcc(),
        "pr": lambda root: pagerank(tol=1e-4),
    }
    for name, (g0, root) in bench_graphs("tiny").items():
        g = G.symmetrize(g0)
        gd = g0
        for pname, mk in problems.items():
            gg = gd if pname == "pr" else g
            base = None
            for p in (1, 2, 4):
                # paper: stride mapping disabled for single-channel
                stride = None if p == 1 else 100
                pg = partition_2d(gg, PartitionConfig(p=p, l=4, lane=8, stride=stride, build_tiles=False))
                prob = mk(root)
                res = run(prob, gg, pg, EngineOptions(backend="xla"))
                t = time_call(lambda: run(prob, gg, pg, EngineOptions(backend="xla")))
                if base is None:
                    base = t
                emit(
                    f"fig10/{pname}/{name}/p{p}",
                    t * 1e6,
                    f"iters={res.iterations} mteps={mteps(gg.num_edges, t):.2f} "
                    f"speedup_vs_p1={base / t:.2f} imbalance={pg.imbalance:.2f}",
                )
