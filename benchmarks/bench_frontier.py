"""Beyond-paper benchmark: frontier-compressed crossbar exchange wire bytes
vs the dense (paper-faithful) crossbar, per graph class. Analytic wire model
over real engine executions (per-phase sparse/full decisions measured on an
8-core mesh in a subprocess — jax device count is locked per process)."""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap

_CHILD = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import repro.core.graph as G
from repro.core.partition import PartitionConfig, partition_2d
from repro.core.problems import bfs
from repro.core.frontier import run_distributed_frontier
from repro.core.reference import bfs_reference
from repro.launch.mesh import make_graph_mesh
mesh = make_graph_mesh(8)
out = {}
for name, g0, root, budget in [
    ("grid-road", G.grid_2d(160, 100), 3, 128),
    ("rmat-sparse", G.symmetrize(G.rmat(12, 8, seed=1)), 5, 128),
]:
    pg = partition_2d(g0, PartitionConfig(p=8, l=2, lane=8, stride=100))
    res, stats = run_distributed_frontier(bfs(root), g0, pg, mesh, budget=budget)
    assert np.array_equal(res.labels["label"], bfs_reference(g0, root))
    out[name] = dict(iters=res.iterations, **{k: float(v) for k, v in stats.items()})
print(json.dumps(out))
"""


def main(emit):
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_CHILD)],
        capture_output=True, text=True, timeout=1200,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}, cwd="/root/repo",
    )
    if res.returncode != 0:
        emit("frontier/error", 0.0, res.stderr[-200:].replace(",", ";"))
        return
    data = json.loads(res.stdout.strip().splitlines()[-1])
    for name, s in data.items():
        emit(
            f"frontier/{name}",
            0.0,
            f"iters={int(s['iters'])} sparse={int(s['sparse_phases'])} "
            f"full={int(s['full_phases'])} wire_reduction={s['reduction']:.2f}x",
        )
