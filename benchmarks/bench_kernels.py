"""Kernel micro-benchmarks: Pallas (interpret; correctness-grade timings) vs
the XLA reference path at matched shapes, plus the analytic MXU/VPU cost per
tile documented for the TPU target."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import time_call
from repro.kernels.csr_gather_reduce import (
    choose_src_bits,
    gather_reduce,
    gather_reduce_cores_pallas,
    prepare_tiles,
    stack_packed_tiles,
)
from repro.kernels.csr_gather_reduce.ref import gather_reduce_reference
from repro.kernels.embedding_bag import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_reference


def main(emit):
    rng = np.random.default_rng(0)
    # csr_gather_reduce at a realistic sub-partition size
    v, e, g = 4096, 65536, 8192
    dst = np.sort(rng.integers(0, v, size=e)).astype(np.int32)
    src = rng.integers(0, g, size=e).astype(np.int32)
    payload = rng.random(g).astype(np.float32)
    tiles = prepare_tiles(src, dst, np.ones(e, bool), num_rows=v, vb=128, eb=256)
    jp = jnp.asarray(payload)

    t_ref = time_call(
        lambda: gather_reduce_reference(
            jp, jnp.asarray(src), jnp.asarray(dst), jnp.asarray(np.ones(e, bool)),
            v, kind="sum",
        ).block_until_ready()
    )
    emit("kernels/csr_gather_reduce/xla_ref", t_ref * 1e6,
         f"V={v} E={e} tile_pad={tiles.tile_padding_ratio:.2f}")
    # fused Pallas path (interpret on CPU — correctness-grade timing) at the
    # SAME shape: gather + map + reduce in one launch, no (E,) materialization
    t_fused = time_call(
        lambda: gather_reduce(jp, tiles, kind="sum", interpret=True).block_until_ready()
    )
    emit("kernels/csr_gather_reduce/pallas_interp", t_fused * 1e6,
         f"V={v} E={e} vs_xla={t_fused / t_ref:.1f}x")
    # multi-core fused launch (the engine hot path): p cores, one pallas_call
    # over the COMPRESSED word stream with tile-count skipping
    p = 4
    tiles_p = prepare_tiles(src, dst, np.ones(e, bool), num_rows=v, vb=256, eb=512)
    bits = choose_src_bits(g, 256)
    word, word_hi, counts, _ = stack_packed_tiles([tiles_p] * p, src_bits=bits)
    t_cores = time_call(
        lambda: gather_reduce_cores_pallas(
            jp, jnp.asarray(word), jnp.asarray(counts),
            jnp.asarray(word_hi) if word_hi is not None else None,
            None, num_rows=v, vb=256, src_bits=bits,
            kind="sum", identity=0.0, interpret=True,
        ).block_until_ready()
    )
    emit("kernels/csr_gather_reduce/pallas_cores_interp", t_cores * 1e6,
         f"p={p} V={v} E={e * p} grid={p}x{word.shape[1]}x{word.shape[2]} "
         f"src_bits={bits} stream_B_per_edge={4 * (1 if word_hi is None else 2)}")
    # analytic TPU tile cost: one-hot MXU matmul per tile
    r_blocks, t_tiles, eb = tiles.src.shape
    mxu_flops = r_blocks * t_tiles * 2 * tiles.vb * eb
    emit("kernels/csr_gather_reduce/tpu_model", 0.0,
         f"mxu_flops_per_pass={mxu_flops:.3e} tiles={r_blocks * t_tiles}")

    # embedding bag
    n, d, b, length = 100_000, 64, 256, 64
    table = rng.random((n, d), np.float32)
    ids = rng.integers(0, n, (b, length)).astype(np.int32)
    t_ref = time_call(
        lambda: embedding_bag_reference(jnp.asarray(table), jnp.asarray(ids)).block_until_ready()
    )
    emit("kernels/embedding_bag/xla_ref", t_ref * 1e6,
         f"N={n} D={d} B={b} L={length} bytes_gathered={b * length * d * 4:.0f}")
