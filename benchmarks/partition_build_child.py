"""Subprocess child for honest partition-build RSS / wall / agreement records.

Peak RSS (``VmHWM``) is a process-wide HIGH-WATER mark, so a build measured
inside a long-lived bench process inherits every earlier allocation peak.
This child exists to measure one streaming build from a cold start: baseline
RSS is
snapshotted after imports, the build runs, and the peak is snapshotted BEFORE
any comparison/engine work (later allocations cannot retroactively raise the
captured number). The bounded-memory acceptance ratio is

    rss_over_footprint = (peak_rss - baseline_rss) / memory_report().total

i.e. build-attributable memory over the final resident partition footprint —
``partition_2d_streaming``'s O(chunk + largest bucket) transient claim means
this stays well under the 4x ceiling where the in-memory path's O(E) edge
materialization would blow through it.

Optional phases, run strictly AFTER the RSS snapshot:
  --compare   materialize the same stream in RAM, build ``partition_2d``, and
              check bit-identity of every packed/flat array (the streaming
              contract, docs/tile_layout.md §11).
  --engine    run BFS (K=1) and lane-batched BFS (each K in --k-lanes) on the
              XLA backend; with --compare the labels from the streaming-built
              and in-memory-built partitions must agree. Reports MTEPS per
              point — the mteps_vs_scale suite's engine numbers.

Prints one JSON object on the last stdout line (the parent parses it).
"""
from __future__ import annotations

import argparse
import json
import resource
import time

import numpy as np

from repro.core.partition import (
    PartitionConfig,
    partition_2d,
    partition_2d_streaming,
)
from repro.data.rmat import materialize, rmat_chunks

# fields whose bit-identity defines streaming == in-memory (None-ness must
# match too; config carries no arrays and is compared by value elsewhere)
_IDENTITY_FIELDS = (
    "src_gidx", "dst_lidx", "valid", "weights", "bucket_sizes",
    "tile_word", "tile_word_hi", "tile_counts", "tile_weights",
    "tile_coverage", "tile_row_pos", "tile_row_orig", "tile_split_map",
    "push_word", "push_word_hi", "push_counts", "push_weights",
    "push_coverage",
)


def _rss_mb() -> float:
    # VmHWM, not ru_maxrss: Linux carries ru_maxrss across fork+exec, so a
    # child spawned from a fat bench parent would inherit the PARENT'S peak
    # and report a zero build delta. VmHWM lives in the mm and resets on
    # exec — the cold-start number this child exists to measure.
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmHWM:"):
                    return int(line.split()[1]) / 1024.0
    except OSError:
        pass
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def bit_identical(a, b) -> bool:
    for name in _IDENTITY_FIELDS:
        va, vb = getattr(a, name), getattr(b, name)
        if (va is None) != (vb is None):
            return False
        if va is not None and not np.array_equal(np.asarray(va), np.asarray(vb)):
            return False
    return (
        a.p == b.p and a.l == b.l and a.sub_size == b.sub_size
        and a.num_edges == b.num_edges and a.src_bits == b.src_bits
        and a.split_rows == b.split_rows and a.push_block == b.push_block
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", type=int, required=True)
    ap.add_argument("--edge-factor", type=int, default=8)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--p", type=int, default=2)
    ap.add_argument("--l", type=int, default=2)
    ap.add_argument("--tile-vb", type=int, default=None)
    ap.add_argument("--chunk-edges", type=int, default=1 << 18)
    ap.add_argument("--no-push", action="store_true",
                    help="pull-only layout (halves packed bytes at scale)")
    ap.add_argument("--memmap-dir", default=None,
                    help="np.memmap the large outputs under this directory")
    ap.add_argument("--compare", action="store_true",
                    help="bit-identity check vs the in-memory partition_2d")
    ap.add_argument("--engine", action="store_true",
                    help="run XLA-backend BFS points (with --compare: "
                         "cross-partition label agreement)")
    ap.add_argument("--k-lanes", default="1",
                    help="comma list of lane widths for --engine (e.g. 1,16)")
    ap.add_argument("--assert-rss-ratio", type=float, default=None,
                    help="fail unless (peak - baseline) / footprint < R")
    ap.add_argument("--assert-rss-delta-mb", type=float, default=None,
                    help="fail unless peak - baseline < M MB")
    args = ap.parse_args()

    stream = rmat_chunks(
        args.scale, args.edge_factor, seed=args.seed,
        chunk_edges=args.chunk_edges,
    )
    cfg = PartitionConfig(
        p=args.p, l=args.l, tile_vb=args.tile_vb,
        build_push=not args.no_push,
    )

    # warm numpy's allocator on a toy build so first-touch pool growth is
    # charged to the baseline, not to the measured build (toy-sized config:
    # the real one may carry a tile_vb larger than the toy graph's vpc)
    warm_cfg = PartitionConfig(p=2, l=2, build_push=not args.no_push)
    partition_2d_streaming(rmat_chunks(6, 4, seed=0), 1 << 6, warm_cfg)
    rss0 = _rss_mb()
    t0 = time.perf_counter()
    pg = partition_2d_streaming(
        stream, stream.num_vertices, cfg, memmap_dir=args.memmap_dir
    )
    build_s = time.perf_counter() - t0
    rss1 = _rss_mb()  # peak up to HERE: later phases cannot raise it

    rep = pg.memory_report()
    footprint_mb = rep["total_bytes"] / 1e6
    delta_mb = max(rss1 - rss0, 0.0)
    ratio = delta_mb / max(footprint_mb, 1e-9)
    rec = {
        "scale": args.scale,
        "edge_factor": args.edge_factor,
        "V": stream.num_vertices,
        "E": stream.num_edges,
        "p": pg.p, "l": pg.l, "tile_vb": pg.tile_vb,
        "src_bits": pg.src_bits,
        "chunk_edges": args.chunk_edges,
        "memmap": args.memmap_dir is not None,
        "partition_build_s": build_s,
        "rss_baseline_mb": rss0,
        "peak_rss_mb": rss1,
        "rss_delta_mb": delta_mb,
        "footprint_mb": footprint_mb,
        "device_mb": rep["device_total_bytes"] / 1e6,
        "device_bytes_per_edge": rep["device_bytes_per_edge"],
        "bytes_per_edge": rep["bytes_per_edge"],
        "rss_over_footprint": ratio,
        "bit_identical": None,
        "points": None,
    }
    if args.assert_rss_ratio is not None:
        assert ratio < args.assert_rss_ratio, (
            f"streaming build used {delta_mb:.0f} MB over a "
            f"{footprint_mb:.0f} MB footprint ({ratio:.2f}x >= "
            f"{args.assert_rss_ratio}x ceiling)"
        )
    if args.assert_rss_delta_mb is not None:
        assert delta_mb < args.assert_rss_delta_mb, (
            f"streaming build RSS delta {delta_mb:.0f} MB exceeds the "
            f"{args.assert_rss_delta_mb:.0f} MB ceiling"
        )

    pg_mem = None
    if args.compare:
        g = materialize(stream)
        pg_mem = partition_2d(g, cfg)
        rec["bit_identical"] = bool(bit_identical(pg, pg_mem))
        assert rec["bit_identical"], (
            "streaming build diverged from partition_2d"
        )

    if args.engine:
        # deferred: jax import + engine runs happen after the RSS snapshot
        import types

        from benchmarks.common import mteps, time_call
        from repro.core.engine import EngineOptions, run
        from repro.core.problems import bfs, bfs_multi
        from repro.data.synthetic import query_workload

        # label init only reads num_vertices for BFS-family problems; the
        # full edge list never needs to exist in this process (that is the
        # point of the streaming build)
        gv = types.SimpleNamespace(num_vertices=stream.num_vertices)
        opts = EngineOptions(backend="xla")
        ks = [int(k) for k in args.k_lanes.split(",")]
        roots = query_workload(max(max(ks), 1), stream.num_vertices, seed=0)
        # K=1 traverses from the modal source of the first chunk — a random
        # root on an unsymmetrized RMAT is often isolated (1-iteration BFS
        # makes the MTEPS point degenerate); multi-lane keeps the random
        # workload (the union frontier is live as long as any lane is)
        s0 = np.asarray(next(iter(stream()))[0])
        hub = int(np.bincount(s0, minlength=stream.num_vertices).argmax())
        points = []
        for k in ks:
            prob = bfs(hub) if k == 1 else bfs_multi(
                [int(r) for r in roots[:k]]
            )
            res = run(prob, gv, pg, opts)
            agree = None
            if pg_mem is not None:
                res_m = run(prob, gv, pg_mem, opts)
                key = "label" if k == 1 else "dist"
                agree = bool(
                    np.array_equal(
                        np.asarray(res.labels[key]),
                        np.asarray(res_m.labels[key]),
                    )
                ) and res.iterations == res_m.iterations
                assert agree, f"K={k} labels diverged across build paths"
            t = time_call(lambda: run(prob, gv, pg, opts))
            points.append({
                "K": k,
                "iterations": int(res.iterations),
                "us": t * 1e6,
                "mteps": mteps(stream.num_edges * k, t),
                "agreement": agree,
            })
        rec["points"] = points

    print(json.dumps(rec))


if __name__ == "__main__":
    main()
