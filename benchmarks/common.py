"""Shared benchmark utilities: the Table III stand-in graph suite (synthetic,
statistics matched to the paper's graphs at CPU-tractable scale), timing
helpers, and MTEPS metrics (paper §IV-B)."""
from __future__ import annotations

import resource
import time
from typing import Callable, Dict, Tuple

import numpy as np

import repro.core.graph as G

__all__ = [
    "BENCH_GRAPHS", "bench_graphs", "time_call", "mteps", "mteps_star",
    "peak_rss_mb", "timed_build",
]


def peak_rss_mb() -> float:
    """Process-wide peak resident set size in MB — recorded in every
    benchmark record so the bounded-memory claim is a measured number.
    Reads ``VmHWM`` (per-mm, resets on exec) rather than ``ru_maxrss``
    (inherited across fork+exec on Linux, so subprocesses would report the
    parent's peak). It is still a high-water mark: honest BUILD deltas need
    a fresh subprocess (see ``benchmarks.partition_build_child``);
    in-process records report the run's overall peak."""
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith("VmHWM:"):
                    return int(line.split()[1]) / 1024.0
    except OSError:  # non-Linux fallback (still a peak, unit caveats apply)
        pass
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def timed_build(fn: Callable, *args, **kwargs):
    """(result, wall_seconds) of one partition build — the per-record
    ``partition_build_s`` satellite metric."""
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    return out, time.perf_counter() - t0


def bench_graphs(scale: str = "small") -> Dict[str, Tuple[G.COOGraph, int]]:
    """name -> (graph, bfs_root). Stand-ins for Table III:
    rmat-like (lj/orkut analogues), star (wiki-talk: low avg degree, hub),
    grid (roadnet-ca: high diameter), dense rmat (mouse-gene analogue)."""
    if scale == "tiny":
        s1, s2 = 10, 9
        grid = (40, 25)
    else:
        s1, s2 = 14, 12
        grid = (160, 100)
    return {
        "rmat-sparse": (G.rmat(s1, 8, seed=1), 5),  # live-journal-ish skew
        "rmat-dense": (G.rmat(s2, 48, seed=2), 7),  # orkut/mouse-gene density
        "star-hub": (G.star((1 << s1) - 1), 0),  # wiki-talk-ish
        "grid-road": (G.grid_2d(*grid), 3),  # roadnet-ca-ish (high diameter)
    }


BENCH_GRAPHS = bench_graphs


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds (calls fn which must block on completion)."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def mteps(num_edges: int, seconds: float) -> float:
    """Graph500 MTEPS = |E| / t (the paper's headline metric; rewards
    convergence in fewer iterations)."""
    return num_edges / seconds / 1e6


def mteps_star(num_edges: int, iterations: int, seconds: float) -> float:
    """MTEPS* = |E| * iters / t (HitGraph/ThunderGP's raw edge-processing
    metric; hides convergence — reported for comparability, paper §IV-B)."""
    return num_edges * iterations / seconds / 1e6
