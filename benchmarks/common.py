"""Shared benchmark utilities: the Table III stand-in graph suite (synthetic,
statistics matched to the paper's graphs at CPU-tractable scale), timing
helpers, and MTEPS metrics (paper §IV-B)."""
from __future__ import annotations

import time
from typing import Callable, Dict, Tuple

import numpy as np

import repro.core.graph as G

__all__ = ["BENCH_GRAPHS", "bench_graphs", "time_call", "mteps", "mteps_star"]


def bench_graphs(scale: str = "small") -> Dict[str, Tuple[G.COOGraph, int]]:
    """name -> (graph, bfs_root). Stand-ins for Table III:
    rmat-like (lj/orkut analogues), star (wiki-talk: low avg degree, hub),
    grid (roadnet-ca: high diameter), dense rmat (mouse-gene analogue)."""
    if scale == "tiny":
        s1, s2 = 10, 9
        grid = (40, 25)
    else:
        s1, s2 = 14, 12
        grid = (160, 100)
    return {
        "rmat-sparse": (G.rmat(s1, 8, seed=1), 5),  # live-journal-ish skew
        "rmat-dense": (G.rmat(s2, 48, seed=2), 7),  # orkut/mouse-gene density
        "star-hub": (G.star((1 << s1) - 1), 0),  # wiki-talk-ish
        "grid-road": (G.grid_2d(*grid), 3),  # roadnet-ca-ish (high diameter)
    }


BENCH_GRAPHS = bench_graphs


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds (calls fn which must block on completion)."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def mteps(num_edges: int, seconds: float) -> float:
    """Graph500 MTEPS = |E| / t (the paper's headline metric; rewards
    convergence in fewer iterations)."""
    return num_edges / seconds / 1e6


def mteps_star(num_edges: int, iterations: int, seconds: float) -> float:
    """MTEPS* = |E| * iters / t (HitGraph/ThunderGP's raw edge-processing
    metric; hides convergence — reported for comparability, paper §IV-B)."""
    return num_edges * iterations / seconds / 1e6
