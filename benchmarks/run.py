"""Benchmark harness — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (harness contract)."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        bench_engine,
        bench_fig1_motivation,
        bench_fig9_optimizations,
        bench_fig10_scalability,
        bench_fig11_12_baseline,
        bench_frontier,
        bench_kernels,
        bench_table2_resources,
        roofline_table,
    )

    modules = [
        bench_fig1_motivation,
        bench_fig9_optimizations,
        bench_fig10_scalability,
        bench_fig11_12_baseline,
        bench_table2_resources,
        bench_kernels,
        bench_engine,
        bench_frontier,
        roofline_table,
    ]
    print("name,us_per_call,derived")

    def emit(name: str, us: float, derived: str = ""):
        print(f"{name},{us:.1f},{derived}", flush=True)

    failed = 0
    for m in modules:
        try:
            m.main(emit)
        except Exception:  # noqa: BLE001
            failed += 1
            print(f"# FAILED {m.__name__}", file=sys.stderr)
            traceback.print_exc()
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
