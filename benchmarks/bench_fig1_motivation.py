"""Fig. 1 reproduction: (a) bytes/edge of compressed CSR vs edge list as a
function of average degree; (b) iterations to convergence, asynchronous vs
synchronous update propagation."""
from __future__ import annotations

import numpy as np

import repro.core.graph as G
from benchmarks.common import bench_graphs
from repro.core.engine import EngineOptions, run
from repro.core.partition import PartitionConfig, partition_2d
from repro.core.problems import bfs


def main(emit):
    # (a) memory footprint per edge vs average degree
    for ef in (1, 2, 4, 8, 16, 32, 64):
        g = G.rmat(10, ef, seed=0)
        davg = g.num_edges / g.num_vertices
        csr = G.bytes_per_edge(g, compressed=True)
        el = G.bytes_per_edge(g, compressed=False)
        emit(
            f"fig1_bytes_per_edge/avg_deg_{davg:.1f}",
            0.0,
            f"csr={csr:.2f}B el={el:.2f}B ratio={el / csr:.2f}",
        )

    # (b) convergence: async vs sync iterations (BFS)
    for name, (g0, root) in bench_graphs("tiny").items():
        g = G.symmetrize(g0)
        pg = partition_2d(g, PartitionConfig(p=4, l=4, lane=8, stride=100, build_tiles=False))
        it_async = run(bfs(root), g, pg, EngineOptions(immediate_updates=True, backend="xla")).iterations
        it_sync = run(bfs(root), g, pg, EngineOptions(immediate_updates=False, backend="xla")).iterations
        emit(
            f"fig1_convergence/{name}",
            0.0,
            f"async_iters={it_async} sync_iters={it_sync} "
            f"speedup={it_sync / max(it_async, 1):.2f}x",
        )
