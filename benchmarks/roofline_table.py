"""Render the roofline table (EXPERIMENTS.md SSRoofline) from the dry-run
JSON records in results/dryrun/."""
from __future__ import annotations

import glob
import json
import os

from repro.launch.mesh import HW


def load_records(out_dir: str = "results/dryrun"):
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def render_markdown(recs, mesh: str = "single") -> str:
    rows = [r for r in recs if r["mesh"] == mesh]
    rows.sort(key=lambda r: r["key"])
    lines = [
        "| cell | compute s | memory s | collective s | dominant | "
        "MODEL_FLOPs | useful ratio | mem/dev GiB |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        mem_gib = (
            r["memory_analysis"]["argument_bytes"] + r["memory_analysis"]["temp_bytes"]
        ) / 2**30
        lines.append(
            f"| {r['key']} | {r['compute_s']:.3e} | {r['memory_s']:.3e} | "
            f"{r['collective_s']:.3e} | **{r['dominant']}** | "
            f"{r['model_flops']:.2e} | {r['useful_ratio']:.3f} | {mem_gib:.2f} |"
        )
    return "\n".join(lines)


def main(emit):
    recs = load_records()
    if not recs:
        emit("roofline/none", 0.0, "no dry-run records found — run repro.launch.dryrun")
        return
    for r in recs:
        dom_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = r["compute_s"] / dom_s if dom_s > 0 else 0.0
        emit(
            f"roofline/{r['key']}/{r['mesh']}",
            dom_s * 1e6,
            f"dominant={r['dominant']} compute_frac_of_bound={frac:.3f} "
            f"useful={r['useful_ratio']:.3f}",
        )


if __name__ == "__main__":
    recs = load_records()
    for mesh in ("single", "multi"):
        print(f"\n## mesh = {mesh}\n")
        print(render_markdown(recs, mesh))
