"""Fig. 11/12 reproduction: GraphScale (async, compressed CSR) vs the
synchronous edge-centric baseline (HitGraph/ThunderGP class) on identical
graphs/roots — BFS and WCC, reporting MTEPS (paper metric), MTEPS*
(competitors' metric), iteration counts, and speedups."""
from __future__ import annotations

import repro.core.graph as G
from benchmarks.common import bench_graphs, mteps, mteps_star, time_call
from repro.core.edge_centric import run_edge_centric
from repro.core.engine import EngineOptions, run
from repro.core.partition import PartitionConfig, partition_2d, partition_edge_centric
from repro.core.problems import bfs, wcc


# backend pinned to the XLA oracle: these figures isolate the paper's
# algorithmic effects; fused-vs-XLA backend timings live in bench_engine.py
def main(emit):
    speedups = []
    for name, (g0, root) in bench_graphs("tiny").items():
        g = G.symmetrize(g0)
        pg = partition_2d(g, PartitionConfig(p=4, l=4, lane=8, stride=100, build_tiles=False))
        ec = partition_edge_centric(g, p=4, lane=8)
        for pname, prob in (("bfs", bfs(root)), ("wcc", wcc())):
            gs = run(prob, g, pg, EngineOptions(backend="xla"))
            t_gs = time_call(lambda: run(prob, g, pg, EngineOptions(backend="xla")))
            eb = run_edge_centric(prob, g, ec)
            t_ec = time_call(lambda: run_edge_centric(prob, g, ec))
            sp = t_ec / t_gs
            speedups.append(sp)
            emit(
                f"fig11_12/{pname}/{name}",
                t_gs * 1e6,
                f"gs_mteps={mteps(g.num_edges, t_gs):.2f} "
                f"ec_mteps={mteps(g.num_edges, t_ec):.2f} "
                f"gs_mteps*={mteps_star(g.num_edges, gs.iterations, t_gs):.2f} "
                f"ec_mteps*={mteps_star(g.num_edges, eb.iterations, t_ec):.2f} "
                f"gs_iters={gs.iterations} ec_iters={eb.iterations} speedup={sp:.2f}x",
            )
    gmean = 1.0
    for s in speedups:
        gmean *= s
    gmean **= 1.0 / max(len(speedups), 1)
    emit("fig11_12/geomean_speedup", 0.0, f"geomean={gmean:.2f}x over edge-centric")
