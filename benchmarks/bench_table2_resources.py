"""Table II analogue: 'resource utilization by problem and number of graph
cores'. FPGA LUT/BRAM/clock become: partitioned-graph device bytes, label
scratch footprint (the per-phase gathered block = BRAM analogue), padding
overhead, and kernel tile VMEM budgets — per problem x p in {1, 2, 4}."""
from __future__ import annotations

import numpy as np

import repro.core.graph as G
from repro.core.partition import PartitionConfig, partition_2d
from repro.core.problems import bfs, pagerank, wcc


def _bytes(pg, label_width):
    edges = pg.src_gidx.nbytes + pg.dst_lidx.nbytes + pg.valid.nbytes
    labels = pg.padded_vertices * label_width
    scratch = pg.gathered_size * label_width  # per-phase crossbar block (VMEM)
    return edges, labels, scratch


def main(emit):
    g = G.symmetrize(G.rmat(12, 16, seed=0))
    for pname, width in (("bfs", 4), ("pr", 8), ("wcc", 4)):
        # PR labels are 8B in the paper (rank+degree); ours exchange 4B
        # payloads but store rank+inv_deg = 8B resident.
        for p in (1, 2, 4):
            pg = partition_2d(g, PartitionConfig(p=p, l=4, lane=8, stride=100))
            e, lab, scr = _bytes(pg, width)
            emit(
                f"table2/{pname}/p{p}",
                0.0,
                f"edge_bytes={e} label_bytes={lab} scratch_bytes_per_core={scr} "
                f"pad_ratio={pg.padding_ratio:.3f} "
                f"bytes_per_edge={(e / max(pg.num_edges, 1)):.2f}",
            )
    # kernel VMEM budgets (BlockSpec tiles): the TPU 'BRAM utilization'
    for vb, eb, gsize in ((128, 1024, 1 << 21), (512, 2048, 1 << 21)):
        vmem = gsize * 4 + vb * 4 + 3 * eb * 4
        emit(
            f"table2/kernel_tile/vb{vb}_eb{eb}",
            0.0,
            f"scratch_pad={gsize * 4 / 2**20:.1f}MiB tile_bytes={vb * 4 + 3 * eb * 4} "
            f"total_vmem={(vmem) / 2**20:.1f}MiB (of ~64MiB v5e budget)",
        )
