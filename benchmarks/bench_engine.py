"""Engine backend comparison: the fused Pallas gather-map-reduce path vs the
XLA materialize-then-reduce oracle at matched shapes, on >= 2 graph scales.

Emits CSV rows through the harness AND writes BENCH_engine.json at the repo
root so the perf trajectory is recorded across PRs. On this CPU container the
Pallas numbers are interpret-mode (correctness-grade, expected slower); the
structural win the JSON also records is the traffic model: bytes the XLA path
materializes for the (p, E_pad) contributions array that the fused path never
writes, the compressed stream's index bytes per edge (packed word vs the
9-byte uncompressed triple) and skipped-tile fraction (padding tiles the
kernel's scalar-prefetched early-out never streams), plus tile padding
with/without degree-aware packing.
"""
from __future__ import annotations

import json
import pathlib

import repro.core.graph as G
from benchmarks.common import mteps, time_call
from repro.core.engine import EngineOptions, run
from repro.core.partition import PartitionConfig, partition_2d
from repro.core.problems import bfs, pagerank

JSON_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_engine.json"

SCALES = {
    "rmat9": (9, 8, 0),  # (log2 V, avg degree, bfs root)
    "rmat11": (11, 8, 3),
}


def main(emit):
    records = []
    for sname, (s, d, root) in SCALES.items():
        g = G.symmetrize(G.rmat(s, d, seed=1))
        pg = partition_2d(g, PartitionConfig(p=4, l=4, lane=8, stride=100))
        for pname, prob in (("bfs", bfs(root)), ("pr", pagerank(tol=1e-4))):
            gg = G.rmat(s, d, seed=1) if pname == "pr" else g
            pgg = (
                partition_2d(gg, PartitionConfig(p=4, l=4, lane=8))
                if pname == "pr"
                else pg
            )
            row = {"graph": sname, "problem": pname, "V": gg.num_vertices,
                   "E": gg.num_edges, "p": pgg.p, "l": pgg.l,
                   "tile_shape": list(pgg.tile_word.shape),
                   "tile_padding_ratio": pgg.tile_padding_ratio,
                   "src_bits": pgg.src_bits,
                   "stream_bytes_per_edge": pgg.stream_bytes_per_edge,
                   "skipped_tile_fraction": pgg.skipped_tile_fraction}
            for backend in ("xla", "pallas"):
                opts = EngineOptions(backend=backend)
                res = run(prob, gg, pgg, opts)
                t = time_call(lambda: run(prob, gg, pgg, opts))
                row[f"{backend}_us"] = t * 1e6
                row[f"{backend}_iters"] = res.iterations
                row[f"{backend}_mteps"] = mteps(gg.num_edges, t)
                emit(
                    f"engine/{sname}/{pname}/{backend}",
                    t * 1e6,
                    f"iters={res.iterations} mteps={mteps(gg.num_edges, t):.2f} "
                    f"interpret={backend == 'pallas'}",
                )
            # contributions-array traffic the fused path structurally avoids
            itemsize = 4
            row["xla_contrib_bytes_per_phase"] = pgg.p * pgg.edge_pad * itemsize
            records.append(row)
    JSON_PATH.write_text(json.dumps({"records": records}, indent=2) + "\n")
    emit("engine/json", 0.0, f"wrote {JSON_PATH.name} ({len(records)} records)")
