"""Engine backend comparison: the fused Pallas gather-map-reduce path vs the
XLA materialize-then-reduce oracle at matched shapes, on >= 2 graph scales,
plus skew-heavy graphs where hub-row splitting actually bites.

Emits CSV rows through the harness AND writes BENCH_engine.json at the repo
root so the perf trajectory is recorded across PRs. On this CPU container the
Pallas numbers are interpret-mode (correctness-grade, expected slower); the
structural wins the JSON also records are the traffic model: bytes the XLA
path materializes for the (p, E_pad) contributions array that the fused path
never writes, the compressed stream's index bytes per edge (packed word vs
the 9-byte uncompressed triple), the skipped-tile fraction (padding tiles the
kernel's scalar-prefetched early-out never streams), and — on the skew suite —
the two-level-reduce effect: ``t_max`` with hub-row splitting vs the unsplit
layout's ``t_max`` (``t_max_reduction``, the stacked-stream shrink the single
fattest row block used to dictate).

The high-diameter suite (ISSUE 6) is where frontier-aware DYNAMIC tile
scheduling bites: on path/grid graphs the BFS/SSSP frontier is a thin
wavefront, so per-iteration coverage∧frontier tile skipping
(``dynamic_skipped_tile_fraction``, recorded per iteration by
``run_frontier_trace``) retires far more work than the static padding-tile
skip — the suite records both next to each other, plus dynamic-vs-static
wall-clock at matched shapes and the three-way (dynamic/static/XLA)
agreement.

The channel-scaling sweep (ISSUE 5) runs the DISTRIBUTED engine — the same
compressed stream NamedSharding-placed one core per device — at 1/2/4/8
simulated memory channels (``--xla_force_host_platform_device_count``, each
count in its own subprocess because jax locks the device count at first
init), recording per-channel ``stream_bytes_per_edge``,
``skipped_tile_fraction``, iterations-to-convergence, and the
distributed-vs-fused agreement boolean into ``BENCH_engine.json``.

The direction-switch suite (ISSUE 8) runs BFS under the push/pull hybrid
(``EngineOptions.direction='auto'``) against the PR 6 pull-only dynamic
schedule on path-512 (ordered + shuffled) and rmat11, recording the
per-iteration direction trace next to the skip fractions and asserting the
acceptance ratio on the shuffled path (auto >= 1.3x over the PR 6
schedule) with round-robin-interleaved min-of-N timing.

The MTEPS-vs-scale suite (ISSUE 10) spawns ``benchmarks.partition_build_child``
per (scale, channels) point: each child cold-starts, streams a seeded graph500
RMAT through ``partition_2d_streaming`` (recording build wall + an HONEST peak
RSS delta — ``ru_maxrss`` is a process-wide high-water mark, so only a fresh
process gives a build-attributable number), checks bit-identity against the
in-memory ``partition_2d``, and runs K∈{1,16} lane-batched BFS on the XLA
backend with cross-build label agreement. Channels here is the partition's
core count p (one core == one memory channel in the paper's model) on the
single-process backend; the distributed engine's own sweep is the
channel_scaling suite above. A separate scale-18 build-only child (~4M edges,
pull-only, tile_vb=1024) asserts the bounded-memory acceptance:
peak RSS delta < 4x the final packed footprint. Every in-process record also
carries ``partition_build_s`` and the run's ``peak_rss_mb``.

``python -m benchmarks.bench_engine --smoke`` runs a tiny-graph CI variant:
asserts the metric keys and Pallas/XLA agreement plus ONE multi-channel
point (no JSON write) so both perf paths are exercised on every CI run.
The only wall-clock threshold smoke carries is the ISSUE 8 acceptance
ratio on the full-size shuffled path-512 direction point.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

import numpy as np

import repro.core.graph as G
from benchmarks.common import mteps, peak_rss_mb, time_call, timed_build
from repro.core.engine import EngineOptions, run, run_frontier_trace
from repro.core.partition import PartitionConfig, partition_2d
from repro.core.problems import bfs, bfs_multi, pagerank, wcc
from repro.data.synthetic import path_grid_graph, query_workload, skewed_graph

JSON_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_engine.json"

SCALES = {
    "rmat9": (9, 8, 0),  # (log2 V, avg degree, bfs root)
    "rmat11": (11, 8, 3),
}

# skew-heavy graphs (ISSUE 3): one kernel row dwarfs the rest, so the unsplit
# layout's T_max is set by the fattest row block. tile_vb is small relative
# to vpc so the LPT packer has row blocks to spread virtual rows across.
SKEW = {
    "star-hub": dict(n=2048, kind="star", hub_in_degree=6000, avg_degree=2, seed=7),
    "powerlaw": dict(n=2048, kind="powerlaw", hub_in_degree=4000, zipf_a=1.5, seed=8),
}
SKEW_CFG = dict(p=4, l=2, lane=8, tile_vb=64)

# min problems must agree bit-exactly; sum (PR) reassociates across the
# virtual-row chunking, so tight tolerance (same contract as the test suite).
_PR_RTOL, _PR_ATOL = 2e-5, 1e-8

# metric keys every skew record must carry (asserted by --smoke / CI)
SKEW_METRIC_KEYS = (
    "t_max", "t_max_unsplit", "t_max_reduction", "split_row_fraction",
    "skipped_tile_fraction", "skipped_tile_fraction_unsplit", "agreement",
)

# high-diameter graphs (ISSUE 6): thin BFS/WCC wavefronts, many iterations —
# the regime where the per-iteration coverage∧frontier skip dwarfs the static
# padding skip. grid-shuffled permutes vertex ids so the wavefront scatters
# across source sub-intervals instead of marching along the id order.
HIGHDIAM = {
    "path-512": dict(width=512, height=1),
    "grid-shuffled": dict(width=96, height=24, shuffle=True, seed=5),
}
HIGHDIAM_CFG = dict(p=4, l=2, lane=8, tile_vb=64, tile_eb=64)

# metric keys every per-problem dynamic-trace dict must carry (asserted by
# --smoke / CI); the record itself also carries the static
# "skipped_tile_fraction" they are compared against, plus "agreement".
DYNAMIC_METRIC_KEYS = (
    "dynamic_skipped_tile_fraction", "mean_dynamic_skipped_tile_fraction",
    "dense_iterations", "iterations",
)

# ---------------------------------------------------------------------------
# direction-switch suite (ISSUE 8): push/pull hybrid traversal. PR 6's
# shuffled records expose the pull schedule's blind spot — word-granularity
# coverage goes dense under label shuffling (grid-shuffled dyn_skip ~0.01) —
# and the push stream's source-binned tiles are the fix: a thin frontier
# activates only the blocks that CONTAIN frontier sources, and a phase with
# no live source is skipped whole. The suite runs BFS three ways on each
# graph (pull-only == the PR 6 schedule byte-for-byte, direction='auto', and
# the XLA oracle), records the per-iteration direction trace, and on the
# shuffled path asserts the acceptance ratio: auto on the direction-tuned
# config beats the PR 6 pull-only dynamic schedule (HIGHDIAM_CFG) >= 1.3x.
# ---------------------------------------------------------------------------

DIRECTION = {
    "path-512": dict(width=512, height=1),
    "path-512-shuffled": dict(width=512, height=1, shuffle=True, seed=11),
}

# Direction-tuned partition: fine phase granularity (l=8) is the regime the
# push arm exploits — a thin wavefront lives in ~1 of 8 source sub-intervals,
# so 7 phases skip whole — while the pull arm must sweep every phase. The
# PR 6 baseline is timed on ITS OWN config (HIGHDIAM_CFG), not this one.
DIRECTION_CFG = dict(p=4, l=8, lane=8, tile_vb=64, tile_eb=64)

# the acceptance floor: shuffled path-512 BFS, auto vs the PR 6 schedule
DIRECTION_MIN_SPEEDUP = 1.3

# metric keys every direction record must carry (asserted by --smoke / CI)
DIRECTION_METRIC_KEYS = (
    "pull_us", "auto_us", "speedup_vs_pull", "iterations", "push_iterations",
    "direction", "agreement",
)


def _interleaved_best(fns, reps):
    """Min-of-``reps`` wall-clock per fn, round-robin interleaved so slow
    drift (shared single-core CI containers) hits every arm equally — a
    sequential median would let a noise burst land on one arm only."""
    import time as _time

    for fn in fns:
        fn()  # warm: trace + compile outside the timed region
    best = [float("inf")] * len(fns)
    for _ in range(reps):
        for i, fn in enumerate(fns):
            t0 = _time.perf_counter()
            fn()
            dt = _time.perf_counter() - t0
            if dt < best[i]:
                best[i] = dt
    return best


def direction_record(gname, g, root, cfg, pr6_cfg=None, reps=13,
                     time_it=True):
    """One direction-suite record: pull-only vs direction='auto' on the same
    partition + XLA oracle agreement (labels AND iteration counts) + the
    per-iteration direction trace. ``pr6_cfg`` additionally times the PR 6
    pull-only dynamic schedule on its own config as the acceptance baseline.
    ``time_it=False`` skips the wall-clock arms (kept for fast checks)."""
    prob = bfs(root)
    pg, build_s = timed_build(partition_2d, g, PartitionConfig(**cfg))
    o_pull = EngineOptions(direction="pull")
    o_auto = EngineOptions(direction="auto")
    res_x = run(prob, g, pg, EngineOptions(backend="xla"))
    res_p = run(prob, g, pg, o_pull)
    res_a = run(prob, g, pg, o_auto)
    agree = (
        _labels_agree(prob, res_a.labels["label"], res_x.labels["label"])
        and _labels_agree(prob, res_p.labels["label"], res_x.labels["label"])
        and res_a.iterations == res_p.iterations == res_x.iterations
    )
    trace = run_frontier_trace(prob, g, pg, o_auto)
    agree = agree and _labels_agree(
        prob, np.asarray(trace["labels"]["label"]),
        np.asarray(res_x.labels["label"]),
    ) and trace["iterations"] == res_x.iterations
    row = {
        "graph": gname, "problem": "bfs", "V": g.num_vertices,
        "E": g.num_edges, "p": pg.p, "l": pg.l,
        "partition_build_s": build_s,
        "peak_rss_mb": peak_rss_mb(),
        "direction_alpha": o_auto.direction_alpha,
        "direction_beta": o_auto.direction_beta,
        "stream_bytes_per_edge": pg.stream_bytes_per_edge,
        "coverage_bytes_per_edge": pg.coverage_bytes_per_edge,
        "iterations": int(res_a.iterations),
        "push_iterations": trace["push_iterations"],
        "direction": trace["direction"],
        "agreement": bool(agree),
    }
    fns = [lambda: run(prob, g, pg, o_pull), lambda: run(prob, g, pg, o_auto)]
    pg6 = None
    if pr6_cfg is not None:
        pg6 = partition_2d(g, PartitionConfig(**pr6_cfg))
        res_6 = run(prob, g, pg6, o_pull)  # the PR 6 schedule, byte-for-byte
        row["agreement"] = bool(
            row["agreement"]
            and _labels_agree(prob, res_6.labels["label"], res_x.labels["label"])
        )
        row["pr6_l"] = pg6.l
        row["pr6_iterations"] = int(res_6.iterations)
        fns.append(lambda: run(prob, g, pg6, o_pull))
    if time_it:
        best = _interleaved_best(fns, reps)
        row["pull_us"] = best[0] * 1e6
        row["auto_us"] = best[1] * 1e6
        row["speedup_vs_pull"] = best[0] / best[1]
        if pg6 is not None:
            row["pr6_pull_us"] = best[2] * 1e6
            row["speedup_vs_pr6"] = best[2] / best[1]
    else:
        row["pull_us"] = row["auto_us"] = None
        row["speedup_vs_pull"] = None
    return row


def _bench_direction(emit, records):
    for gname, gspec in DIRECTION.items():
        g = path_grid_graph(**gspec)
        row = direction_record(gname, g, 0, DIRECTION_CFG,
                               pr6_cfg=HIGHDIAM_CFG)
        records.append(row)
        emit(
            f"engine/direction/{gname}",
            row["auto_us"],
            f"iters={row['iterations']} push_iters={row['push_iterations']} "
            f"speedup_vs_pull={row['speedup_vs_pull']:.2f}x "
            f"vs_pr6={row.get('speedup_vs_pr6', 0):.2f}x "
            f"agree={row['agreement']}",
        )
    # rmat11: wide frontiers — the switch must NOT fire early (hybrid stays
    # pull through the explosion, flips push only on straggler tails).
    s, d, root = SCALES["rmat11"]
    g = G.symmetrize(G.rmat(s, d, seed=1))
    row = direction_record("rmat11", g, root,
                           dict(p=4, l=4, lane=8, tile_vb=64, tile_eb=64))
    records.append(row)
    emit(
        f"engine/direction/rmat11",
        row["auto_us"],
        f"iters={row['iterations']} push_iters={row['push_iterations']} "
        f"speedup_vs_pull={row['speedup_vs_pull']:.2f}x agree={row['agreement']}",
    )
    shuffled = next(r for r in records if r["graph"] == "path-512-shuffled")
    assert shuffled["agreement"], shuffled
    assert shuffled["speedup_vs_pull"] > 1.0, (
        f"shuffled-grid push must beat pull-only wall-clock, got "
        f"{shuffled['speedup_vs_pull']:.2f}x"
    )
    assert shuffled["speedup_vs_pr6"] >= DIRECTION_MIN_SPEEDUP, (
        f"shuffled path-512 BFS must improve >= {DIRECTION_MIN_SPEEDUP}x over "
        f"the PR 6 pull-only dynamic schedule, got "
        f"{shuffled['speedup_vs_pr6']:.2f}x"
    )


def _labels_agree(prob, a, b) -> bool:
    if prob.reduce_kind == "min":
        return bool(np.array_equal(a, b))
    return bool(np.allclose(a, b, rtol=_PR_RTOL, atol=_PR_ATOL))


def _bench_scales(emit, records):
    for sname, (s, d, root) in SCALES.items():
        g = G.symmetrize(G.rmat(s, d, seed=1))
        pg, build_s = timed_build(
            partition_2d, g, PartitionConfig(p=4, l=4, lane=8, stride=100)
        )
        rep = pg.memory_report()
        emit(
            f"engine/{sname}/memory", 0.0,
            f"device={rep['device_total_bytes'] / 1e6:.2f}MB "
            f"dev_B/edge={rep['device_bytes_per_edge']:.1f} "
            f"total_B/edge={rep['bytes_per_edge']:.1f} build={build_s:.3f}s",
        )
        for pname, prob in (("bfs", bfs(root)), ("pr", pagerank(tol=1e-4))):
            gg = G.rmat(s, d, seed=1) if pname == "pr" else g
            if pname == "pr":
                pgg, pg_build_s = timed_build(
                    partition_2d, gg, PartitionConfig(p=4, l=4, lane=8)
                )
            else:
                pgg, pg_build_s = pg, build_s
            prep = pgg.memory_report()
            row = {"graph": sname, "problem": pname, "V": gg.num_vertices,
                   "E": gg.num_edges, "p": pgg.p, "l": pgg.l,
                   "partition_build_s": pg_build_s,
                   "peak_rss_mb": peak_rss_mb(),
                   "device_bytes_per_edge": prep["device_bytes_per_edge"],
                   "memory_report": {
                       "device": prep["device"],
                       "device_total_bytes": prep["device_total_bytes"],
                       "total_bytes": prep["total_bytes"],
                   },
                   "tile_shape": list(pgg.tile_word.shape),
                   "tile_padding_ratio": pgg.tile_padding_ratio,
                   "src_bits": pgg.src_bits,
                   "stream_bytes_per_edge": pgg.stream_bytes_per_edge,
                   "skipped_tile_fraction": pgg.skipped_tile_fraction,
                   "t_max": pgg.tile_word.shape[3],
                   "t_max_reduction": pgg.t_max_reduction,
                   "split_row_fraction": pgg.split_row_fraction}
            for backend in ("xla", "pallas"):
                opts = EngineOptions(backend=backend)
                res = run(prob, gg, pgg, opts)
                t = time_call(lambda: run(prob, gg, pgg, opts))
                row[f"{backend}_us"] = t * 1e6
                row[f"{backend}_iters"] = res.iterations
                row[f"{backend}_mteps"] = mteps(gg.num_edges, t)
                emit(
                    f"engine/{sname}/{pname}/{backend}",
                    t * 1e6,
                    f"iters={res.iterations} mteps={mteps(gg.num_edges, t):.2f} "
                    f"interpret={backend == 'pallas'}",
                )
            # contributions-array traffic the fused path structurally avoids
            itemsize = 4
            row["xla_contrib_bytes_per_phase"] = pgg.p * pgg.edge_pad * itemsize
            records.append(row)


def skew_record(gname, gspec, cfg, prob_pairs, time_fn=None):
    """One skew-suite record: split vs unsplit layouts + backend agreement.
    ``time_fn=None`` skips timing (smoke mode)."""
    g = skewed_graph(**gspec)
    # splitting on (default)
    pg_split, build_s = timed_build(partition_2d, g, PartitionConfig(**cfg))
    pg_none = partition_2d(g, PartitionConfig(**cfg, split_threshold=None))
    row = {
        "graph": gname, "V": g.num_vertices, "E": g.num_edges,
        "p": pg_split.p, "l": pg_split.l,
        "partition_build_s": build_s,
        "peak_rss_mb": peak_rss_mb(),
        "device_bytes_per_edge": pg_split.memory_report()["device_bytes_per_edge"],
        "tile_shape": list(pg_split.tile_word.shape),
        "t_max": int(pg_split.tile_word.shape[3]),
        "t_max_unsplit": int(pg_none.tile_word.shape[3]),
        "t_max_reduction": pg_split.t_max_reduction,
        "split_row_fraction": pg_split.split_row_fraction,
        "src_bits": pg_split.src_bits,
        "stream_bytes_per_edge": pg_split.stream_bytes_per_edge,
        "skipped_tile_fraction": pg_split.skipped_tile_fraction,
        "skipped_tile_fraction_unsplit": pg_none.skipped_tile_fraction,
        "agreement": {},
    }
    # the partitioner's own unsplit-T bookkeeping must match the real thing
    assert pg_split.t_max_unsplit == row["t_max_unsplit"], (
        pg_split.t_max_unsplit, row["t_max_unsplit"])
    for pname, prob in prob_pairs:
        res_x = run(prob, g, pg_none, EngineOptions(backend="xla"))
        res_s = run(prob, g, pg_split, EngineOptions(backend="pallas"))
        res_u = run(prob, g, pg_none, EngineOptions(backend="pallas"))
        row["agreement"][pname] = (
            _labels_agree(prob, res_s.labels["label"], res_x.labels["label"])
            and _labels_agree(prob, res_u.labels["label"], res_x.labels["label"])
        )
        if time_fn is not None:
            for tag, pgg in (("split", pg_split), ("unsplit", pg_none), ("xla", pg_none)):
                opts = EngineOptions(backend="xla" if tag == "xla" else "pallas")
                t = time_fn(lambda: run(prob, g, pgg, opts))
                row[f"{pname}_{tag}_us"] = t * 1e6
                row[f"{pname}_{tag}_mteps"] = mteps(g.num_edges, t)
    return row


def _bench_skew(emit, records):
    for gname, gspec in SKEW.items():
        row = skew_record(
            gname, gspec, SKEW_CFG,
            (("bfs", bfs(3)), ("pr", pagerank(tol=1e-4))),
            time_fn=time_call,
        )
        records.append(row)
        emit(
            f"engine/{gname}/split",
            row["bfs_split_us"],
            f"t_max={row['t_max']}/{row['t_max_unsplit']} "
            f"reduction={row['t_max_reduction']:.2f} "
            f"agree={all(row['agreement'].values())}",
        )


def highdiam_record(gname, gspec, cfg, prob_pairs, time_fn=None):
    """One high-diameter record: per-iteration dynamic skip trace + three-way
    (dynamic / static / XLA) agreement. ``time_fn=None`` skips timing."""
    g = path_grid_graph(**gspec)
    pg, build_s = timed_build(partition_2d, g, PartitionConfig(**cfg))
    row = {
        "graph": gname, "V": g.num_vertices, "E": g.num_edges,
        "p": pg.p, "l": pg.l, "tile_shape": list(pg.tile_word.shape),
        "partition_build_s": build_s,
        "peak_rss_mb": peak_rss_mb(),
        "device_bytes_per_edge": pg.memory_report()["device_bytes_per_edge"],
        "src_bits": pg.src_bits,
        "stream_bytes_per_edge": pg.stream_bytes_per_edge,
        "coverage_bytes_per_edge": pg.coverage_bytes_per_edge,
        "skipped_tile_fraction": pg.skipped_tile_fraction,
        "dynamic": {}, "agreement": {},
    }
    opt_dyn = EngineOptions(backend="pallas")  # dynamic_tile_skip defaults on
    opt_sta = EngineOptions(backend="pallas", dynamic_tile_skip=False)
    opt_xla = EngineOptions(backend="xla")
    for pname, prob in prob_pairs:
        res_x = run(prob, g, pg, opt_xla)
        res_d = run(prob, g, pg, opt_dyn)
        res_s = run(prob, g, pg, opt_sta)
        row["agreement"][pname] = (
            _labels_agree(prob, res_d.labels["label"], res_x.labels["label"])
            and _labels_agree(prob, res_s.labels["label"], res_x.labels["label"])
            and res_d.iterations == res_s.iterations == res_x.iterations
        )
        trace = run_frontier_trace(prob, g, pg, opt_dyn)
        row["dynamic"][pname] = {
            "iterations": trace["iterations"],
            "dense_iterations": trace["dense_iterations"],
            "dynamic_skipped_tile_fraction": trace["dynamic_skipped_tile_fraction"],
            "mean_dynamic_skipped_tile_fraction":
                trace["mean_dynamic_skipped_tile_fraction"],
        }
        if time_fn is not None:
            for tag, opts in (("dynamic", opt_dyn), ("static", opt_sta),
                              ("xla", opt_xla)):
                t = time_fn(lambda: run(prob, g, pg, opts))
                row[f"{pname}_{tag}_us"] = t * 1e6
                row[f"{pname}_{tag}_mteps"] = mteps(g.num_edges, t)
    return row


def _bench_highdiam(emit, records):
    for gname, gspec in HIGHDIAM.items():
        row = highdiam_record(
            gname, gspec, HIGHDIAM_CFG,
            (("bfs", bfs(0)), ("wcc", wcc())),
            time_fn=time_call,
        )
        records.append(row)
        for pname in ("bfs", "wcc"):
            d = row["dynamic"][pname]
            emit(
                f"engine/{gname}/{pname}/dynamic",
                row[f"{pname}_dynamic_us"],
                f"iters={d['iterations']} "
                f"dyn_skip={d['mean_dynamic_skipped_tile_fraction']:.3f} "
                f"static_skip={row['skipped_tile_fraction']:.3f} "
                f"static_us={row[f'{pname}_static_us']:.0f} "
                f"agree={row['agreement'][pname]}",
            )


# ---------------------------------------------------------------------------
# multi-query suite (ISSUE 7): lane-batched BFS at K queries per compressed
# edge-stream pass vs K single-query runs. The batched run decodes every tile
# word EXACTLY as often as a K=1 run (the stream carries no lane dim —
# jaxpr-asserted in tests/test_multi_query.py); only the label payload widens
# to ceil(K/32) packed words, so per-query amortized throughput scales ~K.
# ---------------------------------------------------------------------------

MULTI_K = (1, 8, 64)

# metric keys every multi-query record must carry (asserted by --smoke / CI)
MULTI_METRIC_KEYS = (
    "K", "batched_us", "sequential_warm_us", "per_query_speedup",
    "batched_per_query_mteps", "sequential_per_query_mteps",
    "batched_stream_passes", "sequential_stream_passes", "passes_saved",
    "agreement",
)


def multi_query_record(g, pg, roots, k, time_fn, sequential_sample=None):
    """One K point: batched bfs_multi vs K single-root bfs runs on the SAME
    partition. ``sequential_sample`` caps how many distinct single-root jits
    are compiled for the warm baseline (smoke mode); None runs all K honestly.
    Both sides are timed WARM (compile excluded — conservative in favor of the
    sequential baseline, which in real serving also retraces per root)."""
    opts = EngineOptions(backend="pallas")
    chunk = [int(r) for r in roots[:k]]
    prob = bfs_multi(chunk)
    res_b = run(prob, g, pg, opts)  # compile + correctness reference
    t_batch = time_fn(lambda: run(prob, g, pg, opts))

    sample = chunk if sequential_sample is None else chunk[:sequential_sample]
    seq_probs = [bfs(r) for r in sample]
    cold = 0.0
    seq_iters = []
    agree = True
    dist = np.asarray(res_b.labels["dist"])
    for j, sp in enumerate(seq_probs):
        t0 = time_call(lambda: run(sp, g, pg, opts), warmup=0, iters=1)
        cold += t0  # first call pays trace+compile: the real per-root serving cost
        r = run(sp, g, pg, opts)
        seq_iters.append(r.iterations)
        agree = agree and bool(np.array_equal(dist[:, j], r.labels["label"]))
    t_seq_warm = time_fn(
        lambda: [run(sp, g, pg, opts) for sp in seq_probs]
    ) * (k / len(seq_probs))
    seq_passes = int(np.sum(seq_iters) * (k / len(seq_probs)))
    return {
        "K": k,
        "batched_us": t_batch * 1e6,
        "batched_iters": res_b.iterations,
        "sequential_warm_us": t_seq_warm * 1e6,
        "sequential_cold_us": cold * (k / len(seq_probs)) * 1e6,
        "sequential_sampled": len(seq_probs),
        "per_query_speedup": t_seq_warm / t_batch,
        "batched_per_query_mteps": mteps(g.num_edges * k, t_batch),
        "sequential_per_query_mteps": mteps(g.num_edges * k, t_seq_warm),
        "batched_stream_passes": res_b.iterations,
        "sequential_stream_passes": seq_passes,
        "passes_saved": seq_passes - res_b.iterations,
        "agreement": agree,
    }


def _bench_multi_query(emit, records):
    s, d, _ = SCALES["rmat11"]
    g = G.symmetrize(G.rmat(s, d, seed=1))
    pg, build_s = timed_build(
        partition_2d, g, PartitionConfig(p=4, l=4, lane=8, stride=100)
    )
    roots = query_workload(max(MULTI_K), g.num_vertices, seed=0)
    row = {"graph": "rmat11", "problem": "bfs_multi", "V": g.num_vertices,
           "E": g.num_edges, "p": pg.p, "l": pg.l,
           "partition_build_s": build_s,
           "peak_rss_mb": peak_rss_mb(),
           "stream_bytes_per_edge": pg.stream_bytes_per_edge,
           "points": []}
    for k in MULTI_K:
        rec = multi_query_record(g, pg, roots, k, time_call)
        row["points"].append(rec)
        emit(
            f"engine/multi-query/K={k}",
            rec["batched_us"],
            f"speedup={rec['per_query_speedup']:.1f}x "
            f"mteps/q={rec['batched_per_query_mteps']:.1f} "
            f"passes={rec['batched_stream_passes']}/{rec['sequential_stream_passes']} "
            f"agree={rec['agreement']}",
        )
    k64 = next(r for r in row["points"] if r["K"] == 64)
    assert k64["agreement"], k64
    assert k64["per_query_speedup"] >= 2.0, (
        f"K=64 lane batching must amortize >= 2x per query, got "
        f"{k64['per_query_speedup']:.2f}x"
    )
    records.append(row)


# ---------------------------------------------------------------------------
# channel-scaling sweep: the distributed engine at 1/2/4/8 simulated memory
# channels. Each count runs in a subprocess (jax locks the device count), the
# parent merges the per-channel JSON records.
# ---------------------------------------------------------------------------

CHANNELS = (1, 2, 4, 8)

# metric keys every per-channel record must carry (asserted by --smoke / CI)
CHANNEL_METRIC_KEYS = (
    "stream_bytes_per_edge", "skipped_tile_fraction", "iterations", "agreement",
)


def channel_record(p: int, scale: int = 10, degree: int = 8) -> dict:
    """One channel count, run IN-PROCESS (the caller guarantees >= p devices):
    distributed run vs fused single-process run on the same partition."""
    import jax

    from benchmarks.common import time_call as _time_call
    from repro.core.distributed import (
        build_distributed_run,
        run_distributed,
        shard_labels,
    )
    from repro.core.engine import prepare_labels
    from repro.launch.mesh import make_graph_mesh

    mesh = make_graph_mesh(p)
    rec = {"channels": p}
    g = G.symmetrize(G.rmat(scale, degree, seed=1))
    gd = G.rmat(scale, degree, seed=1)
    for pname, prob, graph, stride in (
        ("bfs", bfs(3), g, 100),
        ("pr", pagerank(tol=1e-4), gd, None),
    ):
        pg, build_s = timed_build(
            partition_2d, graph, PartitionConfig(p=p, l=2, lane=8, stride=stride)
        )
        res_d = run_distributed(prob, graph, pg, mesh)
        res_s = run(prob, graph, pg, EngineOptions(backend="pallas"))
        agree = (
            _labels_agree(prob, res_d.labels["label"], res_s.labels["label"])
            and res_d.iterations == res_s.iterations
        )
        # steady-state timing: build the runner ONCE and time repeated calls
        # (run_distributed rebuilds + retraces per call — compile-dominated
        # numbers made the channel trend an artifact; matches the fused
        # baseline, whose _run_jit cache is warm after the run() above)
        run_fn = build_distributed_run(prob, pg, mesh)
        sharded = shard_labels(prepare_labels(prob, graph, pg), mesh)
        t = _time_call(lambda: jax.block_until_ready(run_fn(sharded)))
        rec[pname] = {
            "stream_bytes_per_edge": pg.stream_bytes_per_edge,
            "skipped_tile_fraction": pg.skipped_tile_fraction,
            "iterations": res_d.iterations,
            "agreement": bool(agree),
            "partition_build_s": build_s,
            "distributed_us": t * 1e6,
            "distributed_mteps": mteps(graph.num_edges, t),
        }
    rec["peak_rss_mb"] = peak_rss_mb()
    return rec


def _spawn_channel_child(p: int, extra_args=()) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p}"
    env.setdefault("JAX_PLATFORMS", "cpu")  # libtpu present: pin CPU backend
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_engine",
         "--channel-child", str(p), *extra_args],
        capture_output=True, text=True, env=env, cwd=str(JSON_PATH.parent),
        timeout=1200,
    )
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return json.loads(res.stdout.strip().splitlines()[-1])


def _bench_channels(emit, channel_records, channels=CHANNELS):
    for p in channels:
        rec = _spawn_channel_child(p)
        channel_records.append(rec)
        emit(
            f"engine/channels/{p}",
            rec["bfs"]["distributed_us"],
            f"bfs_iters={rec['bfs']['iterations']} "
            f"pr_iters={rec['pr']['iterations']} "
            f"agree={rec['bfs']['agreement'] and rec['pr']['agreement']} "
            f"B/edge={rec['bfs']['stream_bytes_per_edge']}",
        )


# ---------------------------------------------------------------------------
# MTEPS-vs-scale suite (ISSUE 10): graph500-style RMAT through the streaming
# (out-of-core) partition build, swept over scale x K lanes x channels. Each
# point runs in benchmarks.partition_build_child — a fresh process — because
# ru_maxrss is a process-wide high-water mark: only a cold start yields an
# honest build-attributable RSS delta. "channels" is the partition core count
# p (the paper maps one core to one memory channel); the engine points run
# the single-process XLA backend, so the channel axis here measures how the
# p-way 2-D layout scales the SAME stream, while the distributed
# channel_scaling sweep above owns the multi-device story.
# ---------------------------------------------------------------------------

MTEPS_SCALES = (10, 12, 14)
MTEPS_CHANNELS = (1, 2)
MTEPS_K_LANES = "1,16"

# the bounded-memory acceptance build: scale-18 RMAT (~262k V, ~4.2M directed
# edges), pull-only, coarse tiles (tile_vb=1024 — the default sub_size-sized
# row blocks degenerate to R=2 at this scale), l=4 keeps the gathered
# interval inside the 16-bit packed regime
SCALE18_ARGS = (
    "--scale", "18", "--edge-factor", "16", "--p", "2", "--l", "4",
    "--tile-vb", "1024", "--no-push", "--assert-rss-ratio", "4.0",
)

# metric keys every mteps_vs_scale point must carry (asserted by --scale-smoke)
MTEPS_METRIC_KEYS = (
    "scale", "E", "partition_build_s", "peak_rss_mb", "rss_delta_mb",
    "footprint_mb", "device_bytes_per_edge", "rss_over_footprint",
    "bit_identical", "points",
)


def _spawn_build_child(extra_args=()) -> dict:
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")  # libtpu present: pin CPU backend
    env["PYTHONPATH"] = "src" + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    res = subprocess.run(
        [sys.executable, "-m", "benchmarks.partition_build_child", *extra_args],
        capture_output=True, text=True, env=env, cwd=str(JSON_PATH.parent),
        timeout=1200,
    )
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return json.loads(res.stdout.strip().splitlines()[-1])


def _bench_mteps_vs_scale(emit) -> dict:
    points = []
    for scale in MTEPS_SCALES:
        for p in MTEPS_CHANNELS:
            rec = _spawn_build_child((
                "--scale", str(scale), "--edge-factor", "8",
                "--p", str(p), "--l", "2",
                "--compare", "--engine", "--k-lanes", MTEPS_K_LANES,
            ))
            rec["channels"] = p
            assert rec["bit_identical"], rec
            assert all(pt["agreement"] for pt in rec["points"]), rec
            points.append(rec)
            for pt in rec["points"]:
                emit(
                    f"engine/mteps-vs-scale/s{scale}/c{p}/K={pt['K']}",
                    pt["us"],
                    f"mteps={pt['mteps']:.2f} iters={pt['iterations']} "
                    f"build={rec['partition_build_s']:.2f}s "
                    f"rss_delta={rec['rss_delta_mb']:.0f}MB "
                    f"agree={pt['agreement']}",
                )
    # the acceptance build: scale 18, streaming, bounded memory (the child
    # asserts rss_over_footprint < 4; no --compare — materializing 4M edges
    # in RAM is exactly what this path exists to avoid)
    b18 = _spawn_build_child(SCALE18_ARGS)
    emit(
        "engine/mteps-vs-scale/s18/build",
        b18["partition_build_s"] * 1e6,
        f"E={b18['E']} footprint={b18['footprint_mb']:.0f}MB "
        f"rss_delta={b18['rss_delta_mb']:.0f}MB "
        f"ratio={b18['rss_over_footprint']:.2f}x "
        f"dev_B/edge={b18['device_bytes_per_edge']:.1f}",
    )
    return {"points": points, "build_scale18": b18}


def scale_smoke(emit):
    """CI acceptance point for the streaming partitioner (``make bench-scale``):
    one scale-14 RMAT through ``partition_2d_streaming`` in a cold child under
    an asserted RSS ceiling, bit-identity vs the in-memory build, and XLA BFS
    label agreement across both builds. No JSON write."""
    rec = _spawn_build_child((
        "--scale", "14", "--edge-factor", "8", "--p", "2", "--l", "2",
        "--compare", "--engine", "--k-lanes", "1",
        "--assert-rss-delta-mb", "256",
    ))
    for key in MTEPS_METRIC_KEYS:
        assert key in rec, f"missing mteps_vs_scale metric {key!r}"
    assert rec["bit_identical"], "streaming build diverged from partition_2d"
    assert all(pt["agreement"] for pt in rec["points"]), rec["points"]
    emit(
        "engine/scale-smoke", rec["points"][0]["us"],
        f"scale=14 E={rec['E']} build={rec['partition_build_s']:.2f}s "
        f"rss_delta={rec['rss_delta_mb']:.0f}MB "
        f"mteps={rec['points'][0]['mteps']:.2f} bit_identical=ok agreement=ok",
    )


def main(emit):
    records = []
    _bench_scales(emit, records)
    _bench_skew(emit, records)
    _bench_highdiam(emit, records)
    _bench_direction(emit, records)
    _bench_multi_query(emit, records)
    channel_records = []
    _bench_channels(emit, channel_records)
    assert all(
        rec[p]["agreement"] for rec in channel_records for p in ("bfs", "pr")
    ), channel_records
    scale_curve = _bench_mteps_vs_scale(emit)
    # Merge rather than overwrite: --serve-smoke owns the "serving" key and
    # may have run first (check.sh order) or in a previous invocation.
    data = json.loads(JSON_PATH.read_text()) if JSON_PATH.exists() else {}
    data["records"] = records
    data["channel_scaling"] = channel_records
    data["mteps_vs_scale"] = scale_curve
    JSON_PATH.write_text(json.dumps(data, indent=2) + "\n")
    emit(
        "engine/json", 0.0,
        f"wrote {JSON_PATH.name} ({len(records)} records, "
        f"{len(channel_records)} channel points, "
        f"{len(scale_curve['points'])} scale points)",
    )


def smoke(emit):
    """Tiny-graph CI pass: exercise the fused perf path end to end, assert
    metric keys + Pallas/XLA agreement, and run ONE multi-channel point
    through the distributed engine. No timing thresholds, no JSON write."""
    spec = dict(n=256, kind="star", hub_in_degree=700, avg_degree=2, seed=7)
    cfg = dict(p=2, l=2, lane=8, tile_vb=32, tile_eb=32)
    row = skew_record(
        "smoke-star", spec, cfg,
        (("bfs", bfs(3)), ("pr", pagerank(tol=1e-4))),
        time_fn=None,
    )
    for key in SKEW_METRIC_KEYS:
        assert key in row, f"missing skew metric {key!r}"
    assert row["split_row_fraction"] > 0.0, "smoke graph must trigger splitting"
    assert row["t_max"] < row["t_max_unsplit"], row
    assert all(row["agreement"].values()), row["agreement"]
    emit(
        "engine/smoke", 0.0,
        f"t_max={row['t_max']}/{row['t_max_unsplit']} "
        f"reduction={row['t_max_reduction']:.2f} agreement=ok",
    )
    # one high-diameter dynamic-skip point: the per-iteration coverage∧frontier
    # skip must beat the static padding skip where the frontier is a wavefront
    hd = highdiam_record(
        "smoke-path", dict(width=192), dict(p=2, l=2, lane=8, tile_vb=32, tile_eb=32),
        (("bfs", bfs(0)), ("wcc", wcc())),
        time_fn=None,
    )
    for pname in ("bfs", "wcc"):
        for key in DYNAMIC_METRIC_KEYS:
            assert key in hd["dynamic"][pname], f"missing dynamic metric {key!r}"
        assert hd["agreement"][pname], hd["agreement"]
        assert (
            hd["dynamic"][pname]["mean_dynamic_skipped_tile_fraction"]
            > hd["skipped_tile_fraction"]
        ), hd
    emit(
        "engine/smoke-dynamic", 0.0,
        f"bfs_dyn_skip={hd['dynamic']['bfs']['mean_dynamic_skipped_tile_fraction']:.3f} "
        f"static_skip={hd['skipped_tile_fraction']:.3f} agreement=ok",
    )
    # the direction-switch acceptance point (ISSUE 8): shuffled path-512 BFS,
    # direction='auto' on the direction-tuned config vs the PR 6 pull-only
    # dynamic schedule on HIGHDIAM_CFG. This one smoke point DOES carry a
    # wall-clock threshold (the acceptance ratio); min-of-9 interleaved reps
    # keep it robust on noisy single-core containers.
    dg = path_grid_graph(**DIRECTION["path-512-shuffled"])
    drow = direction_record("path-512-shuffled", dg, 0, DIRECTION_CFG,
                            pr6_cfg=HIGHDIAM_CFG, reps=9)
    for key in DIRECTION_METRIC_KEYS:
        assert key in drow, f"missing direction metric {key!r}"
    assert drow["agreement"], "direction arms diverged from the XLA oracle"
    assert drow["push_iterations"] > 0, drow["direction"][:8]
    assert drow["speedup_vs_pull"] > 1.0, (
        f"shuffled-grid push must beat pull-only wall-clock, got "
        f"{drow['speedup_vs_pull']:.2f}x"
    )
    assert drow["speedup_vs_pr6"] >= DIRECTION_MIN_SPEEDUP, (
        f"shuffled path-512 BFS must improve >= {DIRECTION_MIN_SPEEDUP}x over "
        f"the PR 6 pull-only dynamic schedule, got "
        f"{drow['speedup_vs_pr6']:.2f}x"
    )
    emit(
        "engine/smoke-direction", drow["auto_us"],
        f"push_iters={drow['push_iterations']}/{drow['iterations']} "
        f"speedup_vs_pull={drow['speedup_vs_pull']:.2f}x "
        f"vs_pr6={drow['speedup_vs_pr6']:.2f}x agreement=ok",
    )
    # one K=64 lane-batching point (ISSUE 7): the batched run must amortize
    # to >= 2x the per-query throughput of single-root runs on the SAME
    # partition — both sides warm, interpret-mode. The sequential baseline
    # samples 6 distinct roots (6 single-root compiles keep smoke fast); the
    # full 64-root honest sweep runs in the non-smoke bench.
    mg = G.symmetrize(G.rmat(8, 8, seed=1))
    mpg = partition_2d(mg, PartitionConfig(p=2, l=2, lane=8))
    mroots = query_workload(64, mg.num_vertices, seed=0)
    mrec = multi_query_record(mg, mpg, mroots, 64, time_call,
                              sequential_sample=6)
    for key in MULTI_METRIC_KEYS:
        assert key in mrec, f"missing multi-query metric {key!r}"
    assert mrec["agreement"], "lane-batched labels diverged from single runs"
    assert mrec["per_query_speedup"] >= 2.0, (
        f"K=64 lane batching must amortize >= 2x per query, got "
        f"{mrec['per_query_speedup']:.2f}x"
    )
    emit(
        "engine/smoke-multi-query", mrec["batched_us"],
        f"K=64 speedup={mrec['per_query_speedup']:.1f}x "
        f"passes={mrec['batched_stream_passes']}/{mrec['sequential_stream_passes']} "
        f"agreement=ok",
    )
    # one multi-channel point: 2 simulated channels, small graph
    rec = _spawn_channel_child(2, extra_args=("--channel-scale", "8"))
    for prob_key in ("bfs", "pr"):
        for key in CHANNEL_METRIC_KEYS:
            assert key in rec[prob_key], f"missing channel metric {key!r}"
        assert rec[prob_key]["agreement"], rec
    emit(
        "engine/smoke-channels", rec["bfs"]["distributed_us"],
        f"channels=2 bfs_iters={rec['bfs']['iterations']} agreement=ok",
    )


# Steady-state (warm-jit, non-cold) serving batch budget for the serve smoke:
# a warm K-lane BFS batch on the smoke graph must clear this comfortably
# (measured ~2.5 ms on the CI container; cold/first-of-generation batches are
# excluded — they carry the trace).
SERVE_STEADY_BATCH_MS = 5.0


def serve_smoke(emit):
    """Always-on serving CI point (docs/serving.md): run a mixed-op query
    stream with one mid-stream delta flush through the request loop, merge
    the serving metrics (p50/p95/p99 latency, QPS, amortized MTEPS, steady
    batch wall, flush stats) into BENCH_engine.json under a ``serving`` key
    — preserving the engine records — and assert the steady-state BFS batch
    median stays under ``SERVE_STEADY_BATCH_MS``."""
    from repro.data.synthetic import edge_insertion_stream, mixed_query_workload
    from repro.launch.serve import _serve_events
    from repro.serve import (
        GraphService, LoopConfig, RecommendScorer, RequestLoop,
    )

    scale, degree, lanes, queries = 8, 6, 8, 96
    g0 = G.symmetrize(G.rmat(scale, degree, seed=1))
    w = (np.random.default_rng(2).random(g0.num_edges) + 0.1).astype(np.float32)
    g = G.COOGraph(src=g0.src, dst=g0.dst, num_vertices=g0.num_vertices, weights=w)
    service = GraphService(
        g, PartitionConfig(p=4, l=2), lanes=lanes,
        scorer=RecommendScorer(pool_size=32, topk=4),
    )
    loop = RequestLoop(service, LoopConfig(max_wait_ms=20.0, host_batch=lanes))
    # BFS-heavy mix: enough warm same-kind batches on both sides of the flush
    # for a meaningful steady-state median per generation
    workload = mixed_query_workload(
        queries, g.num_vertices,
        mix={"bfs": 0.55, "sssp": 0.15, "recommend": 0.2, "neighbors": 0.1},
        seed=3,
    )
    deltas = edge_insertion_stream(32, g.num_vertices, weighted=True, seed=4)
    completions = loop.run(_serve_events(workload, deltas))
    s = loop.metrics.summary()

    assert len(completions) == len(workload), (len(completions), len(workload))
    assert s["rejected"] == 0, s["rejected"]
    assert s["flushes"], "serve smoke must exercise a mid-stream delta flush"
    for f in s["flushes"]:
        assert 0 < f["repacked_fraction"] < 1.0, (
            f"flush must re-tile a strict subset of packed bytes, got "
            f"{f['repacked_fraction']:.3f}"
        )
    steady_bfs = s["per_kind"]["bfs"]["steady_batch_ms"]
    assert steady_bfs is not None and len(
        [b for b in loop.metrics.steady_batches("bfs")]
    ) >= 3, "need >= 3 steady BFS batches for a stable median"
    assert steady_bfs < SERVE_STEADY_BATCH_MS, (
        f"steady-state BFS batch median {steady_bfs:.2f} ms exceeds the "
        f"{SERVE_STEADY_BATCH_MS} ms serving budget"
    )
    assert s["amortized_mteps"] and s["amortized_mteps"] > 0.0, s["amortized_mteps"]

    # merge under "serving", preserving the engine records already on disk
    data = json.loads(JSON_PATH.read_text()) if JSON_PATH.exists() else {}
    data["serving"] = {
        "graph": {"scale": scale, "degree": degree, "num_edges": int(g.num_edges),
                  "delta_edges": 32},
        "lanes": lanes,
        "peak_rss_mb": peak_rss_mb(),
        **s,
    }
    JSON_PATH.write_text(json.dumps(data, indent=2) + "\n")
    lat = s["latency"]
    emit(
        "engine/serve-smoke", steady_bfs * 1e3,
        f"qps={s['qps']:.1f} p50={lat['p50_ms']:.1f}ms p95={lat['p95_ms']:.1f}ms "
        f"steady_bfs={steady_bfs:.2f}ms mteps={s['amortized_mteps']:.2f} "
        f"flush_frac={s['flushes'][0]['repacked_fraction']:.3f}",
    )


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-graph CI pass: asserts, no timings, no JSON")
    ap.add_argument("--serve-smoke", action="store_true",
                    help="serving CI pass: mixed-op stream + mid-stream delta "
                         "flush; merges a 'serving' key into BENCH_engine.json "
                         "and asserts the steady BFS batch budget")
    ap.add_argument("--scale-smoke", action="store_true",
                    help="streaming-partitioner CI pass (make bench-scale): "
                         "scale-14 RMAT in a cold child under an asserted RSS "
                         "ceiling + bit-identity + label agreement; no JSON")
    ap.add_argument("--channel-child", type=int, default=None, metavar="P",
                    help="internal: one channel-sweep point (needs P forced "
                         "host devices); prints a JSON record")
    ap.add_argument("--channel-scale", type=int, default=10,
                    help="log2 graph size for the channel sweep point")
    args = ap.parse_args()

    def _emit(name, us, detail=""):
        print(f"{name},{us:.1f},{detail}")

    if args.channel_child is not None:
        print(json.dumps(channel_record(args.channel_child, scale=args.channel_scale)))
    elif args.serve_smoke:
        serve_smoke(_emit)
    elif args.scale_smoke:
        scale_smoke(_emit)
    else:
        (smoke if args.smoke else main)(_emit)
