"""Fig. 9 reproduction: effect of the three GraphScale optimizations on BFS —
immediate updates, prefetch skipping (modeled as bytes saved: the functional
engine fuses it structurally), and stride mapping — normalized to all-off,
on a 4-core system, measuring iterations, wall time, and padding waste."""
from __future__ import annotations

import repro.core.graph as G
from benchmarks.common import bench_graphs, time_call
from repro.core.engine import EngineOptions, run
from repro.core.partition import PartitionConfig, partition_2d
from repro.core.problems import bfs


def main(emit):
    for name, (g0, root) in bench_graphs("tiny").items():
        g = G.symmetrize(g0)
        base_pg = partition_2d(g, PartitionConfig(p=4, l=4, lane=8))
        stride_pg = partition_2d(g, PartitionConfig(p=4, l=4, lane=8, stride=100))

        # paper-figure variants pinned to the XLA backend so the measured
        # deltas isolate the paper's optimizations; the last row times the
        # fused Pallas path (interpret on CPU) at the same matched shape
        variants = {
            "all_off": (base_pg, EngineOptions(immediate_updates=False, prefetch_skipping=False, backend="xla")),
            "immediate_updates": (base_pg, EngineOptions(immediate_updates=True, prefetch_skipping=False, backend="xla")),
            "stride_mapping": (stride_pg, EngineOptions(immediate_updates=True, backend="xla")),
            "fused_pallas": (stride_pg, EngineOptions(immediate_updates=True, backend="pallas")),
        }
        base_t = None
        for vname, (pg, opts) in variants.items():
            res = run(bfs(root), g, pg, opts)
            t = time_call(lambda: run(bfs(root), g, pg, opts))
            if base_t is None:
                base_t = t
            emit(
                f"fig9/{name}/{vname}",
                t * 1e6,
                f"iters={res.iterations} norm_runtime={t / base_t:.3f} "
                f"imbalance={pg.imbalance:.2f} pad={pg.padding_ratio:.2f}",
            )
