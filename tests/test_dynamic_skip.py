"""Frontier-aware dynamic tile scheduling (docs/tile_layout.md §7) — what
keeps the "same results, fewer tiles" claim honest:

  * three-way equivalence: dynamic-pallas == static-pallas == XLA oracle,
    labels AND iteration counts, across BFS / WCC / weighted SSSP (bit-exact)
    and PageRank (inert flag: sum problems stay dense), async and sync apply
    modes, including a hub-split skew graph (two-level reduce under dynamic
    scheduling).
  * convergence: the frontier bitmap empties exactly when the label-diff
    ``not_converged`` check would stop — same iteration counts, and the
    per-iteration frontier words are precisely the label-change words.
  * structure: the dynamic iteration's jaxpr carries the coverage bitmaps
    ONLY as packed (p, R, T, Wc) uint32 words — no per-tile unpacked
    (p, R, T, Wc*32) coverage array, no (p, E_pad) per-edge array.
  * the density switch: wide frontiers take the dense fallback
    (``dynamic_skip_density=0.0`` forces it everywhere and must reproduce the
    static schedule's skip fraction exactly; ``> 1.0`` disables it).
  * the perf claim itself: on a high-diameter path graph the mean dynamic
    skip fraction strictly exceeds the static padding skip.
"""
import dataclasses

import jax
import numpy as np

import repro.core.graph as G
from repro.core import frontier_words as fwords
from repro.core.engine import (
    EngineOptions,
    dynamic_skip_enabled,
    make_iteration,
    prepare_labels,
    run,
    run_frontier_trace,
)
from repro.core.partition import PartitionConfig, partition_2d
from repro.core.problems import bfs, pagerank, sssp, wcc
from repro.data.synthetic import path_grid_graph, skewed_graph

# dynamic_tile_skip defaults on; direction pinned to pull because this
# file asserts PULL-schedule stats (dense fallback, skipped-tile
# fractions) — under the default 'auto' narrow tails take the push arm
# and report the push stream's fractions (tests/test_direction_switch.py)
_DYN = EngineOptions(backend="pallas", direction="pull")
_STA = EngineOptions(backend="pallas", dynamic_tile_skip=False)
_XLA = EngineOptions(backend="xla")


def _weighted(g, seed=0):
    rng = np.random.default_rng(seed)
    w = rng.uniform(0.5, 2.0, g.src.shape[0]).astype(np.float32)
    return dataclasses.replace(g, weights=w)


def _three_way(prob, g, pg, sync=False):
    kw = {} if not sync else {"immediate_updates": False}
    res_x = run(prob, g, pg, dataclasses.replace(_XLA, **kw))
    res_d = run(prob, g, pg, dataclasses.replace(_DYN, **kw))
    res_s = run(prob, g, pg, dataclasses.replace(_STA, **kw))
    assert np.array_equal(res_d.labels["label"], res_x.labels["label"]), prob.name
    assert np.array_equal(res_s.labels["label"], res_x.labels["label"]), prob.name
    assert res_d.iterations == res_s.iterations == res_x.iterations, (
        prob.name, res_d.iterations, res_s.iterations, res_x.iterations)
    assert res_d.converged and res_s.converged and res_x.converged


def test_dynamic_matches_static_and_oracle_min_problems():
    g = _weighted(G.symmetrize(G.rmat(9, 6, seed=4)))
    pg = partition_2d(g, PartitionConfig(p=4, l=2, lane=8, stride=100))
    assert pg.tile_coverage is not None
    for prob in (bfs(3), wcc(), sssp(3)):
        _three_way(prob, g, pg)
        _three_way(prob, g, pg, sync=True)  # Jacobi apply, same fixed point


def test_dynamic_matches_on_hub_split_graph():
    """Dynamic scheduling composes with hub-row splitting: the coverage
    bitmap of a split tile covers the virtual rows' sources and the
    two-level combine still folds only tiles that ran."""
    g = _weighted(skewed_graph(256, kind="star", hub_in_degree=700,
                               avg_degree=2, seed=7))
    pg = partition_2d(g, PartitionConfig(p=2, l=2, lane=8, tile_vb=32,
                                         tile_eb=32))
    assert pg.split_row_fraction > 0.0  # splitting actually engaged
    for prob in (bfs(3), wcc(), sssp(3)):
        _three_way(prob, g, pg)


def test_pagerank_dynamic_flag_is_inert():
    """Sum reduces need every contribution every iteration: the flag must
    gate itself off and reproduce the static schedule."""
    g = G.rmat(9, 6, seed=4)
    pg = partition_2d(g, PartitionConfig(p=4, l=2, lane=8))
    prob = pagerank(tol=1e-4)
    assert not dynamic_skip_enabled(prob, pg, _DYN)
    res_d = run(prob, g, pg, _DYN)
    res_s = run(prob, g, pg, _STA)
    np.testing.assert_array_equal(res_d.labels["label"], res_s.labels["label"])
    assert res_d.iterations == res_s.iterations


def test_frontier_convergence_agrees_with_label_diff():
    """The free convergence check: the frontier-carried loop stops at exactly
    the iteration count of the label-diff ``not_converged`` loop, and the
    traced per-iteration frontier is the label-change words."""
    g = G.symmetrize(G.rmat(9, 6, seed=9))
    pg = partition_2d(g, PartitionConfig(p=2, l=3, lane=8))
    for prob in (bfs(5), wcc()):
        trace = run_frontier_trace(prob, g, pg, _DYN)
        ref = run(prob, g, pg, _STA)  # label-diff convergence
        assert trace["converged"]
        assert trace["iterations"] == ref.iterations
        assert np.array_equal(trace["labels"]["label"], ref.labels["label"])

    # frontier words ARE the change words: one hand-stepped iteration
    prob = bfs(5)
    labels = prepare_labels(prob, g, pg)
    step = jax.jit(make_iteration(prob, pg, _DYN))
    fw0 = fwords.full_frontier_words(pg.l, pg.sub_size, lead=(pg.p,))
    new, nf = step(labels, fw0)
    want = fwords.frontier_words_from_labels(
        labels["label"], new["label"], pg.l, pg.sub_size)
    np.testing.assert_array_equal(np.asarray(nf), np.asarray(want))


def _dynamic_iteration_avals(prob, g, pg):
    labels = prepare_labels(prob, g, pg)
    iteration = make_iteration(prob, pg, _DYN)
    fw0 = fwords.full_frontier_words(pg.l, pg.sub_size, lead=(pg.p,))
    jaxpr = jax.make_jaxpr(iteration)(labels, fw0)
    avals = []

    def walk(jp):
        for eqn in jp.eqns:
            for v in eqn.outvars:
                if hasattr(v, "aval") and hasattr(v.aval, "shape"):
                    avals.append(
                        (tuple(v.aval.shape), str(getattr(v.aval, "dtype", "")))
                    )
            for sub in jax.core.jaxprs_in_params(eqn.params):
                walk(sub.jaxpr if hasattr(sub, "jaxpr") else sub)

    walk(jaxpr.jaxpr)
    return avals


def test_dynamic_jaxpr_keeps_coverage_packed():
    """Structural bandwidth property of the schedule itself: coverage words
    stay packed uint32 — the jaxpr has no per-tile unpacked coverage array
    (Wc*32 bit columns) and still no per-edge (p, E_pad) array."""
    g = G.symmetrize(G.rmat(9, 8, seed=5))
    pg = partition_2d(g, PartitionConfig(p=2, l=2, lane=4))
    avals = _dynamic_iteration_avals(bfs(0), g, pg)
    shapes = {s for s, _ in avals}
    p, _, r, t, wc = pg.tile_coverage.shape
    assert (p, pg.edge_pad) not in shapes  # per-edge array: never
    # per-tile coverage only ever as packed words...
    assert any(s == (p, r, t, wc) and d == "uint32" for s, d in avals)
    # ...never unpacked to per-source-bit columns
    assert (p, r, t, wc * 32) not in shapes
    assert (r, t, wc * 32) not in shapes
    # and no full-size decompressed edge mask rides along with the schedule
    tile_shape = (p,) + pg.tile_word.shape[2:]
    assert not [d for s, d in avals if s == tile_shape and d == "bool"]


def test_density_switch_dense_fallback():
    g = G.symmetrize(G.rmat(9, 6, seed=2))
    pg = partition_2d(g, PartitionConfig(p=2, l=2, lane=8))
    prob = wcc()
    # density 0.0: every iteration takes the dense branch -> the dynamic
    # carry reproduces the static schedule, skipping only padding tiles
    always = run_frontier_trace(
        prob, g, pg, dataclasses.replace(_DYN, dynamic_skip_density=0.0))
    assert always["dense_iterations"] == always["iterations"]
    for f in always["dynamic_skipped_tile_fraction"]:
        assert np.isclose(f, pg.skipped_tile_fraction), (
            f, pg.skipped_tile_fraction)
    ref = run(prob, g, pg, _XLA)
    assert np.array_equal(always["labels"]["label"], ref.labels["label"])
    assert always["iterations"] == ref.iterations
    # density > 1.0: the fallback never fires
    never = run_frontier_trace(
        prob, g, pg, dataclasses.replace(_DYN, dynamic_skip_density=1.5))
    assert never["dense_iterations"] == 0
    assert np.array_equal(never["labels"]["label"], ref.labels["label"])
    # default 0.5: WCC's first iterations change every label — the wide
    # frontier must actually take the fallback at least once
    mid = run_frontier_trace(prob, g, pg, _DYN)
    assert mid["dense_iterations"] >= 1
    assert mid["dense_iterations"] < mid["iterations"]  # and not always


def test_path_graph_dynamic_skips_more_than_static():
    """The perf claim: with a thin BFS wavefront, per-iteration dead-tile
    skipping strictly beats the static padding skip, and skipping grows as
    the wave marches away from most tiles."""
    g = path_grid_graph(192)
    pg = partition_2d(g, PartitionConfig(p=2, l=2, lane=8, tile_vb=32,
                                         tile_eb=32))
    trace = run_frontier_trace(bfs(0), g, pg, _DYN)
    assert trace["converged"]
    assert (trace["mean_dynamic_skipped_tile_fraction"]
            > pg.skipped_tile_fraction)
    # every per-iteration fraction is over the same denominator as the
    # static fraction, so dynamic >= static holds pointwise too
    for f in trace["dynamic_skipped_tile_fraction"]:
        assert f >= pg.skipped_tile_fraction - 1e-12
    ref = run(bfs(0), g, pg, _XLA)
    assert np.array_equal(trace["labels"]["label"], ref.labels["label"])
    assert trace["iterations"] == ref.iterations

    # shuffled ids scatter the wavefront across sub-intervals: coverage
    # false-positives mean little is skippable on a graph this small, so the
    # claim here is equivalence under a non-contiguous frontier — and the
    # shared-denominator invariant dynamic >= static still holding.
    gs = path_grid_graph(64, 3, shuffle=True, seed=5)
    pgs = partition_2d(gs, PartitionConfig(p=2, l=2, lane=8, tile_vb=32,
                                           tile_eb=32))
    _three_way(wcc(), gs, pgs)
    ts = run_frontier_trace(wcc(), gs, pgs, _DYN)
    assert (ts["mean_dynamic_skipped_tile_fraction"]
            >= pgs.skipped_tile_fraction)


def test_frontier_given_but_dynamic_disabled_raises():
    g = G.symmetrize(G.rmat(8, 6, seed=1))
    pg = partition_2d(g, PartitionConfig(p=2, l=2, lane=8))
    labels = prepare_labels(bfs(0), g, pg)
    fw = fwords.full_frontier_words(pg.l, pg.sub_size, lead=(pg.p,))
    iteration = make_iteration(bfs(0), pg, _STA)
    try:
        iteration(labels, fw)
    except ValueError as e:
        assert "dynamic" in str(e)
    else:
        raise AssertionError("expected ValueError")
