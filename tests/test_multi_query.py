"""Multi-query lane batching (ISSUE 7): one compressed edge-stream pass
answers K queries.

Equivalence contract: lane k of a K-query run is BIT-IDENTICAL to the
corresponding single-query run —

  * packed layout (bfs_multi): reach bitmaps OR-reduce over the stream and
    hop distances are recovered level-synchronously, so every dist column
    matches ``bfs(root_k)`` exactly; ``immediate_updates`` True/False are
    identical by construction ('or' always runs the synchronous schedule).
  * vector layout (sssp_multi / ppr_multi): the trailing lane axis widens
    the payload only. min broadcasts over lanes (bit-identical, sync AND
    async); the PPR sum keeps per-lane summation order, so at a FIXED
    iteration count lanes are bit-identical to K=1 runs (per-lane
    convergence makes free-running tolerance runs stop at different
    iterations — that is the feature, not a bug).

Structural contract: the packed tile-word stream carries NO lane dimension —
a K=64 iteration fetches each tile word exactly as often as K=1 (jaxpr-
asserted below). Per-lane convergence: ``not_converged_lanes`` exposes which
lanes are still live, and a converged lane's labels freeze (monotone
reduces) while the batch keeps running for the rest.
"""
import numpy as np
import pytest

import jax

import repro.core.graph as G
from repro.core.engine import (
    EngineOptions,
    _make_iteration,
    prepare_labels,
    run,
)
from repro.core.partition import PartitionConfig, partition_2d
from repro.core.problems import bfs, bfs_multi, ppr_multi, sssp, sssp_multi
from repro.core.reference import bfs_reference
from repro.data.synthetic import skewed_graph

from test_distributed import PRELUDE, run_sub

ROOTS = [3, 7, 0, 100, 3]  # deliberate duplicate: two lanes, same source


def _bfs_graph():
    return G.symmetrize(G.rmat(8, 6, seed=13))


def _sssp_graph(seed=11):
    rng = np.random.default_rng(seed)
    g0 = G.rmat(8, 6, seed=seed)
    w = (rng.random(g0.num_edges) + 0.1).astype(np.float32)
    return G.COOGraph(src=g0.src, dst=g0.dst, num_vertices=g0.num_vertices,
                      weights=w)


# ---------------------------------------------------------------------------
# packed lanes: bfs_multi
# ---------------------------------------------------------------------------


def test_bfs_multi_lanes_match_single_runs():
    """Every dist column == the single-query run, duplicates included; the
    XLA oracle and both immediate_updates settings agree bit-exactly ('or'
    problems always run the level-synchronized schedule)."""
    g = _bfs_graph()
    pg = partition_2d(g, PartitionConfig(p=2, l=2, lane=4))
    prob = bfs_multi(ROOTS)
    res = run(prob, g, pg, EngineOptions(backend="pallas"))
    dist = res.labels["dist"]
    assert dist.shape == (g.num_vertices, len(ROOTS))
    for j, r in enumerate(ROOTS):
        single = run(bfs(r), g, pg, EngineOptions(backend="pallas"))
        np.testing.assert_array_equal(dist[:, j], single.labels["label"])
    for opts in (
        EngineOptions(backend="xla"),
        EngineOptions(backend="pallas", immediate_updates=False),
        EngineOptions(backend="pallas", dynamic_tile_skip=False),
    ):
        other = run(prob, g, pg, opts)
        np.testing.assert_array_equal(dist, other.labels["dist"])
        assert other.iterations == res.iterations


def test_bfs_multi_partial_word_lanes():
    """K=40 spans a full word + a partial second word: every lane (both
    words, including the dead tail bits) recovers the reference distances."""
    g = G.symmetrize(G.rmat(7, 4, seed=3))
    pg = partition_2d(g, PartitionConfig(p=2, l=2, lane=4))
    rng = np.random.default_rng(0)
    roots = rng.integers(0, g.num_vertices, size=40).tolist()
    res = run(bfs_multi(roots), g, pg, EngineOptions(backend="pallas"))
    for j, r in enumerate(roots):
        np.testing.assert_array_equal(res.labels["dist"][:, j],
                                      bfs_reference(g, r))


def test_multi_query_hub_split_graph():
    """Lane batching composes with hub-row splitting (two-level reduce): the
    split layout must stay bit-identical per lane on a star graph whose hub
    row actually splits — for both the packed-OR and the vector-min path."""
    g = skewed_graph(n=256, kind="star", hub_in_degree=700, avg_degree=2,
                     seed=7)
    pg = partition_2d(
        g, PartitionConfig(p=2, l=2, lane=8, tile_vb=32, tile_eb=32)
    )
    assert pg.split_row_fraction > 0.0, "graph must trigger splitting"
    opts = EngineOptions(backend="pallas")
    roots = [0, 5, 17, 0]
    res_b = run(bfs_multi(roots), g, pg, opts)
    res_s = run(sssp_multi(roots), g, pg, opts)
    for j, r in enumerate(roots):
        np.testing.assert_array_equal(res_b.labels["dist"][:, j],
                                      run(bfs(r), g, pg, opts).labels["label"])
        np.testing.assert_array_equal(res_s.labels["label"][:, j],
                                      run(sssp(r), g, pg, opts).labels["label"])


# ---------------------------------------------------------------------------
# vector lanes: sssp_multi / ppr_multi
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("immediate", [True, False])
def test_sssp_multi_lanes_match_single_runs(immediate):
    g = _sssp_graph()
    pg = partition_2d(g, PartitionConfig(p=2, l=2, lane=4))
    roots = [1, 50, 200]
    opts = EngineOptions(backend="pallas", immediate_updates=immediate)
    res = run(sssp_multi(roots), g, pg, opts)
    for j, r in enumerate(roots):
        np.testing.assert_array_equal(res.labels["label"][:, j],
                                      run(sssp(r), g, pg, opts).labels["label"])
    res_x = run(sssp_multi(roots), g, pg,
                EngineOptions(backend="xla", immediate_updates=immediate))
    np.testing.assert_array_equal(res.labels["label"], res_x.labels["label"])


def test_ppr_multi_fixed_iters_bit_identical():
    """At a FIXED iteration count every rank column is bit-identical to its
    K=1 run: the (vb, Eb) x (Eb, K) dot keeps each lane's summation in its
    own output column, so widening K cannot reassociate a lane's sum."""
    g = G.rmat(8, 6, seed=12)
    pg = partition_2d(g, PartitionConfig(p=2, l=2, lane=4))
    seeds = [2, 9, 77]
    opts = EngineOptions(backend="pallas", max_iters=12)
    res = run(ppr_multi(seeds, tol=0.0), g, pg, opts)
    assert res.iterations == 12
    for j, s in enumerate(seeds):
        single = run(ppr_multi([s], tol=0.0), g, pg, opts)
        np.testing.assert_array_equal(res.labels["label"][:, j],
                                      single.labels["label"][:, 0])


# ---------------------------------------------------------------------------
# per-lane convergence
# ---------------------------------------------------------------------------


def _two_chains():
    """Disconnected graph: a 4-vertex chain (lane 0 converges fast) and a
    47-vertex chain (lane 1 keeps the batch running)."""
    short = np.arange(3, dtype=np.uint32)
    long = np.arange(4, 50, dtype=np.uint32)
    src = np.concatenate([short, long])
    dst = np.concatenate([short + 1, long + 1])
    return G.symmetrize(G.COOGraph(src=src, dst=dst, num_vertices=51))


def test_per_lane_convergence_frozen_lane():
    """A converged lane's dist column freezes while the other lane advances,
    and not_converged_lanes reports exactly which lanes are live."""
    g = _two_chains()
    pg = partition_2d(g, PartitionConfig(p=2, l=2, lane=4))
    prob = bfs_multi([0, 4])
    labels = prepare_labels(prob, g, pg)
    iteration = _make_iteration(prob, pg, EngineOptions(backend="xla"))
    masks, dists = [], []
    for _ in range(50):
        new = iteration(labels)
        masks.append(np.asarray(prob.not_converged_lanes(labels, new)))
        dists.append(np.asarray(new["dist"]))
        if not np.asarray(prob.not_converged(labels, new)):
            break
        labels = new
    masks = np.stack(masks)
    # lane 0 (3-hop chain) finishes long before lane 1 (46-hop chain): the
    # mask must pass through [False, True] — converged lane, live batch
    assert masks[-1].tolist() == [False, False]
    lane0_live = int(np.max(np.nonzero(masks[:, 0])[0]))
    lane1_live = int(np.max(np.nonzero(masks[:, 1])[0]))
    assert lane0_live < lane1_live
    assert masks[lane0_live + 1].tolist() == [False, True]
    # frozen: lane 0's column never changes again after its last live step
    for d in dists[lane0_live + 1:]:
        np.testing.assert_array_equal(d[..., 0], dists[lane0_live][..., 0])
    # and the final distances are the per-component references
    final = run(prob, g, pg, EngineOptions(backend="pallas")).labels["dist"]
    np.testing.assert_array_equal(final[:, 0], bfs_reference(g, 0))
    np.testing.assert_array_equal(final[:, 1], bfs_reference(g, 4))


def test_engine_options_lanes_admission_check():
    g = _bfs_graph()
    pg = partition_2d(g, PartitionConfig(p=2, l=2, lane=4))
    prob = bfs_multi([1, 2, 3])
    run(prob, g, pg, EngineOptions(backend="pallas", lanes=3))  # matches: ok
    with pytest.raises(ValueError, match="lanes"):
        run(prob, g, pg, EngineOptions(backend="pallas", lanes=8))
    with pytest.raises(ValueError, match="lanes"):
        run(bfs(1), g, pg, EngineOptions(backend="pallas", lanes=3))


# ---------------------------------------------------------------------------
# structural: the stream carries no lane dimension
# ---------------------------------------------------------------------------


def _iteration_avals(problem, pg, g):
    labels = prepare_labels(problem, g, pg)
    iteration = _make_iteration(problem, pg, EngineOptions(backend="pallas"))
    jaxpr = jax.make_jaxpr(iteration)(labels)
    avals = []

    def walk(jp):
        for eqn in jp.eqns:
            for v in eqn.outvars:
                if hasattr(v, "aval") and hasattr(v.aval, "shape"):
                    avals.append(
                        (tuple(v.aval.shape), str(getattr(v.aval, "dtype", "")))
                    )
            for sub in jax.core.jaxprs_in_params(eqn.params):
                walk(sub.jaxpr if hasattr(sub, "jaxpr") else sub)

    walk(jaxpr.jaxpr)
    return avals


def test_edge_stream_fetched_once_regardless_of_k():
    """The bandwidth point of lane batching, checked structurally: a K=64
    iteration's jaxpr slices exactly ONE full-size (p, R, T, Eb) int32
    intermediate — the packed word stream, same count as K=1 — and no
    intermediate widens the stream by a lane axis."""
    g = _bfs_graph()
    pg = partition_2d(g, PartitionConfig(p=2, l=2, lane=4))
    tile_shape = (pg.p,) + pg.tile_word.shape[2:]
    rng = np.random.default_rng(1)
    for k in (1, 64):
        roots = rng.integers(0, g.num_vertices, size=k).tolist()
        avals = _iteration_avals(bfs_multi(roots), pg, g)
        int32_tiles = [d for s, d in avals if s == tile_shape and d == "int32"]
        assert len(int32_tiles) == 1, (k, int32_tiles)
        laned_tiles = [s for s, _ in avals
                       if len(s) == len(tile_shape) + 1
                       and s[: len(tile_shape)] == tile_shape]
        assert not laned_tiles, (k, laned_tiles)


# ---------------------------------------------------------------------------
# distributed: lane batching over the shard_map crossbar
# ---------------------------------------------------------------------------


def test_distributed_multi_query_matches_single_process():
    run_sub(
        PRELUDE
        + """
from repro.core import graph as G
from repro.core.partition import PartitionConfig, partition_2d
from repro.core.problems import bfs_multi, sssp_multi
from repro.core.engine import EngineOptions, run
from repro.core.distributed import run_distributed
from repro.core.frontier import run_distributed_frontier

g = G.symmetrize(G.rmat(8, 8, seed=3))
pg = partition_2d(g, PartitionConfig(p=4, l=2, lane=4, stride=100))
prob = bfs_multi([3, 7, 0, 100, 3])
res = run_distributed(prob, g, pg, mesh4)
single = run(prob, g, pg, EngineOptions(backend="pallas"))
assert np.array_equal(res.labels["dist"], single.labels["dist"])
assert res.iterations == single.iterations

rng = np.random.default_rng(5)
w = (rng.random(g.num_edges) + 0.1).astype(np.float32)
gw = G.COOGraph(src=g.src, dst=g.dst, num_vertices=g.num_vertices, weights=w)
pgw = partition_2d(gw, PartitionConfig(p=4, l=2, lane=4, stride=100))
sprob = sssp_multi([1, 50, 200])
res_s = run_distributed(sprob, gw, pgw, mesh4)
single_s = run(sprob, gw, pgw, EngineOptions(backend="pallas"))
assert np.array_equal(res_s.labels["label"], single_s.labels["label"])
assert res_s.iterations == single_s.iterations

# frontier-compressed exchange ships (index, K-row) pairs: same labels
res_f, stats = run_distributed_frontier(sssp_multi([1, 50, 200]), gw, pgw,
                                        mesh4, budget=64)
assert np.array_equal(res_f.labels["label"], single_s.labels["label"])
assert stats["sparse_phases"] + stats["full_phases"] > 0
print("OK")
"""
    )
