"""The always-on graph service (ISSUE 9, docs/serving.md): admission
batching, workload generators, the mixed-op router, the request loop, and
the resident-partition swap protocol (jit-cache eviction on flush).

Router answers are checked against INDEPENDENT oracles — single-query engine
runs for bfs/sssp, a direct lane-batched run through the problems API for
ppr, and the raw COO edge list for neighbors — not against the router's own
machinery.
"""
import numpy as np
import pytest

import repro.core.graph as G
from repro.core.engine import EngineOptions, evict_from_cache, prepare_labels, run
from repro.core.partition import PartitionConfig, partition_2d
from repro.core.problems import INF_U32, bfs, ppr_multi, sssp
from repro.data.synthetic import (
    DEFAULT_QUERY_MIX,
    QUERY_KINDS,
    admission_batches,
    edge_insertion_stream,
    mixed_query_workload,
)
from repro.serve import (
    GraphService,
    LoopConfig,
    Query,
    RecommendScorer,
    RequestLoop,
    latency_summary,
)

LANES = 4


@pytest.fixture(scope="module")
def graph():
    g0 = G.symmetrize(G.rmat(6, 4, seed=1))
    w = (np.random.default_rng(2).random(g0.num_edges) + 0.1).astype(np.float32)
    return G.COOGraph(src=g0.src, dst=g0.dst, num_vertices=g0.num_vertices, weights=w)


@pytest.fixture(scope="module")
def service(graph):
    return GraphService(
        graph, PartitionConfig(p=2, l=2), lanes=LANES,
        scorer=RecommendScorer(pool_size=16, topk=4),
    )


# ---------------------------------------------------------------------------
# admission batching + workload generators


def test_admission_batches_partial_padding():
    roots = np.arange(10)
    batches = admission_batches(roots, 4)
    assert [served for _, served in batches] == [4, 4, 2]
    assert all(chunk.shape == (4,) for chunk, _ in batches)
    # the partial batch is padded by repeating its LAST root
    assert batches[-1][0].tolist() == [8, 9, 9, 9]


def test_admission_batches_edge_cases():
    assert admission_batches(np.array([], dtype=np.int64), 4) == []
    with pytest.raises(ValueError):
        admission_batches(np.arange(3), 0)
    # K = 1: every query is its own batch, nothing padded
    batches = admission_batches(np.array([5, 5, 7]), 1)
    assert [c.tolist() for c, _ in batches] == [[5], [5], [7]]
    # duplicate roots inside one batch survive (duplicate lanes are cheap)
    (chunk, served), = admission_batches(np.array([3, 3, 3, 3]), 4)
    assert chunk.tolist() == [3, 3, 3, 3] and served == 4


def test_mixed_query_workload_contract():
    wl = mixed_query_workload(64, 128, seed=5)
    assert wl == mixed_query_workload(64, 128, seed=5)  # deterministic
    assert len(wl) == 64
    for q in wl:
        assert q["kind"] in DEFAULT_QUERY_MIX
        assert 0 <= q["root"] < 128 and 0 <= q["target"] < 128
    # all requested kinds actually show up at this size
    assert {q["kind"] for q in wl} == set(DEFAULT_QUERY_MIX)
    only = mixed_query_workload(8, 128, mix={"bfs": 1.0}, seed=5)
    assert {q["kind"] for q in only} == {"bfs"}


def test_mixed_query_workload_validation():
    with pytest.raises(ValueError):
        mixed_query_workload(4, 16, mix={"not-a-kind": 1.0})
    with pytest.raises(ValueError):
        mixed_query_workload(4, 16, mix={"bfs": 0.0})
    assert set(QUERY_KINDS) >= set(DEFAULT_QUERY_MIX)


def test_edge_insertion_stream_contract():
    batches = edge_insertion_stream(30, 64, num_batches=4, weighted=True, seed=6)
    assert len(batches) == 4
    assert sum(s.shape[0] for s, _, _ in batches) == 30
    for s, d, w in batches:
        assert s.shape == d.shape == w.shape and w.dtype == np.float32
        assert s.min() >= 0 and max(s.max(), d.max()) < 64
    s, d, w = edge_insertion_stream(10, 64, seed=7)[0]
    assert w is None and s.shape == (10,)


def test_latency_summary_empty():
    s = latency_summary([])
    assert s["n"] == 0 and s["p50_ms"] is None and s["p99_ms"] is None


# ---------------------------------------------------------------------------
# router answers vs independent oracles


def test_bfs_batch_matches_single_query_oracle(graph, service):
    roots = [0, 7, 19, 33]
    qs = [Query(kind="bfs", root=r, target=5, qid=i) for i, r in enumerate(roots)]
    res = service.answer_batch(qs)
    assert res.served == 4 and res.kind == "bfs"
    pg = partition_2d(graph, PartitionConfig(p=2, l=2))
    for q, ans in zip(qs, res.answers):
        lab = run(bfs(q.root), graph, pg, EngineOptions()).labels["label"]
        want = int(lab[q.target])
        assert ans["reachable"] == (want != int(INF_U32))
        assert ans["distance"] == want


def test_sssp_batch_matches_single_query_oracle(graph, service):
    qs = [Query(kind="sssp", root=r, target=9, qid=i) for i, r in enumerate([2, 11])]
    res = service.answer_batch(qs)
    assert res.served == 2  # partial batch: padded to K internally
    pg = partition_2d(graph, PartitionConfig(p=2, l=2))
    for q, ans in zip(qs, res.answers):
        lab = run(sssp(q.root), graph, pg, EngineOptions()).labels["label"]
        assert ans["distance"] == float(lab[q.target])
        assert ans["reachable"] == bool(np.isfinite(lab[q.target]))


def test_ppr_batch_matches_direct_lane_run(graph, service):
    root = 3
    qs = [Query(kind="ppr", root=root, qid=0)]
    ans = service.answer_batch(qs).answers[0]
    # oracle: the identical lane batch built directly through the problems
    # API (the router pads a partial batch by repeating the last root)
    prob = ppr_multi([root] * LANES, tol=service.ppr_tol)
    labels = prepare_labels(prob, graph, service.pg)
    res = run(prob, graph, service.pg, service.opts, labels=labels)
    lab = np.asarray(res.labels["label"])
    top = np.argsort(-lab[:, 0], kind="stable")[: service.ppr_topk]
    assert np.array_equal(ans["vertices"], top)
    assert np.array_equal(ans["scores"], lab[top, 0])


def test_neighbors_matches_coo(graph, service):
    qs = [Query(kind="neighbors", root=v, qid=i) for i, v in enumerate([0, 13, 40])]
    res = service.answer_batch(qs)
    assert res.iterations == 0
    for q, ans in zip(qs, res.answers):
        want = np.sort(graph.src[graph.dst == q.root])
        assert np.array_equal(np.sort(ans), want.astype(ans.dtype))


def test_recommend_shapes_and_determinism(graph, service):
    a1 = service.answer_batch([Query(kind="recommend", root=8, qid=0)]).answers[0]
    a2 = service.answer_batch([Query(kind="recommend", root=8, qid=1)]).answers[0]
    assert a1["vertices"].shape == (4,) and a1["scores"].shape == (4,)
    assert np.array_equal(a1["vertices"], a2["vertices"])
    assert np.array_equal(a1["scores"], a2["scores"])
    # candidates come from the scorer's hub pool
    assert set(a1["vertices"].tolist()) <= set(
        service.scorer._pool_vertices.tolist()
    )


def test_batch_validation(service):
    with pytest.raises(ValueError):
        service.answer_batch([])
    with pytest.raises(ValueError):
        service.answer_batch([Query(kind="bfs", root=0), Query(kind="sssp", root=0)])
    with pytest.raises(ValueError):
        service.answer_batch([Query(kind="pagerank", root=0)])
    with pytest.raises(ValueError):
        service.answer_batch([Query(kind="bfs", root=0)] * (LANES + 1))


# ---------------------------------------------------------------------------
# request loop


def test_loop_capacity_rejection(graph):
    svc = GraphService(graph, PartitionConfig(p=2, l=2), lanes=LANES)
    loop = RequestLoop(svc, LoopConfig(queue_capacity=2, max_wait_ms=1e6))
    assert loop.submit(Query(kind="bfs", root=0, qid=0), now=0.0)
    assert loop.submit(Query(kind="bfs", root=1, qid=1), now=0.0)
    assert not loop.submit(Query(kind="bfs", root=2, qid=2), now=0.0)
    assert loop.queued == 2 and loop.metrics.rejected == 1


def test_loop_coalesces_full_batch(graph):
    svc = GraphService(graph, PartitionConfig(p=2, l=2), lanes=LANES)
    loop = RequestLoop(svc, LoopConfig(max_wait_ms=1e6))
    for i in range(LANES):
        assert loop.submit(Query(kind="bfs", root=i, qid=i), now=0.0)
    done = loop.pump(now=0.0)  # full-width batch drains with no deadline
    assert [c.qid for c in done] == list(range(LANES))
    assert len(loop.metrics.batches) == 1
    b = loop.metrics.batches[0]
    assert b.served == LANES and b.kind == "bfs"
    assert all(c.latency_ms >= 0.0 for c in done)


def test_loop_deadline_drains_partial_batch(graph):
    svc = GraphService(graph, PartitionConfig(p=2, l=2), lanes=LANES)
    loop = RequestLoop(svc, LoopConfig(max_wait_ms=20.0))
    assert loop.submit(Query(kind="sssp", root=1, qid=7), now=0.0)
    assert loop.pump(now=0.010) == []  # young partial batch keeps waiting
    done = loop.pump(now=0.025)  # past the 20 ms deadline
    assert [c.qid for c in done] == [7]
    assert loop.metrics.batches[-1].served == 1


def test_loop_run_replays_mixed_stream(graph):
    svc = GraphService(
        graph, PartitionConfig(p=2, l=2), lanes=LANES,
        scorer=RecommendScorer(pool_size=16, topk=4),
    )
    loop = RequestLoop(svc, LoopConfig(max_wait_ms=5.0, host_batch=LANES))
    wl = mixed_query_workload(20, graph.num_vertices, seed=9)
    events = [
        ("query", Query(kind=q["kind"], root=q["root"], target=q["target"], qid=i))
        for i, q in enumerate(wl)
    ]
    done = loop.run(events)
    assert sorted(c.qid for c in done) == list(range(20))
    s = loop.metrics.summary()
    assert s["queries"] == 20 and s["latency"]["n"] == 20
    assert s["qps"] > 0 and s["batches"] == len(loop.metrics.batches)
    for kind in {q["kind"] for q in wl}:
        assert s["per_kind"][kind]["latency"]["n"] == sum(
            1 for q in wl if q["kind"] == kind
        )


# ---------------------------------------------------------------------------
# flush protocol: swap, generation bump, jit-cache eviction


def test_flush_mid_stream_matches_fresh_service(graph):
    svc = GraphService(
        graph, PartitionConfig(p=2, l=2), lanes=LANES,
        scorer=RecommendScorer(pool_size=16, topk=4),
    )
    qs = [Query(kind="bfs", root=r, target=21, qid=i) for i, r in enumerate(range(4))]
    first = svc.answer_batch(qs)
    assert first.cold  # generation 0, first bfs batch traces
    assert not svc.answer_batch(qs).cold  # warm now
    old_pg = svc.pg
    src, dst, w = edge_insertion_stream(24, graph.num_vertices, weighted=True, seed=3)[0]
    svc.ingest(src, dst, w)
    rec = svc.flush()
    assert rec.edges_added == 24 and svc.generation == 1
    assert svc.pg is not old_pg  # the resident partition was SWAPPED, not mutated
    assert not evict_from_cache(old_pg)  # flush already evicted the retired entry
    assert svc.g.num_edges == graph.num_edges + 24
    post = svc.answer_batch(qs)
    assert post.cold  # new generation: first batch per kind re-traces
    # answers on the delta-retiled resident == a fresh service on the grown
    # graph with a cold partition
    g2 = G.COOGraph(
        src=np.concatenate([graph.src, src.astype(graph.src.dtype)]),
        dst=np.concatenate([graph.dst, dst.astype(graph.dst.dtype)]),
        num_vertices=graph.num_vertices,
        weights=np.concatenate([graph.weights, w]),
    )
    fresh = GraphService(g2, PartitionConfig(p=2, l=2), lanes=LANES)
    for a, b in zip(post.answers, fresh.answer_batch(qs).answers):
        assert a == b


def test_auto_flush_threshold(graph):
    svc = GraphService(
        graph, PartitionConfig(p=2, l=2), lanes=LANES, auto_flush_edges=8,
    )
    loop = RequestLoop(svc)
    loop.ingest([1, 2, 3], [4, 5, 6], [1.0, 1.0, 1.0])
    assert svc.delta.pending_edges == 3  # below threshold: staged only
    loop.ingest([7] * 5, [8] * 5, [1.0] * 5)
    assert svc.delta.pending_edges == 0  # threshold crossed: auto-flushed
    assert svc.generation == 1 and len(loop.metrics.flushes) == 1
    assert svc.g.num_edges == graph.num_edges + 8


def test_opts_lanes_mismatch_rejected(graph):
    with pytest.raises(ValueError):
        GraphService(
            graph, PartitionConfig(p=2, l=2), lanes=4, opts=EngineOptions(lanes=8),
        )
