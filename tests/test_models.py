"""Model zoo tests: transformer forward/decode equivalence, MoE, gradients;
GNN forwards; DIN scoring. Plus the 10 per-arch reduced-config smoke tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.data.synthetic import (
    batched_molecules,
    graph_batch_from_coo,
    lm_batch,
    recsys_batch,
    retrieval_batch,
)
from repro.models.layers import MoEConfig, moe_ffn
from repro.models.transformer import (
    LMConfig,
    count_params,
    decode_step,
    forward,
    init_kv_cache,
    init_params,
)
import repro.core.graph as G
from repro.models.gnn import archs as gnn
from repro.models.recsys.din import init as din_init, score, score_candidates

TINY = LMConfig(
    name="tiny", n_layers=3, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
    vocab=101, qk_norm=True, dtype=jnp.float32, attn_chunk=8,
)


def test_transformer_decode_matches_forward():
    p = init_params(jax.random.key(0), TINY)
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, 101)
    logits, _ = jax.jit(lambda p, t: forward(p, t, TINY))(p, toks)
    cache = init_kv_cache(TINY, 2, 16, dtype=jnp.float32)
    step = jax.jit(lambda p, c, t, i: decode_step(p, c, t, i, TINY))
    for i in range(16):
        lg, cache = step(p, cache, toks[:, i : i + 1], jnp.int32(i))
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(logits[:, i, :]), atol=2e-3
        )


def test_transformer_scan_unroll_equivalence():
    """Unrolled scans (dry-run costing mode) are numerically identical."""
    import dataclasses

    p = init_params(jax.random.key(0), TINY)
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, 101)
    a, _ = forward(p, toks, TINY)
    b, _ = forward(p, toks, dataclasses.replace(TINY, scan_unroll=True))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_transformer_grads_flow():
    p = init_params(jax.random.key(0), TINY)
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, 101)

    def loss(p):
        lg, aux = forward(p, toks, TINY)
        return jnp.mean(lg.astype(jnp.float32) ** 2) + aux

    g = jax.jit(jax.grad(loss))(p)
    total = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g))
    assert np.isfinite(total) and total > 0


def test_moe_capacity_drop_and_combine():
    """With huge capacity, sort-based MoE equals dense per-token expert mix."""
    t, d, e, k = 32, 16, 4, 2
    rng = jax.random.key(0)
    ks = jax.random.split(rng, 5)
    x = jax.random.normal(ks[0], (t, d))
    router = jax.random.normal(ks[1], (d, e)) * 0.1
    w1 = jax.random.normal(ks[2], (e, d, 8)) * 0.1
    w3 = jax.random.normal(ks[3], (e, d, 8)) * 0.1
    w2 = jax.random.normal(ks[4], (e, 8, d)) * 0.1
    cfg = MoEConfig(num_experts=e, top_k=k, d_ff_expert=8)
    out, aux = moe_ffn(x, router, w1, w3, w2, cfg, capacity=t * k)  # no drops
    # dense reference
    gates = jax.nn.softmax((x @ router).astype(jnp.float32), axis=-1)
    top_g, top_i = jax.lax.top_k(gates, k)
    top_g = top_g / top_g.sum(-1, keepdims=True)
    ref = jnp.zeros_like(out)
    for ei in range(e):
        h = jax.nn.silu(x @ w1[ei]) * (x @ w3[ei])
        y = h @ w2[ei]
        wgt = ((top_i == ei) * top_g).sum(-1)[:, None].astype(x.dtype)
        ref = ref + y * wgt
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-5)
    assert float(aux) >= 0


def test_moe_grouped_matches_ungrouped():
    """Grouped dispatch (the production path) must equal single-group
    dispatch given per-group capacity >= demand."""
    from repro.models.layers import moe_ffn_grouped

    t, d, e, k = 64, 16, 4, 2
    ks = jax.random.split(jax.random.key(3), 5)
    x = jax.random.normal(ks[0], (t, d))
    router = jax.random.normal(ks[1], (d, e)) * 0.1
    w1 = jax.random.normal(ks[2], (e, d, 8)) * 0.1
    w3 = jax.random.normal(ks[3], (e, d, 8)) * 0.1
    w2 = jax.random.normal(ks[4], (e, 8, d)) * 0.1
    cfg = MoEConfig(num_experts=e, top_k=k, d_ff_expert=8)
    ref, _ = moe_ffn(x, router, w1, w3, w2, cfg, capacity=t * k)
    for g in (1, 2, 4):
        out, aux = moe_ffn_grouped(
            x, router, w1, w3, w2, cfg, capacity=(t // g) * k, groups=g
        )
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4, atol=2e-5)
        assert np.isfinite(float(aux))


def test_moe_capacity_zero_overflow_drops():
    t, d, e = 16, 8, 4
    cfg = MoEConfig(num_experts=e, top_k=1, d_ff_expert=4)
    ks = jax.random.split(jax.random.key(1), 5)
    out, _ = moe_ffn(
        jax.random.normal(ks[0], (t, d)),
        jax.random.normal(ks[1], (d, e)),
        jax.random.normal(ks[2], (e, d, 4)),
        jax.random.normal(ks[3], (e, d, 4)),
        jax.random.normal(ks[4], (e, 4, d)),
        cfg,
        capacity=8,
    )
    assert out.shape == (t, d) and bool(jnp.isfinite(out).all())


def test_count_params_formula_matches_init():
    p = init_params(jax.random.key(0), TINY)
    actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(p))
    assert actual == count_params(TINY)


# ---------------------------------------------------------------------------
# per-arch smoke tests (reduced configs, one step on CPU, per assignment)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch_id", [a for a in ARCHS if ARCHS[a].family == "lm"])
def test_smoke_lm(arch_id):
    arch = ARCHS[arch_id]
    cfg = arch.smoke()
    p = init_params(jax.random.key(0), cfg)
    batch = lm_batch(0, 0, batch=2, seq=16, vocab=cfg.vocab)
    logits, aux = jax.jit(lambda p, t: forward(p, t, cfg))(p, jnp.asarray(batch["tokens"]))
    assert logits.shape == (2, 16, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    # one train step
    from repro.train.optim import AdamWConfig
    from repro.train.steps import init_train_state, make_lm_train_step

    ocfg = AdamWConfig(lr=1e-3, total_steps=10)
    st = init_train_state(p, ocfg)
    ts = jax.jit(make_lm_train_step(cfg, ocfg))
    st, m = ts(st, {k: jnp.asarray(v) for k, v in batch.items()})
    assert np.isfinite(float(m["loss"]))


@pytest.mark.parametrize("arch_id", [a for a in ARCHS if ARCHS[a].family == "gnn"])
def test_smoke_gnn(arch_id):
    arch = ARCHS[arch_id]
    cfg = arch.smoke()
    g = G.symmetrize(G.rmat(7, 4, seed=2))
    batch, labels = graph_batch_from_coo(
        np.asarray(g.src), np.asarray(g.dst), g.num_vertices, d_feat=12, n_classes=4
    )
    p = gnn.init(jax.random.key(0), cfg, in_dim=12, out_dim=4)
    out = jax.jit(lambda p, b: gnn.apply(p, b, cfg))(p, batch)
    assert out.shape == (batch.num_nodes, 4)
    assert not bool(jnp.isnan(out).any())
    from repro.train.optim import AdamWConfig
    from repro.train.steps import init_train_state, make_gnn_train_step

    ocfg = AdamWConfig(lr=1e-3, total_steps=10)
    st = init_train_state(p, ocfg)
    ts = jax.jit(make_gnn_train_step(cfg, ocfg, task="node_class"))
    st, m = ts(st, batch, jnp.asarray(labels % 4))
    assert np.isfinite(float(m["loss"]))


def test_smoke_din():
    arch = ARCHS["din"]
    cfg = arch.smoke()
    p = din_init(jax.random.key(0), cfg)
    b = {
        k: jnp.asarray(v)
        for k, v in recsys_batch(
            0, 0, 8, cfg.seq_len, cfg.item_vocab, cfg.cate_vocab, cfg.profile_bag_len
        ).items()
    }
    logits = jax.jit(lambda p, b: score(p, b, cfg))(p, b)
    assert logits.shape == (8,) and not bool(jnp.isnan(logits).any())
    rb = {
        k: jnp.asarray(v)
        for k, v in retrieval_batch(
            0, cfg.seq_len, 128, cfg.item_vocab, cfg.cate_vocab, cfg.profile_bag_len
        ).items()
    }
    sc = jax.jit(lambda p, b: score_candidates(p, b, cfg, chunk=64))(p, rb)
    sc2 = jax.jit(lambda p, b: score_candidates(p, b, cfg, chunk=None))(p, rb)
    np.testing.assert_allclose(np.asarray(sc), np.asarray(sc2), rtol=1e-5, atol=1e-6)


def test_molecule_graph_classification_trains():
    cfg = ARCHS["gin-tu"].smoke()
    mb, mlab = batched_molecules(0, n_graphs=8, nodes_per=10, edges_per=20, d_feat=12)
    p = gnn.init(jax.random.key(1), cfg, 12, 2)
    from repro.train.optim import AdamWConfig
    from repro.train.steps import init_train_state, make_gnn_train_step

    ocfg = AdamWConfig(lr=1e-2, total_steps=30, warmup_steps=1)
    st = init_train_state(p, ocfg)
    ts = jax.jit(make_gnn_train_step(cfg, ocfg, task="graph_class"))
    losses = []
    for _ in range(15):
        st, m = ts(st, mb, jnp.asarray(mlab))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]  # it learns the toy labels
