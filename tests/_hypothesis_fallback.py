"""Minimal stand-in for ``hypothesis`` when it is not installed.

The container image cannot pip-install, so property tests degrade to
deterministic random-example sweeps: ``@given`` draws ``max_examples``
pseudo-random examples from the strategies with an rng seeded by the test
name (stable across runs — failures are reproducible, not flaky). Install
``hypothesis`` (see requirements-dev.txt) to get real shrinking/search.

Only the surface the test suite uses is implemented: ``given``, ``settings``
(max_examples, deadline ignored), ``strategies.integers / sampled_from /
booleans``.
"""
from __future__ import annotations

import functools
import zlib

import numpy as np

__version__ = "0.0-fallback"


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)


def integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: elements[int(rng.integers(0, len(elements)))])


def booleans():
    return _Strategy(lambda rng: bool(rng.integers(0, 2)))


class strategies:  # mirrors `from hypothesis import strategies as st`
    integers = staticmethod(integers)
    sampled_from = staticmethod(sampled_from)
    booleans = staticmethod(booleans)


def settings(max_examples=10, deadline=None, **_ignored):
    def deco(fn):
        fn._fallback_max_examples = max_examples
        return fn

    return deco


def given(**strategy_kwargs):
    def deco(fn):
        n = getattr(fn, "_fallback_max_examples", 10)

        # zero-arg wrapper: strategy args must NOT look like pytest fixtures
        @functools.wraps(fn)
        def wrapper():
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
            for _ in range(n):
                drawn = {k: s.example(rng) for k, s in strategy_kwargs.items()}
                fn(**drawn)

        del wrapper.__wrapped__  # keep pytest from seeing fn's signature
        return wrapper

    return deco
