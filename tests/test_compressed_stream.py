"""Compressed edge-stream equivalence suite.

Three implementations of the phase reduction must agree:

  1. compressed-Pallas  — ``gather_reduce_cores_pallas`` on the bit-packed
     word stream with scalar-prefetched tile-count skipping (the engine hot
     path),
  2. uncompressed-Pallas — ``gather_reduce_pallas`` on the raw
     (src, dstb, valid) tile arrays (runs every tile, padding included),
  3. XLA oracle — ``gather_reduce_reference`` / the engine's ``backend='xla'``.

Min reductions (BFS/WCC/SSSP) must be BIT-IDENTICAL everywhere. Sum (PR) is
bit-identical between the two Pallas paths (identical tile binning; skipped
tiles only ever add the exact 0.0 identity) and tight-tolerance vs the oracle
(different summation order by design).

Also pins down the packed word format itself (roundtrip + field-overflow
rejection) and the 16->32-bit regime fallback when the gathered crossbar
block outgrows the 16-bit src field (p * sub_size > 2^16).
"""
import numpy as np
import pytest

import jax.numpy as jnp

import repro.core.graph as G
from repro.core.engine import EngineOptions, prepare_labels, run
from repro.core.partition import PartitionConfig, partition_2d
from repro.core.problems import bfs, pagerank, sssp, wcc
from repro.kernels.csr_gather_reduce.kernel import (
    gather_reduce_cores_pallas,
    gather_reduce_pallas,
)
from repro.kernels.csr_gather_reduce.ops import (
    DSTB16_LIMIT,
    SRC16_LIMIT,
    choose_src_bits,
    pack_edge_words,
    prepare_tiles,
    stack_packed_tiles,
)
from repro.kernels.csr_gather_reduce.ref import gather_reduce_reference

PROBLEMS = ["bfs", "wcc", "sssp", "pagerank"]


# ---------------------------------------------------------------------------
# Packed word format
# ---------------------------------------------------------------------------


def _unpack_np(word, word_hi, src_bits):
    """Numpy mirror of the in-kernel shift/mask decode."""
    if src_bits == 16:
        w = word.view(np.uint32)
        return w & 0xFFFF, (w >> 16) & 0x7FFF, word < 0
    hi = word_hi.view(np.uint32)
    return word.view(np.uint32), hi & 0x7FFFFFFF, word_hi < 0


@pytest.mark.parametrize("src_bits", [16, 32])
def test_pack_roundtrip(src_bits, rng):
    n = 4096
    src_max = SRC16_LIMIT if src_bits == 16 else 1 << 20
    dst_max = DSTB16_LIMIT if src_bits == 16 else 1 << 18
    src = rng.integers(0, src_max, n).astype(np.int64)
    dstb = rng.integers(0, dst_max, n).astype(np.int64)
    valid = rng.random(n) < 0.7
    # force the boundary values so the field widths are actually exercised
    src[0], dstb[0], valid[0] = src_max - 1, dst_max - 1, True
    src[1], dstb[1], valid[1] = 0, 0, False
    word, word_hi = pack_edge_words(src, dstb, valid, src_bits=src_bits)
    assert word.dtype == np.int32
    assert (word_hi is None) == (src_bits == 16)
    s, d, v = _unpack_np(word, word_hi, src_bits)
    np.testing.assert_array_equal(s, src)
    np.testing.assert_array_equal(d, dstb)
    np.testing.assert_array_equal(v, valid)


def test_pack_rejects_field_overflow():
    ok = np.zeros(2, np.int64)
    with pytest.raises(ValueError, match="16-bit"):
        pack_edge_words(np.array([SRC16_LIMIT]), ok[:1], np.ones(1, bool), src_bits=16)
    with pytest.raises(ValueError, match="15-bit"):
        pack_edge_words(ok[:1], np.array([DSTB16_LIMIT]), np.ones(1, bool), src_bits=16)
    with pytest.raises(ValueError, match="16 or 32"):
        pack_edge_words(ok, ok, np.ones(2, bool), src_bits=8)


def test_choose_src_bits_thresholds():
    assert choose_src_bits(SRC16_LIMIT, 8) == 16
    assert choose_src_bits(SRC16_LIMIT + 1, 8) == 32
    assert choose_src_bits(100, DSTB16_LIMIT) == 16
    assert choose_src_bits(100, DSTB16_LIMIT + 1) == 32


# ---------------------------------------------------------------------------
# Kernel-level three-way equivalence on random buckets (property-style)
# ---------------------------------------------------------------------------


def _random_cores(rng, p, v, e, g_sz, vb, eb, weighted):
    """Per-core random dst-sorted buckets -> (tiles list, packed cores stack)."""
    tiles = []
    for _ in range(p):
        dst = np.sort(rng.integers(0, v, e)).astype(np.int32)
        src = rng.integers(0, g_sz, e).astype(np.int32)
        valid = rng.random(e) < 0.8
        w = rng.random(e).astype(np.float32) if weighted else None
        tiles.append(
            prepare_tiles(src, dst, valid, num_rows=v, vb=vb, eb=eb, weights=w)
        )
    src_bits = choose_src_bits(g_sz, vb)
    word, hi, counts, weights = stack_packed_tiles(tiles, src_bits=src_bits)
    return tiles, word, hi, counts, weights, src_bits


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize(
    "kind,edge_op,weighted",
    [("min", "none", False), ("sum", "none", False),
     ("min", "add", True), ("min", "add", False)],
)
def test_kernel_three_way_random(seed, kind, edge_op, weighted):
    rng = np.random.default_rng(seed)
    p, v, e, g_sz, vb, eb = 2, 32, 300, 64, 8, 16
    identity = np.float32(np.finfo(np.float32).max) if kind == "min" else 0.0
    tiles, word, hi, counts, weights, src_bits = _random_cores(
        rng, p, v, e, g_sz, vb, eb, weighted
    )
    assert src_bits == 16
    payload = rng.random(g_sz).astype(np.float32)
    jp = jnp.asarray(payload)

    compressed = gather_reduce_cores_pallas(
        jp, jnp.asarray(word), jnp.asarray(counts),
        None if hi is None else jnp.asarray(hi),
        None if weights is None else jnp.asarray(weights),
        num_rows=v, vb=vb, src_bits=src_bits, kind=kind, edge_op=edge_op,
        identity=float(identity), interpret=True,
    )
    for i, t in enumerate(tiles):
        uncompressed = gather_reduce_pallas(
            jp, jnp.asarray(t.src), jnp.asarray(t.dstb), jnp.asarray(t.valid),
            None if t.weights is None else jnp.asarray(t.weights),
            num_rows=v, vb=vb, kind=kind, edge_op=edge_op,
            identity=float(identity), interpret=True,
        )
        # identical binning + exact identity padding => bit-identical even for sum
        np.testing.assert_array_equal(
            np.asarray(compressed[i]), np.asarray(uncompressed)
        )
        block_base = np.arange(v // vb, dtype=np.int32)[:, None, None] * vb
        ref_w = None
        if edge_op == "add":  # reference needs explicit unit weights
            ref_w = (
                jnp.asarray(t.weights).reshape(-1)
                if t.weights is not None
                else jnp.ones(t.src.size, jnp.float32)
            )
        oracle = gather_reduce_reference(
            jp,
            jnp.asarray(t.src).reshape(-1),
            jnp.asarray(t.dstb + block_base).reshape(-1),
            jnp.asarray(t.valid).reshape(-1),
            v, kind=kind, identity=float(identity),
            weights=ref_w,
        )
        if kind == "min":
            np.testing.assert_array_equal(np.asarray(compressed[i]), np.asarray(oracle))
        else:
            np.testing.assert_allclose(
                np.asarray(compressed[i]), np.asarray(oracle), rtol=1e-6, atol=1e-9
            )


def test_kernel_32bit_src_beyond_16bit_range(rng):
    """Real 32-bit-regime run whose src offsets genuinely exceed 2^16 — the
    fallback must address the full gathered block."""
    g_sz, p, v, e, vb, eb = 70_000, 1, 16, 64, 8, 8
    dst = np.sort(rng.integers(0, v, e)).astype(np.int32)
    src = rng.integers(0, g_sz, e).astype(np.int32)
    src[0] = g_sz - 1  # force an offset that cannot fit 16 bits
    valid = np.ones(e, bool)
    tiles = prepare_tiles(src, dst, valid, num_rows=v, vb=vb, eb=eb)
    src_bits = choose_src_bits(g_sz, vb)
    assert src_bits == 32
    word, hi = pack_edge_words(tiles.src, tiles.dstb, tiles.valid, src_bits=32)
    payload = rng.random(g_sz).astype(np.float32)
    out = gather_reduce_cores_pallas(
        jnp.asarray(payload),
        jnp.asarray(word[None]),
        jnp.asarray(tiles.tile_counts[None]),
        jnp.asarray(hi[None]),
        None,
        num_rows=v, vb=vb, src_bits=32, kind="min",
        identity=float(np.finfo(np.float32).max), interpret=True,
    )
    block_base = np.arange(v // vb, dtype=np.int32)[:, None, None] * vb
    oracle = gather_reduce_reference(
        jnp.asarray(payload),
        jnp.asarray(tiles.src).reshape(-1),
        jnp.asarray(tiles.dstb + block_base).reshape(-1),
        jnp.asarray(tiles.valid).reshape(-1),
        v, kind="min", identity=float(np.finfo(np.float32).max),
    )
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(oracle))


# ---------------------------------------------------------------------------
# Engine-level three-way equivalence across the four problems
# ---------------------------------------------------------------------------


def _make_case(pname, rng):
    if pname == "sssp":
        g0 = G.rmat(8, 6, seed=11)
        w = rng.random(g0.num_edges).astype(np.float32)
        g = G.COOGraph(src=g0.src, dst=g0.dst, num_vertices=g0.num_vertices, weights=w)
        return sssp(1), g
    if pname == "pagerank":
        return pagerank(), G.rmat(8, 6, seed=12)
    g = G.symmetrize(G.rmat(8, 6, seed=13))
    return (bfs(3), g) if pname == "bfs" else (wcc(), g)


@pytest.mark.parametrize("pname", PROBLEMS)
@pytest.mark.parametrize("force_bits", [None, 32])
def test_engine_three_way(pname, force_bits, rng):
    """Full engine runs (compressed-Pallas vs XLA oracle) plus a per-phase
    sweep against the uncompressed-Pallas kernel, in both packing regimes
    (auto-16 and forced-32 fallback)."""
    prob, g = _make_case(pname, rng)
    pg = partition_2d(g, PartitionConfig(p=2, l=2, lane=4, pack_src_bits=force_bits))
    assert pg.src_bits == (force_bits or 16)
    assert (pg.tile_word_hi is not None) == (pg.src_bits == 32)

    res_p = run(prob, g, pg, EngineOptions(backend="pallas"))
    res_x = run(prob, g, pg, EngineOptions(backend="xla"))
    assert res_p.iterations == res_x.iterations
    if prob.reduce_kind == "min":
        np.testing.assert_array_equal(res_p.labels["label"], res_x.labels["label"])
    else:
        np.testing.assert_allclose(
            res_p.labels["label"], res_x.labels["label"], rtol=1e-6, atol=1e-9
        )

    # per-phase: compressed cores stream vs uncompressed per-bucket tiles on
    # the INITIAL labels (any fixed payload works — the kernels are pure)
    labels = prepare_labels(prob, g, pg)
    payload = np.asarray(prob.src_transform(labels))
    eb = pg.tile_word.shape[-1]
    for m in range(pg.l):
        gathered = jnp.asarray(
            payload[:, m * pg.sub_size : (m + 1) * pg.sub_size].reshape(-1)
        )
        w_m = (
            jnp.asarray(pg.tile_weights[:, m])
            if prob.edge_op == "add" and pg.tile_weights is not None
            else None
        )
        compressed = gather_reduce_cores_pallas(
            gathered,
            jnp.asarray(pg.tile_word[:, m]),
            jnp.asarray(pg.tile_counts[:, m]),
            jnp.asarray(pg.tile_word_hi[:, m]) if pg.tile_word_hi is not None else None,
            w_m,
            num_rows=pg.vertices_per_core, vb=pg.tile_vb, src_bits=pg.src_bits,
            kind=prob.reduce_kind, edge_op=prob.edge_op,
            identity=prob.identity, interpret=True,
        )
        for i in range(pg.p):
            tiles = prepare_tiles(
                pg.src_gidx[i, m], pg.dst_lidx[i, m], pg.valid[i, m],
                num_rows=pg.vertices_per_core, vb=pg.tile_vb, eb=eb,
                weights=pg.weights[i, m] if pg.weights is not None else None,
                balance_rows=True,
            )
            uncompressed = gather_reduce_pallas(
                gathered,
                jnp.asarray(tiles.src), jnp.asarray(tiles.dstb),
                jnp.asarray(tiles.valid),
                jnp.asarray(tiles.weights)
                if tiles.weights is not None and prob.edge_op == "add"
                else None,
                num_rows=pg.vertices_per_core, vb=pg.tile_vb,
                kind=prob.reduce_kind, edge_op=prob.edge_op,
                identity=prob.identity, interpret=True,
            )
            np.testing.assert_array_equal(
                np.asarray(compressed[i]), np.asarray(uncompressed)
            )


def _expected_stream_bpe(pg):
    """Mirror of the accounting contract: pull packed words plus the push
    stream's packed words amortized over pull edge slots."""
    pull = 4.0 * (1 if pg.tile_word_hi is None else 2)
    if pg.push_word is None:
        return pull
    push = 4.0 * (1 if pg.push_word_hi is None else 2)
    return pull + push * pg.push_word.size / pg.tile_word.size


def test_partition_auto_selects_32bit_fallback():
    """p * sub_size > 2^16 flips the regime without being asked to."""
    g = G.rmat(17, 1, seed=3)  # 131072 vertices
    pg = partition_2d(g, PartitionConfig(p=2, l=1))  # gathered block = 131072
    assert pg.gathered_size > SRC16_LIMIT
    assert pg.src_bits == 32
    assert pg.tile_word_hi is not None
    # push stream built by default: bytes/edge = pull 8.0 + amortized push
    assert pg.push_word is not None
    assert pg.stream_bytes_per_edge == _expected_stream_bpe(pg)
    assert pg.stream_bytes_per_edge > 8.0
    # opting out of the push layout restores the exact pull-only figure
    pg_pull = partition_2d(g, PartitionConfig(p=2, l=1, build_push=False))
    assert pg_pull.push_word is None
    assert pg_pull.stream_bytes_per_edge == 8.0


def test_stream_metrics_16bit_regime():
    g = G.symmetrize(G.rmat(9, 8, seed=5))
    pg = partition_2d(g, PartitionConfig(p=4, l=4, lane=8))
    assert pg.src_bits == 16 and pg.tile_word_hi is None
    assert pg.stream_bytes_per_edge == _expected_stream_bpe(pg)
    assert 0.0 <= pg.skipped_tile_fraction < 1.0
    # counts never exceed the uniform T the stream was padded to
    assert int(pg.tile_counts.max()) <= pg.tile_word.shape[3]
    pg_pull = partition_2d(g, PartitionConfig(p=4, l=4, lane=8,
                                              build_push=False))
    assert pg_pull.stream_bytes_per_edge == 4.0
    # push coverage words are charged to the coverage overhead metric
    assert pg.coverage_bytes_per_edge > pg_pull.coverage_bytes_per_edge
