"""Multi-device tests. jax locks device count at first init, so every case
runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
(tests themselves keep the single real device)."""
import subprocess
import sys
import textwrap

import pytest

FLAGS = "--xla_force_host_platform_device_count=8"

# these tests exercise repro.dist inside their subprocess snippets; the
# conftest marker is a no-op while the package is importable
from conftest import requires_dist  # noqa: F401


def run_sub(code: str):
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        # JAX_PLATFORMS=cpu: the container ships libtpu; without the pin the
        # subprocess probes the (absent) TPU and collectives can hang
        env={"XLA_FLAGS": FLAGS, "PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
        timeout=900,
    )
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return res.stdout


PRELUDE = """
import numpy as np, jax, jax.numpy as jnp
import repro.dist  # installs the jax>=0.6 shard_map/make_mesh/AxisType shims on 0.4.x
from jax.sharding import PartitionSpec as P, NamedSharding
mesh4 = jax.make_mesh((4,), ("graph",), axis_types=(jax.sharding.AxisType.Auto,))
"""


def test_distributed_engine_matches_single_process():
    run_sub(
        PRELUDE
        + """
from repro.core import graph as G
from repro.core.partition import PartitionConfig, partition_2d
from repro.core.problems import bfs, pagerank, wcc
from repro.core.engine import EngineOptions, run
from repro.core.distributed import run_distributed
from repro.core.reference import bfs_reference, pagerank_reference, wcc_reference

g = G.symmetrize(G.rmat(10, 8, seed=3))
pg = partition_2d(g, PartitionConfig(p=4, l=2, lane=4, stride=100))
res = run_distributed(bfs(7), g, pg, mesh4)
assert np.array_equal(res.labels["label"], bfs_reference(g, 7))
single = run(bfs(7), g, pg, EngineOptions())
assert res.iterations == single.iterations  # bit-identical engine semantics
res_w = run_distributed(wcc(), g, pg, mesh4)
assert np.array_equal(res_w.labels["label"], wcc_reference(G.rmat(10, 8, seed=3)))
gd = G.rmat(10, 8, seed=3)
pgd = partition_2d(gd, PartitionConfig(p=4, l=2, lane=4))
res_p = run_distributed(pagerank(), gd, pgd, mesh4)
assert np.allclose(res_p.labels["label"], pagerank_reference(gd), atol=1e-4)
print("OK")
"""
    )


@requires_dist
def test_crossbar_embedding_lookup():
    run_sub(
        PRELUDE
        + """
mesh = jax.make_mesh((2, 4), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
from repro.dist.embedding import make_crossbar_lookup
rng = np.random.default_rng(0)
table = rng.random((64, 8), np.float32)
ids = rng.integers(-1, 64, (16, 5)).astype(np.int32)
lookup = make_crossbar_lookup(mesh, table_axis="model", batch_axes=("data",), capacity_factor=4.0)
tbl = jax.device_put(table, NamedSharding(mesh, P("model", None)))
idd = jax.device_put(ids, NamedSharding(mesh, P("data", None)))
out = jax.jit(lookup)(tbl, idd)
ref = np.where(ids[..., None] >= 0, table[np.maximum(ids, 0)], 0.0)
np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-6)
print("OK")
"""
    )


@requires_dist
def test_compressed_psum_dp_training_converges():
    """Pure-DP shard_map training with int8 error-feedback gradient
    compression across the (slow) axis still converges on a toy problem."""
    run_sub(
        PRELUDE
        + """
from repro.dist.compression import compressed_psum, make_error_feedback
rng = np.random.default_rng(0)
X = rng.standard_normal((64, 8)).astype(np.float32)
w_true = rng.standard_normal((8,)).astype(np.float32)
y = X @ w_true
Xs = jax.device_put(X, NamedSharding(mesh4, P("graph", None)))
ys = jax.device_put(y, NamedSharding(mesh4, P("graph")))
init_ef, apply_ef = make_error_feedback(mode="int8")

def local_step(w, ef, xb, yb):
    def loss(w):
        return jnp.mean((xb @ w - yb) ** 2)
    g = jax.grad(loss)(w)
    g_synced, ef = apply_ef(g, ef, "graph")
    return w - 0.05 * g_synced, ef

step = jax.jit(jax.shard_map(
    local_step, mesh=mesh4,
    in_specs=(P(), P(), P("graph", None), P("graph")),
    out_specs=(P(), P()), check_vma=False,
))
w = jnp.zeros(8)
ef = init_ef(w)
for _ in range(300):
    w, ef = step(w, ef, Xs, ys)
err = float(jnp.abs(w - w_true).max())
assert err < 0.05, err
print("OK", err)
"""
    )


@requires_dist
def test_graphscale_gnn_aggregation():
    """Distributed feature aggregation over the 2-D-partitioned crossbar
    engine equals the dense segment_sum oracle."""
    run_sub(
        PRELUDE
        + """
from repro.core import graph as G
from repro.core.partition import PartitionConfig, partition_2d
from repro.dist.gnn_parallel import make_graphscale_aggregate, shard_features

g = G.symmetrize(G.rmat(9, 6, seed=1))
pg = partition_2d(g, PartitionConfig(p=4, l=3, lane=4, stride=50))
rng = np.random.default_rng(0)
feat = rng.standard_normal((g.num_vertices, 8)).astype(np.float32)
sharded = shard_features(feat, pg, mesh4)
agg = jax.jit(make_graphscale_aggregate(pg, mesh4))(sharded)
out = np.asarray(agg).reshape(-1, 8)
# undo stride permutation
res = out[pg.perm[:g.num_vertices]] if pg.perm is not None else out[:g.num_vertices]
ref = np.zeros_like(feat)
np.add.at(ref, g.dst, feat[g.src])
np.testing.assert_allclose(res, ref, rtol=1e-5, atol=1e-5)
print("OK")
"""
    )


@requires_dist
def test_crossbar_property_random_routing():
    """Hypothesis-style randomized crossbar check in one subprocess: random
    table sizes, id distributions (uniform/skewed/padding-heavy), and
    capacities — served ids match the oracle, over-capacity ids return zero
    rows and are counted."""
    run_sub(
        PRELUDE
        + """
from repro.dist.embedding import crossbar_lookup_local
from jax.sharding import PartitionSpec as P
rng = np.random.default_rng(7)
mesh = jax.make_mesh((4,), ("x",), axis_types=(jax.sharding.AxisType.Auto,))
for trial in range(12):
    rows = int(rng.integers(2, 17)) * 4     # divisible by 4 shards
    d = int(rng.integers(1, 9))
    n = int(rng.integers(1, 65))
    cap = int(rng.integers(1, 33))
    table = rng.random((rows, d), np.float32)
    kind = trial % 3
    if kind == 0:
        ids = rng.integers(-1, rows, (4 * n,)).astype(np.int32)
    elif kind == 1:  # skew: hammer one shard (tests capacity overflow)
        ids = rng.integers(0, max(rows // 4, 1), (4 * n,)).astype(np.int32)
    else:  # all padding
        ids = np.full((4 * n,), -1, np.int32)

    def body(tbl, idl):
        got, dropped = crossbar_lookup_local(tbl, idl, "x", 4, cap)
        return got, dropped[None]

    fn = jax.shard_map(body, mesh=mesh, in_specs=(P("x", None), P("x")),
                       out_specs=(P("x", None), P("x")), check_vma=False)
    tbl = jax.device_put(table, jax.NamedSharding(mesh, P("x", None)))
    idd = jax.device_put(ids, jax.NamedSharding(mesh, P("x")))
    got, dropped = jax.jit(fn)(tbl, idd)
    got = np.asarray(got)
    ref = np.where(ids[:, None] >= 0, table[np.maximum(ids, 0)], 0.0)
    # each returned row is either the oracle row (served) or zeros (dropped)
    served = np.abs(got - ref).max(axis=1) < 1e-6
    zeroed = np.abs(got).max(axis=1) < 1e-12
    assert np.all(served | zeroed), f"trial {trial}: row neither served nor zero"
    n_drop = int(np.asarray(dropped).sum())
    n_unserved = int((~served & (ids >= 0)).sum())
    assert n_unserved <= n_drop, (trial, n_unserved, n_drop)
    if kind == 0 and cap >= n:  # uniform ids under capacity: all served
        np.testing.assert_allclose(got, ref, rtol=1e-6)
print("OK")
"""
    )


def test_frontier_compressed_engine_matches_dense():
    """Beyond-paper frontier exchange (docs/distributed.md §5): identical fixed point
    to the dense crossbar, wire reduction on high-diameter graphs, safe
    fallback on expansion-heavy graphs."""
    run_sub(
        PRELUDE
        + """
import repro.core.graph as G
from repro.core.partition import PartitionConfig, partition_2d
from repro.core.problems import bfs
from repro.core.frontier import run_distributed_frontier
from repro.core.reference import bfs_reference
from repro.launch.mesh import make_graph_mesh
mesh = make_graph_mesh(8)
g = G.grid_2d(80, 60)
pg = partition_2d(g, PartitionConfig(p=8, l=2, lane=8, stride=100))
res, stats = run_distributed_frontier(bfs(3), g, pg, mesh, budget=64)
assert np.array_equal(res.labels["label"], bfs_reference(g, 3))
assert stats["sparse_phases"] > 0
g2 = G.symmetrize(G.rmat(10, 8, seed=1))
pg2 = partition_2d(g2, PartitionConfig(p=8, l=2, lane=8))
res2, stats2 = run_distributed_frontier(bfs(5), g2, pg2, mesh, budget=64)
assert np.array_equal(res2.labels["label"], bfs_reference(g2, 5))
print("OK", stats["reduction"], stats2["reduction"])
"""
    )


@requires_dist
def test_gat_graphscale_matches_dense_reference():
    """GAT on the paper's dst-partitioned layout (hillclimb cell C) equals
    the dense single-device GAT bit-for-bit (within f32 tolerance)."""
    run_sub(
        PRELUDE
        + """
import repro.core.graph as G
from repro.core.partition import PartitionConfig, partition_2d
from repro.dist.gat_parallel import make_gat_graphscale_loss
from repro.dist.gnn_parallel import shard_features
from repro.models.gnn import archs as gnn
from repro.models.gnn.common import GraphBatch
from repro.train.losses import masked_softmax_xent

g = G.symmetrize(G.rmat(8, 6, seed=3))
pg = partition_2d(g, PartitionConfig(p=4, l=1, lane=4))
rng = np.random.default_rng(0)
F, H, HD, OUT = 12, 4, 4, 5
cfg = gnn.GNNConfig(name="gat", n_layers=2, d_hidden=HD, n_heads=H)
params = gnn.init(jax.random.key(0), cfg, F, OUT)
feat = rng.standard_normal((g.num_vertices, F)).astype(np.float32)
labels = rng.integers(0, OUT, g.num_vertices).astype(np.int32)
batch = GraphBatch(node_feat=jnp.asarray(feat), edge_src=jnp.asarray(g.src.astype(np.int32)),
                   edge_dst=jnp.asarray(g.dst.astype(np.int32)),
                   node_mask=jnp.ones(g.num_vertices, bool), edge_mask=jnp.ones(g.num_edges, bool),
                   graph_id=jnp.zeros(g.num_vertices, jnp.int32), n_graphs=1)
ref_loss = masked_softmax_xent(gnn.apply(params, batch, cfg), jnp.asarray(labels),
                               jnp.ones(g.num_vertices))
feat_sh = shard_features(feat, pg, mesh4)
lab_pad = np.zeros(pg.padded_vertices, np.int32); lab_pad[:g.num_vertices] = labels
mask_pad = np.zeros(pg.padded_vertices, np.float32); mask_pad[:g.num_vertices] = 1.0
lab_sh = jax.device_put(lab_pad, NamedSharding(mesh4, P("graph")))
mask_sh = jax.device_put(mask_pad, NamedSharding(mesh4, P("graph")))
loss_fn = make_gat_graphscale_loss(mesh4, ("graph",), pg.vertices_per_core, H, HD)
sg, dl, vm = map(jnp.asarray, (pg.src_gidx, pg.dst_lidx, pg.valid))
loss = jax.jit(loss_fn)(params, feat_sh, sg, dl, vm, lab_sh, mask_sh)
np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-5)
gr = jax.jit(jax.grad(loss_fn))(params, feat_sh, sg, dl, vm, lab_sh, mask_sh)
tot = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(gr))
assert np.isfinite(tot) and tot > 0
print("OK")
"""
    )


@requires_dist
def test_crossbar_full_mesh_lookup():
    """Full two-level crossbar: table rows sharded over the WHOLE mesh
    (hillclimb cell B it2) matches plain gather."""
    run_sub(
        PRELUDE
        + """
mesh = jax.make_mesh((2, 4), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
from repro.dist.embedding import make_crossbar_lookup
rng = np.random.default_rng(1)
table = rng.random((64, 6), np.float32)  # 64 rows over all 8 devices
ids = rng.integers(-1, 64, (16, 3)).astype(np.int32)
lookup = make_crossbar_lookup(mesh, table_axis=("data", "model"),
                              batch_axes=("data", "model"), capacity_factor=4.0)
tbl = jax.device_put(table, NamedSharding(mesh, P(("data", "model"), None)))
idd = jax.device_put(ids.reshape(-1, 3), NamedSharding(mesh, P(("data", "model"), None)))
out = jax.jit(lookup)(tbl, idd)
ref = np.where(ids[..., None] >= 0, table[np.maximum(ids, 0)], 0.0)
np.testing.assert_allclose(np.asarray(out), ref.reshape(-1, 3, 6)[: out.shape[0]], rtol=1e-6)
# gradient path: table grads stay correct through the double all_to_all
def loss(tbl):
    return (lookup(tbl, idd) ** 2).sum()
g = jax.jit(jax.grad(loss))(tbl)
ref_g = np.zeros_like(table)
rows = np.maximum(ids, 0)
vals = np.where(ids[..., None] >= 0, table[rows], 0.0)
np.add.at(ref_g, rows.reshape(-1), 2 * vals.reshape(-1, 6) * (ids.reshape(-1) >= 0)[:, None])
np.testing.assert_allclose(np.asarray(g), ref_g, rtol=1e-5, atol=1e-6)
print("OK")
"""
    )


@requires_dist
def test_lm_sharded_train_step_runs():
    """A reduced LM train step executes (not just compiles) on a 2x4 mesh
    with the production sharding rules."""
    run_sub(
        """
import numpy as np, jax, jax.numpy as jnp
import repro.dist  # installs the jax>=0.6 API shims on 0.4.x
from jax.sharding import PartitionSpec as P, NamedSharding
import dataclasses
mesh = jax.make_mesh((2, 4), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
from repro.models.transformer import LMConfig, init_params
from repro.dist import sharding as shd
from repro.train.optim import AdamWConfig
from repro.train.steps import init_train_state, make_lm_train_step
cfg = LMConfig(name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
               vocab=128, qk_norm=True, dtype=jnp.float32, attn_chunk=16)
r = shd.rules_for_mesh(mesh)
cfg = dataclasses.replace(cfg,
    act_sharding=NamedSharding(mesh, P("data", None, None)),
    logit_sharding=NamedSharding(mesh, P("data", None, "model")),
    attn_sharding=NamedSharding(mesh, P("data", "model", None, None)))
ocfg = AdamWConfig(lr=1e-3, total_steps=10)
params = init_params(jax.random.key(0), cfg)
state = init_train_state(params, ocfg)
sspecs = shd.state_specs(shd.lm_param_specs(r, cfg))
state = jax.device_put(state, jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs,
                       is_leaf=lambda x: isinstance(x, P)))
batch = {"tokens": jnp.zeros((8, 32), jnp.int32), "labels": jnp.zeros((8, 32), jnp.int32)}
batch = jax.device_put(batch, NamedSharding(mesh, P("data", None)))
step = jax.jit(make_lm_train_step(cfg, ocfg), donate_argnums=0)
with mesh:
    state, m = step(state, batch)
    state, m = step(state, batch)
assert np.isfinite(float(m["loss"]))
print("OK", float(m["loss"]))
"""
    )
