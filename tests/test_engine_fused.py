"""Fused-path equivalence suite: the engine's Pallas (interpret) backend vs
the XLA oracle across all four problems x update schemes x partition shapes.

Min problems (BFS/WCC/SSSP) must be BIT-IDENTICAL: gather, saturating add,
and min-reduce are order-independent, so any divergence is a real bug.
PageRank (sum reduce) is checked to tight tolerance plus identical iteration
counts — the fused kernel reduces per (row-block, tile) while the oracle
segment-sums the flat edge list, so float summation order differs by design.

Also proves the bandwidth claim structurally: the jaxpr of a fused iteration
contains NO (p, E_pad) intermediate (the materialize-then-reduce array the
XLA path builds) and no decompressed full-size edge-index arrays — the only
full-size per-edge intermediate is the bit-packed word stream — while the
oracle's jaxpr keeps the (p, E_pad) array. See test_compressed_stream.py for
the word-format and three-way kernel equivalence suite.
"""
import numpy as np
import pytest

import jax

import repro.core.graph as G
from repro.core.engine import EngineOptions, _make_iteration, prepare_labels, run
from repro.core.partition import PartitionConfig, partition_2d
from repro.core.problems import bfs, pagerank, sssp, wcc

PROBLEMS = ["bfs", "wcc", "sssp", "pagerank"]


def _make_case(pname, rng):
    """(problem, graph) pairs sized for interpret-mode grids."""
    if pname == "sssp":
        g0 = G.rmat(8, 6, seed=11)
        w = rng.random(g0.num_edges).astype(np.float32)
        g = G.COOGraph(src=g0.src, dst=g0.dst, num_vertices=g0.num_vertices, weights=w)
        return sssp(1), g
    if pname == "pagerank":
        return pagerank(), G.rmat(8, 6, seed=12)
    g = G.symmetrize(G.rmat(8, 6, seed=13))
    return (bfs(3), g) if pname == "bfs" else (wcc(), g)


@pytest.mark.parametrize("pname", PROBLEMS)
@pytest.mark.parametrize("immediate", [True, False])
@pytest.mark.parametrize("p", [1, 2, 4])
@pytest.mark.parametrize("l", [1, 3])
def test_fused_matches_xla(pname, immediate, p, l, rng):
    prob, g = _make_case(pname, rng)
    pg = partition_2d(g, PartitionConfig(p=p, l=l, lane=4))
    res_x = run(prob, g, pg, EngineOptions(immediate_updates=immediate, backend="xla"))
    res_p = run(prob, g, pg, EngineOptions(immediate_updates=immediate, backend="pallas"))
    assert res_p.iterations == res_x.iterations
    assert res_p.converged == res_x.converged
    if prob.reduce_kind == "min":
        np.testing.assert_array_equal(res_p.labels["label"], res_x.labels["label"])
    else:
        np.testing.assert_allclose(
            res_p.labels["label"], res_x.labels["label"], rtol=1e-6, atol=1e-9
        )


@pytest.mark.parametrize("stride", [None, 7])
def test_fused_matches_xla_with_stride_and_packing_off(stride, rng):
    """Degree-aware packing and stride mapping are layout choices — results
    must not change."""
    g = G.symmetrize(G.rmat(8, 6, seed=21))
    for packing in (True, False):
        pg = partition_2d(
            g,
            PartitionConfig(p=2, l=2, lane=4, stride=stride, degree_aware_tiles=packing),
        )
        a = run(bfs(0), g, pg, EngineOptions(backend="pallas"))
        b = run(bfs(0), g, pg, EngineOptions(backend="xla"))
        np.testing.assert_array_equal(a.labels["label"], b.labels["label"])
        assert a.iterations == b.iterations


def _iteration_avals(problem, g, pg, backend):
    """(shape, dtype-name) of every intermediate in one traced iteration,
    including sub-jaxprs (fori_loop bodies, pallas_call kernels)."""
    labels = prepare_labels(problem, g, pg)
    opts = EngineOptions(backend=backend)
    iteration = _make_iteration(problem, pg, opts)
    jaxpr = jax.make_jaxpr(iteration)(labels)

    avals = []

    def walk(jp):
        for eqn in jp.eqns:
            for v in eqn.outvars:
                if hasattr(v, "aval") and hasattr(v.aval, "shape"):
                    avals.append(
                        (tuple(v.aval.shape), str(getattr(v.aval, "dtype", "")))
                    )
            for sub in jax.core.jaxprs_in_params(eqn.params):
                walk(sub.jaxpr if hasattr(sub, "jaxpr") else sub)

    walk(jaxpr.jaxpr)
    return avals


def test_fused_path_materializes_no_contributions_array():
    """Bandwidth property, checked structurally: a fused iteration's jaxpr has
    no (p, E_pad) intermediate (the materialize-then-reduce array the XLA path
    builds) and no decompressed full-size edge-index array — the only
    (p, R, T, Eb) int32 intermediate is the packed word stream itself, and no
    (p, R, T, Eb) bool valid mask exists at all. The oracle's jaxpr keeps the
    (p, E_pad) array (positive control, so the check cannot rot into
    vacuity)."""
    g = G.symmetrize(G.rmat(9, 8, seed=5))
    pg = partition_2d(g, PartitionConfig(p=2, l=2, lane=4))
    contrib_shape = (pg.p, pg.edge_pad)
    fused = _iteration_avals(bfs(0), g, pg, "pallas")
    oracle = _iteration_avals(bfs(0), g, pg, "xla")
    assert contrib_shape not in {s for s, _ in fused}
    assert contrib_shape in {s for s, _ in oracle}

    # compressed-stream property: exactly ONE full-size (p, R, T, Eb) int32
    # intermediate (the phase-sliced packed word) — an unpacked src/dstb pair
    # would add more — and no full-size bool valid array anywhere.
    tile_shape = (pg.p,) + pg.tile_word.shape[2:]
    int32_tiles = [d for s, d in fused if s == tile_shape and d == "int32"]
    bool_tiles = [d for s, d in fused if s == tile_shape and d == "bool"]
    assert len(int32_tiles) == 1, int32_tiles
    assert not bool_tiles


def test_fused_kernel_runs_all_cores_in_one_launch():
    """One pallas_call (or interpreter equivalent) per phase covers all p
    cores: the stacked tile arrays carry the core dimension."""
    g = G.symmetrize(G.rmat(8, 6, seed=6))
    pg = partition_2d(g, PartitionConfig(p=4, l=2, lane=4))
    assert pg.tile_word.shape[:2] == (4, 2)
    assert pg.tile_counts.shape == pg.tile_word.shape[:3]
    assert pg.tile_vb > 0 and pg.vertices_per_core % pg.tile_vb == 0


def test_degree_aware_packing_reduces_tile_padding():
    """LPT row packing must never do worse than natural row order, and on a
    skew-clustered graph (R-MAT low-id hubs) it must do strictly better."""
    g = G.symmetrize(G.rmat(12, 8, seed=2))
    cfg = dict(p=4, l=2, lane=4, tile_vb=32)
    packed = partition_2d(g, PartitionConfig(**cfg, degree_aware_tiles=True))
    plain = partition_2d(g, PartitionConfig(**cfg, degree_aware_tiles=False))
    assert packed.tile_word.shape[3] < plain.tile_word.shape[3]  # T shrinks
    assert packed.tile_padding_ratio < plain.tile_padding_ratio


def test_row_pos_is_a_permutation():
    g = G.symmetrize(G.rmat(9, 6, seed=7))
    pg = partition_2d(g, PartitionConfig(p=2, l=2, lane=4, tile_vb=16))
    assert pg.tile_row_pos is not None
    vpc = pg.vertices_per_core
    for i in range(pg.p):
        for m in range(pg.l):
            assert sorted(pg.tile_row_pos[i, m].tolist()) == list(range(vpc))


def test_sssp_unit_weights_without_weight_array(rng):
    """edge_op='add' on an unweighted graph: the fused path synthesizes unit
    weights and must match the oracle (which adds 1.0 in edge_map)."""
    g = G.symmetrize(G.rmat(8, 6, seed=8))
    pg = partition_2d(g, PartitionConfig(p=2, l=2, lane=4))
    a = run(sssp(0), g, pg, EngineOptions(backend="pallas"))
    b = run(sssp(0), g, pg, EngineOptions(backend="xla"))
    np.testing.assert_array_equal(a.labels["label"], b.labels["label"])
    assert a.iterations == b.iterations
