"""Shared fixtures + collection guards. NOTE: no XLA_FLAGS here — tests run
on the single real CPU device; multi-device tests spawn subprocesses with
their own flags."""
import importlib.util
import sys

import numpy as np
import pytest

# ``hypothesis`` may be absent (the container cannot pip-install); register a
# deterministic fallback BEFORE test modules import it. requirements-dev.txt
# installs the real thing where possible.
if importlib.util.find_spec("hypothesis") is None:
    import pathlib

    _spec = importlib.util.spec_from_file_location(
        "hypothesis", pathlib.Path(__file__).parent / "_hypothesis_fallback.py"
    )
    _mod = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_mod)
    sys.modules.setdefault("hypothesis", _mod)

import repro.core.graph as G

# ``repro.dist`` landed in PR 5 (ISSUE 5); the guard stays so a broken or
# partially-checked-out tree degrades to skips instead of collection errors.
# Tests that reach for it at runtime (subprocess snippets, launch/cells)
# import ``requires_dist`` from this conftest — a no-op while the package
# imports cleanly.
HAS_DIST = importlib.util.find_spec("repro.dist") is not None
collect_ignore = []
if not HAS_DIST:
    collect_ignore += ["test_fault_tolerance.py", "test_elastic.py"]

requires_dist = pytest.mark.skipif(
    not HAS_DIST, reason="repro.dist not yet implemented (see ROADMAP.md Open items)"
)


def pytest_report_header(config):
    if not HAS_DIST:
        return (
            "repro.dist missing: ignoring test_fault_tolerance.py / "
            "test_elastic.py, skipping dist-dependent tests"
        )
    return None


@pytest.fixture(scope="session")
def small_graphs():
    return {
        "karate": G.karate_club(),
        "rmat10": G.rmat(10, 8, seed=1),
        "grid": G.grid_2d(13, 17),
        "star": G.star(64),
        "chain": G.chain(40),
    }


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
