"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the single real
CPU device; multi-device tests spawn subprocesses with their own flags."""
import numpy as np
import pytest

import repro.core.graph as G


@pytest.fixture(scope="session")
def small_graphs():
    return {
        "karate": G.karate_club(),
        "rmat10": G.rmat(10, 8, seed=1),
        "grid": G.grid_2d(13, 17),
        "star": G.star(64),
        "chain": G.chain(40),
    }


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
