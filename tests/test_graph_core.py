"""Unit + property tests for the GraphScale core: graph structures, the 2-D
partitioner, and both engines vs pure-numpy oracles."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.core.graph as G
from repro.core.edge_centric import run_edge_centric
from repro.core.engine import EngineOptions, run
from repro.core.partition import (
    PartitionConfig,
    partition_2d,
    partition_edge_centric,
    stride_permutation,
)
from repro.core.problems import INF_U32, bfs, pagerank, sssp, wcc
from repro.core.reference import (
    bfs_reference,
    pagerank_reference,
    sssp_reference,
    wcc_reference,
)


# ---------------------------------------------------------------------------
# graph structures
# ---------------------------------------------------------------------------


def test_coo_csr_roundtrip(small_graphs):
    g = small_graphs["rmat10"]
    csr = G.coo_to_csr(g)
    back = G.csr_to_coo(csr)
    orig = set(zip(g.src.tolist(), g.dst.tolist()))
    rt = set(zip(back.src.tolist(), back.dst.tolist()))
    assert orig == rt


def test_symmetrize_contains_both_directions(small_graphs):
    g = small_graphs["karate"]
    u = G.symmetrize(g)
    es = set(zip(u.src.tolist(), u.dst.tolist()))
    for s, d in zip(g.src.tolist(), g.dst.tolist()):
        assert (s, d) in es and (d, s) in es


def test_bytes_per_edge_csr_smaller_for_dense():
    dense = G.rmat(10, 32, seed=0)  # avg degree >> 1
    assert G.bytes_per_edge(dense, compressed=True) < G.bytes_per_edge(
        dense, compressed=False
    )


def test_rmat_properties():
    g = G.rmat(12, 16, seed=3)
    assert g.num_vertices == 4096
    deg = G.out_degrees(g)
    # R-MAT is skewed: max degree far above mean
    assert deg.max() > 8 * deg.mean()


# ---------------------------------------------------------------------------
# partitioner
# ---------------------------------------------------------------------------


def test_stride_permutation_is_permutation():
    perm = stride_permutation(1000, 100)
    assert sorted(perm.tolist()) == list(range(1000))
    # first vertices of the new order are v0, v100, v200, ...
    inv = np.argsort(perm)
    assert inv[0] == 0 and inv[1] == 100 and inv[2] == 200


@pytest.mark.parametrize("p,l", [(1, 1), (2, 3), (4, 2), (8, 1)])
def test_partition_preserves_all_edges(small_graphs, p, l):
    g = small_graphs["rmat10"]
    pg = partition_2d(g, PartitionConfig(p=p, l=l, lane=4))
    assert int(pg.bucket_sizes.sum()) == g.num_edges
    assert pg.valid.sum() == g.num_edges
    # every edge's rewritten indices decode back to the original edge set
    vpc, sub = pg.vertices_per_core, pg.sub_size
    seen = set()
    for i in range(p):
        for m in range(l):
            v = pg.valid[i, m]
            gidx = pg.src_gidx[i, m][v]
            lidx = pg.dst_lidx[i, m][v]
            src_core = gidx // sub
            src = src_core * vpc + m * sub + (gidx % sub)
            dst = i * vpc + lidx
            seen.update(zip(src.tolist(), dst.tolist()))
    assert seen == set(zip(g.src.tolist(), g.dst.tolist()))


def test_partition_dst_sorted_within_bucket(small_graphs):
    g = small_graphs["rmat10"]
    pg = partition_2d(g, PartitionConfig(p=2, l=2, lane=4))
    for i in range(2):
        for m in range(2):
            d = pg.dst_lidx[i, m]
            assert (np.diff(d) >= 0).all()  # padding rows at vpc-1 keep order


def test_stride_mapping_improves_balance():
    g = G.star(2000)  # all edges hit one interval without shuffling
    pg_plain = partition_2d(G.symmetrize(g), PartitionConfig(p=4, l=2, lane=4))
    pg_stride = partition_2d(
        G.symmetrize(g), PartitionConfig(p=4, l=2, lane=4, stride=100)
    )
    assert pg_stride.imbalance <= pg_plain.imbalance


@given(
    n=st.integers(10, 200),
    m=st.integers(10, 400),
    p=st.sampled_from([1, 2, 4]),
    l=st.sampled_from([1, 2, 3]),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=25, deadline=None)
def test_partition_edge_conservation_property(n, m, p, l, seed):
    g = G.erdos_renyi(n, m, seed=seed)
    if g.num_edges == 0:
        return
    pg = partition_2d(g, PartitionConfig(p=p, l=l, lane=2, edge_pad=4))
    assert int(pg.bucket_sizes.sum()) == g.num_edges
    assert 0.0 <= pg.padding_ratio < 1.0


# ---------------------------------------------------------------------------
# engines vs oracles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("gname,root", [("karate", 0), ("rmat10", 5), ("grid", 3)])
def test_bfs_matches_reference(small_graphs, gname, root):
    g = G.symmetrize(small_graphs[gname])
    pg = partition_2d(g, PartitionConfig(p=2, l=3, lane=4))
    res = run(bfs(root), g, pg, EngineOptions())
    assert np.array_equal(res.labels["label"], bfs_reference(g, root))
    assert res.converged


@pytest.mark.parametrize("gname", ["karate", "rmat10", "star", "chain"])
def test_wcc_matches_reference(small_graphs, gname):
    g0 = small_graphs[gname]
    g = G.symmetrize(g0)
    pg = partition_2d(g, PartitionConfig(p=2, l=2, lane=4, stride=7))
    res = run(wcc(), g, pg, EngineOptions())
    assert np.array_equal(res.labels["label"], wcc_reference(g0))


@pytest.mark.parametrize("gname", ["karate", "rmat10", "grid"])
def test_pagerank_matches_reference(small_graphs, gname):
    g = small_graphs[gname]
    pg = partition_2d(g, PartitionConfig(p=2, l=2, lane=4))
    res = run(pagerank(), g, pg, EngineOptions())
    np.testing.assert_allclose(
        res.labels["label"], pagerank_reference(g), atol=1e-4
    )


def test_sssp_matches_reference(rng):
    g0 = G.rmat(9, 8, seed=4)
    w = rng.random(g0.num_edges).astype(np.float32)
    g = G.COOGraph(src=g0.src, dst=g0.dst, num_vertices=g0.num_vertices, weights=w)
    pg = partition_2d(g, PartitionConfig(p=2, l=2, lane=4))
    res = run(sssp(1), g, pg, EngineOptions())
    ref = sssp_reference(g, 1)
    np.testing.assert_allclose(res.labels["label"], ref, rtol=1e-5)


def test_async_converges_in_fewer_or_equal_iterations(small_graphs):
    """The paper's central claim (Fig. 1 right): asynchronous update
    propagation needs no MORE iterations than synchronous."""
    for gname in ("grid", "karate", "rmat10"):
        g = G.symmetrize(small_graphs[gname])
        pg = partition_2d(g, PartitionConfig(p=2, l=4, lane=4))
        a = run(bfs(0), g, pg, EngineOptions(immediate_updates=True))
        s = run(bfs(0), g, pg, EngineOptions(immediate_updates=False))
        assert a.iterations <= s.iterations
        assert np.array_equal(a.labels["label"], s.labels["label"])


def test_edge_centric_baseline_matches(small_graphs):
    g = G.symmetrize(small_graphs["rmat10"])
    part = partition_edge_centric(g, p=4, lane=4)
    res = run_edge_centric(bfs(7), g, part)
    assert np.array_equal(res.labels["label"], bfs_reference(g, 7))


def test_edge_centric_equals_sync_iterations(small_graphs):
    """HitGraph-style engine is synchronous: same iteration count as the
    GraphScale engine with immediate updates OFF."""
    g = G.symmetrize(small_graphs["grid"])
    pg = partition_2d(g, PartitionConfig(p=2, l=2, lane=4))
    part = partition_edge_centric(g, p=2, lane=4)
    sync = run(bfs(0), g, pg, EngineOptions(immediate_updates=False))
    ec = run_edge_centric(bfs(0), g, part)
    assert sync.iterations == ec.iterations


def test_engine_backend_route_matches_xla(small_graphs):
    """EngineOptions(backend='pallas') routes the whole gather-map-reduce
    phase through the fused kernel and must match the XLA oracle exactly
    (min reduce: no float reassociation)."""
    g = G.symmetrize(small_graphs["karate"])
    pg = partition_2d(g, PartitionConfig(p=2, l=2, lane=4))
    a = run(bfs(0), g, pg, EngineOptions(backend="xla"))
    b = run(bfs(0), g, pg, EngineOptions(backend="pallas"))
    assert np.array_equal(a.labels["label"], b.labels["label"])
    assert a.iterations == b.iterations


def test_engine_options_rejects_unknown_backend():
    with pytest.raises(ValueError):
        EngineOptions(backend="tpu")


@given(
    n=st.integers(8, 120),
    m=st.integers(8, 300),
    seed=st.integers(0, 1000),
    p=st.sampled_from([1, 2, 4]),
    l=st.sampled_from([1, 2]),
    async_=st.booleans(),
    stride=st.sampled_from([None, 7, 100]),
)
@settings(max_examples=20, deadline=None)
def test_engine_bfs_property(n, m, seed, p, l, async_, stride):
    """Engine invariant: BFS fixed point is independent of partitioning,
    stride mapping, and update-propagation scheme."""
    g = G.symmetrize(G.erdos_renyi(n, m, seed=seed))
    if g.num_edges == 0:
        return
    pg = partition_2d(g, PartitionConfig(p=p, l=l, lane=2, stride=stride))
    res = run(bfs(0), g, pg, EngineOptions(immediate_updates=async_))
    assert np.array_equal(res.labels["label"], bfs_reference(g, 0))
