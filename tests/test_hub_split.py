"""Hub-row splitting (two-level reduce) suite — ISSUE 3.

Covers the split layout invariants, the LPT/prepare_tiles edge cases splitting
exposes (a row holding most of the bucket, multi-way splits bigger than the
unsplit T_max * Eb, empty buckets), equivalence of split-Pallas vs
unsplit-Pallas vs the XLA oracle across BFS/WCC/SSSP/PR, the identity-element
regression (a min-problem's split combine must fold with the problem's
identity — INF — and never inject the sum identity 0), and the
disable-switch (``split_threshold=None`` preserves the pre-split layout
byte for byte).
"""
import numpy as np
import pytest

import jax.numpy as jnp

import repro.core.graph as G
from repro.core.engine import EngineOptions, run
from repro.core.partition import PartitionConfig, partition_2d
from repro.core.problems import INF_U32, bfs, pagerank, sssp, wcc
from repro.data.synthetic import skewed_graph
from repro.kernels.csr_gather_reduce.ops import (
    combine_split_rows,
    gather_reduce,
    prepare_tiles,
    split_map_from_row_orig,
)

PROBLEMS = ["bfs", "wcc", "sssp", "pagerank"]

# sum (PR) reassociates across virtual-row chunks — tight tolerance; min
# problems must be bit-identical (same contract as the rest of the suite).
PR_TOL = dict(rtol=2e-5, atol=1e-8)


def _hub_graph(rng, n=512, hub=3, hub_deg=3000, bg=1000):
    """Multigraph with one dominant in-degree hub + uniform background."""
    src = np.concatenate([
        rng.integers(0, n, hub_deg), rng.integers(0, n, bg)
    ]).astype(np.uint32)
    dst = np.concatenate([
        np.full(hub_deg, hub, np.int64), rng.integers(0, n, bg)
    ]).astype(np.uint32)
    return G.COOGraph(src=src, dst=dst, num_vertices=n)


def _weighted(g, rng):
    w = rng.random(g.num_edges).astype(np.float32)
    return G.COOGraph(src=g.src, dst=g.dst, num_vertices=g.num_vertices, weights=w)


def _problem(pname, g, rng):
    if pname == "bfs":
        return bfs(1), g
    if pname == "wcc":
        return wcc(), g
    if pname == "sssp":
        return sssp(1), _weighted(g, rng)
    return pagerank(tol=1e-4), g


# ---------------------------------------------------------------------------
# prepare_tiles splitting edge cases
# ---------------------------------------------------------------------------


def test_split_layout_invariants():
    """Virtual rows partition every natural row's edges; row_orig covers all
    natural rows; chunk sizes respect the threshold."""
    rng = np.random.default_rng(0)
    v, e, thr = 64, 900, 40
    dst = np.sort(rng.integers(0, v, e)).astype(np.int32)
    dst[: e // 2] = 5  # hub row
    dst = np.sort(dst)
    src = rng.integers(0, 128, e).astype(np.int32)
    t = prepare_tiles(src, dst, np.ones(e, bool), num_rows=v, vb=8, eb=16,
                      balance_rows=True, split_threshold=thr)
    assert t.row_orig is not None and t.row_pos is None
    assert t.num_split_rows >= 1
    packed_rows = t.src.shape[0] * t.vb
    assert t.row_orig.shape == (packed_rows,)
    # every natural row owns >= 1 virtual row; hub owns ceil(450/40) = 12
    owned = np.bincount(t.row_orig[t.row_orig >= 0], minlength=v)
    assert owned.min() >= 1
    assert owned[5] == -(-int((dst == 5).sum()) // thr)
    # per-virtual-row edge counts never exceed the threshold
    block_rows = t.dstb + (np.arange(t.src.shape[0])[:, None, None] * t.vb)
    per_pos = np.bincount(block_rows[t.valid], minlength=packed_rows)
    assert per_pos.max() <= thr
    # edges per natural row are conserved through the split
    orig_per_pos = t.row_orig.copy()
    recon = np.zeros(v, np.int64)
    np.add.at(recon, orig_per_pos[orig_per_pos >= 0], per_pos[orig_per_pos >= 0])
    np.testing.assert_array_equal(recon, np.bincount(dst, minlength=v))
    # split_map inverts row_orig
    sm = split_map_from_row_orig(t.row_orig, v)
    assert sm.shape[0] == v and (sm[:, 0] >= 0).all()
    for row in range(v):
        np.testing.assert_array_equal(
            np.sort(sm[row][sm[row] >= 0]), np.nonzero(t.row_orig == row)[0]
        )


def test_single_row_majority_of_edges():
    """A row holding > 50% of the bucket's edges must split and T must drop
    vs the unsplit layout; reductions stay correct."""
    rng = np.random.default_rng(1)
    v, vb, eb = 32, 8, 8
    hub_e, bg_e = 600, 200
    dst = np.sort(np.concatenate([
        np.full(hub_e, 9), rng.integers(0, v, bg_e)
    ]).astype(np.int32))
    e = dst.shape[0]
    src = rng.integers(0, 64, e).astype(np.int32)
    un = prepare_tiles(src, dst, np.ones(e, bool), num_rows=v, vb=vb, eb=eb,
                       balance_rows=True)
    sp = prepare_tiles(src, dst, np.ones(e, bool), num_rows=v, vb=vb, eb=eb,
                       balance_rows=True, split_threshold=64)
    assert sp.src.shape[1] < un.src.shape[1]  # T shrinks
    assert sp.t_tiles_unsplit == un.src.shape[1]
    payload = jnp.asarray(rng.random(64).astype(np.float32))
    for kind, ident in (("min", float(np.finfo(np.float32).max)), ("sum", 0.0)):
        a = gather_reduce(payload, sp, kind=kind, identity=ident)
        b = gather_reduce(payload, un, kind=kind, identity=ident)
        if kind == "min":
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), **PR_TOL)


def test_multiway_split_row_bigger_than_tmax_eb():
    """A hub bigger than the whole rest of the bucket times T_max*Eb forces a
    many-way split; R must grow past num_rows/vb to hold the virtual rows."""
    rng = np.random.default_rng(2)
    v, vb, eb = 16, 8, 8
    hub_e = 1000
    dst = np.sort(np.concatenate([
        np.full(hub_e, 2), rng.integers(0, v, 50)
    ]).astype(np.int32))
    src = rng.integers(0, 32, dst.shape[0]).astype(np.int32)
    sp = prepare_tiles(src, dst, np.ones(dst.shape[0], bool), num_rows=v,
                       vb=vb, eb=eb, balance_rows=True, split_threshold=eb)
    n_chunks = -(-int((dst == 2).sum()) // eb)  # ~125 virtual rows, one row
    assert (sp.row_orig == 2).sum() == n_chunks
    assert sp.src.shape[0] > v // vb  # R grew
    payload = jnp.asarray(rng.random(32).astype(np.float32))
    out = gather_reduce(payload, sp, kind="min",
                        identity=float(np.finfo(np.float32).max))
    ref = prepare_tiles(src, dst, np.ones(dst.shape[0], bool), num_rows=v,
                        vb=vb, eb=eb)
    expect = gather_reduce(payload, ref, kind="min",
                           identity=float(np.finfo(np.float32).max))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_empty_bucket_and_empty_blocks():
    """Empty (core, phase) buckets and rows with zero edges survive the split
    path: counts 0, one virtual row per natural row, identity outputs."""
    t = prepare_tiles(np.zeros(0, np.int32), np.zeros(0, np.int32),
                      np.zeros(0, bool), num_rows=16, vb=4, eb=4,
                      balance_rows=True, split_threshold=2)
    assert t.row_orig is None and t.num_split_rows == 0  # nothing to split
    out = gather_reduce(jnp.ones(8, jnp.float32), t, kind="min", identity=7.0)
    np.testing.assert_array_equal(np.asarray(out), np.full(16, 7.0, np.float32))


# ---------------------------------------------------------------------------
# engine-level equivalence: split-Pallas vs unsplit-Pallas vs XLA oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pname", PROBLEMS)
def test_engine_split_three_way(pname, rng):
    g0 = _hub_graph(rng)
    prob, g = _problem(pname, g0, rng)
    cfg = dict(p=2, l=2, lane=8, tile_vb=32, tile_eb=32)
    pg_split = partition_2d(g, PartitionConfig(**cfg))
    pg_none = partition_2d(g, PartitionConfig(**cfg, split_threshold=None))
    assert pg_split.split_rows > 0, "hub graph must trigger splitting"
    assert pg_split.tile_split_map is not None
    assert pg_split.tile_word.shape[3] < pg_none.tile_word.shape[3]
    # tile_split_map is exactly the gather form of tile_row_orig — the engine
    # reads the former, tests/debugging read the latter; they must not drift
    vl, s_max = pg_split.tile_split_map.shape[2:]
    for i in range(pg_split.p):
        for m in range(pg_split.l):
            sm = split_map_from_row_orig(pg_split.tile_row_orig[i, m], vl)
            expect = np.full((vl, s_max), -1, np.int32)
            expect[:, : sm.shape[1]] = sm
            np.testing.assert_array_equal(pg_split.tile_split_map[i, m], expect)

    res_s = run(prob, g, pg_split, EngineOptions(backend="pallas"))
    res_u = run(prob, g, pg_none, EngineOptions(backend="pallas"))
    res_x = run(prob, g, pg_none, EngineOptions(backend="xla"))
    if prob.reduce_kind == "min":
        np.testing.assert_array_equal(res_s.labels["label"], res_x.labels["label"])
        np.testing.assert_array_equal(res_u.labels["label"], res_x.labels["label"])
        assert res_s.iterations == res_u.iterations == res_x.iterations
    else:
        np.testing.assert_allclose(
            res_s.labels["label"], res_x.labels["label"], **PR_TOL
        )
        np.testing.assert_allclose(
            res_u.labels["label"], res_x.labels["label"], **PR_TOL
        )


@pytest.mark.parametrize("immediate", [True, False])
def test_engine_split_update_schemes(immediate, rng):
    """Async (immediate) and sync phases both run the level-2 combine."""
    g = _hub_graph(rng, n=256, hub_deg=1500, bg=600)
    pg = partition_2d(
        g, PartitionConfig(p=2, l=2, lane=8, tile_vb=16, tile_eb=16)
    )
    assert pg.split_rows > 0
    a = run(bfs(0), g, pg, EngineOptions(immediate_updates=immediate,
                                         backend="pallas"))
    b = run(bfs(0), g, pg, EngineOptions(immediate_updates=immediate,
                                         backend="xla"))
    np.testing.assert_array_equal(a.labels["label"], b.labels["label"])
    assert a.iterations == b.iterations


def test_engine_split_32bit_regime(rng):
    """Splitting composes with the 32-bit packed-word fallback."""
    gs = G.symmetrize(_hub_graph(rng, n=256, hub_deg=1500, bg=600))
    pgs = partition_2d(gs, PartitionConfig(p=2, l=2, lane=8, tile_vb=16,
                                           tile_eb=16, pack_src_bits=32))
    assert pgs.split_rows > 0 and pgs.src_bits == 32
    assert pgs.tile_word_hi is not None
    a = run(wcc(), gs, pgs, EngineOptions(backend="pallas"))
    b = run(wcc(), gs, pgs, EngineOptions(backend="xla"))
    np.testing.assert_array_equal(a.labels["label"], b.labels["label"])


# ---------------------------------------------------------------------------
# identity-element regression (satellite: the level-2 combine must use the
# problem's reduce identity — min folds with INF, sum with 0)
# ---------------------------------------------------------------------------


def test_combine_uses_reduce_identity_not_zero():
    """Padded split_map entries contribute the problem's identity: a wrong
    0-identity in a min combine would zero every label; a wrong INF in a sum
    combine would blow it up; reusing a real position would double-count."""
    reduced = jnp.asarray(np.array([5.0, 7.0, 11.0, 2.0], np.float32))
    # row 0 owns positions {0, 2}; row 1 owns {3} with one padded entry
    sm = jnp.asarray(np.array([[0, 2], [3, -1]], np.int32))
    out_min = combine_split_rows(reduced, sm, kind="min", identity=float(np.inf))
    np.testing.assert_array_equal(np.asarray(out_min), [5.0, 2.0])
    out_sum = combine_split_rows(reduced, sm, kind="sum", identity=0.0)
    np.testing.assert_array_equal(np.asarray(out_sum), [16.0, 2.0])
    # uint32 min path (BFS/WCC labels): identity INF_U32 survives the cast
    red_u = jnp.asarray(np.array([3, INF_U32, 9, 1], np.uint32))
    out_u = combine_split_rows(red_u, sm, kind="min", identity=float(INF_U32))
    np.testing.assert_array_equal(np.asarray(out_u), [3, 1])


def test_bfs_unreached_hub_row_stays_inf(rng):
    """Regression: a split hub row NOT reached by BFS must stay INF_U32 —
    any stray 0/sum-identity in the level-2 fold would mark it reached."""
    n = 128
    # hub 5 receives many edges from sources that BFS (rooted in a separate
    # component) never reaches; component {0, 1} is root's.
    hub_src = rng.integers(2, n, 800).astype(np.uint32)
    src = np.concatenate([hub_src, np.array([0], np.uint32)])
    dst = np.concatenate([np.full(800, 5, np.uint32), np.array([1], np.uint32)])
    g = G.COOGraph(src=src, dst=dst, num_vertices=n)
    pg = partition_2d(g, PartitionConfig(p=2, l=2, lane=8, tile_vb=8, tile_eb=8))
    assert pg.split_rows > 0
    res = run(bfs(0), g, pg, EngineOptions(backend="pallas"))
    assert res.labels["label"][1] == 1
    assert res.labels["label"][5] == INF_U32  # hub unreached: identity held
    oracle = run(bfs(0), g, pg, EngineOptions(backend="xla"))
    np.testing.assert_array_equal(res.labels["label"], oracle.labels["label"])


def test_pagerank_split_conserves_mass():
    """Sum identity regression: virtual-row partials must add each edge
    exactly once — total rank mass is conserved under splitting.

    Uses a private rng (NOT the shared session fixture): the graph must not
    depend on how many draws earlier tests made, or the reassociation
    tolerance turns order-dependent (seen as a full-suite-only flake)."""
    g = _hub_graph(np.random.default_rng(12), n=256, hub_deg=2000, bg=500)
    cfg = dict(p=2, l=2, lane=8, tile_vb=16, tile_eb=16)
    pg = partition_2d(g, PartitionConfig(**cfg))
    assert pg.split_rows > 0
    res = run(pagerank(tol=1e-5), g, pg, EngineOptions(backend="pallas"))
    ref = run(pagerank(tol=1e-5), g, pg, EngineOptions(backend="xla"))
    np.testing.assert_allclose(
        res.labels["label"].sum(), ref.labels["label"].sum(), rtol=1e-5
    )
    np.testing.assert_allclose(res.labels["label"], ref.labels["label"], **PR_TOL)


# ---------------------------------------------------------------------------
# metrics + disable switch
# ---------------------------------------------------------------------------


def test_star_t_max_halved_and_metrics():
    """Acceptance shape: on a star-like graph the split layout's T_max is
    <= 50% of the unsplit layout's, and the metrics record it."""
    g = skewed_graph(2048, kind="star", hub_in_degree=6000, avg_degree=2, seed=7)
    cfg = dict(p=4, l=2, lane=8, tile_vb=64)
    pg_split = partition_2d(g, PartitionConfig(**cfg))
    pg_none = partition_2d(g, PartitionConfig(**cfg, split_threshold=None))
    assert pg_split.tile_word.shape[3] <= 0.5 * pg_none.tile_word.shape[3]
    assert pg_split.t_max_unsplit == pg_none.tile_word.shape[3]
    assert pg_split.t_max_reduction <= 0.5
    assert 0.0 < pg_split.split_row_fraction < 1.0
    assert pg_split.skipped_tile_fraction < pg_none.skipped_tile_fraction
    # splitting also shrinks the stacked stream itself
    assert pg_split.tile_word.size < pg_none.tile_word.size
    assert pg_none.t_max_reduction == 1.0 and pg_none.split_row_fraction == 0.0


def test_split_threshold_none_preserves_old_layout():
    """Disable switch: split_threshold=None must reproduce the pre-split
    layout byte for byte (row_pos permutation, no split fields) even on a
    graph whose default partition splits."""
    g = skewed_graph(512, kind="star", hub_in_degree=2000, avg_degree=2, seed=3)
    cfg = dict(p=2, l=2, lane=8, tile_vb=32, tile_eb=32)
    pg_auto = partition_2d(g, PartitionConfig(**cfg))
    pg_none = partition_2d(g, PartitionConfig(**cfg, split_threshold=None))
    assert pg_auto.split_rows > 0
    assert pg_none.tile_row_orig is None and pg_none.tile_split_map is None
    assert pg_none.split_rows == 0
    assert pg_none.tile_row_pos is not None
    vpc = pg_none.vertices_per_core
    for i in range(pg_none.p):
        for m in range(pg_none.l):
            assert sorted(pg_none.tile_row_pos[i, m].tolist()) == list(range(vpc))
    # and byte-for-byte: None matches a manual unsplit prepare_tiles stack
    from repro.kernels.csr_gather_reduce.ops import stack_packed_tiles

    layouts = [
        prepare_tiles(
            pg_none.src_gidx[i, m], pg_none.dst_lidx[i, m], pg_none.valid[i, m],
            num_rows=vpc, vb=pg_none.tile_vb, eb=32, balance_rows=True,
        )
        for i in range(pg_none.p)
        for m in range(pg_none.l)
    ]
    word, _, counts, _ = stack_packed_tiles(layouts, src_bits=pg_none.src_bits)
    np.testing.assert_array_equal(
        pg_none.tile_word, word.reshape(pg_none.tile_word.shape)
    )
    np.testing.assert_array_equal(
        pg_none.tile_counts, counts.reshape(pg_none.tile_counts.shape)
    )


def test_auto_threshold_no_hub_is_identical_to_disabled():
    """'auto' on a hub-free graph never splits, so the layout equals the
    disabled one exactly — the default is safe for every existing graph."""
    g = G.symmetrize(G.rmat(8, 6, seed=13))
    cfg = dict(p=2, l=2, lane=4)
    pg_auto = partition_2d(g, PartitionConfig(**cfg))
    pg_none = partition_2d(g, PartitionConfig(**cfg, split_threshold=None))
    assert pg_auto.split_rows == 0 and pg_auto.tile_row_orig is None
    np.testing.assert_array_equal(pg_auto.tile_word, pg_none.tile_word)
    np.testing.assert_array_equal(pg_auto.tile_counts, pg_none.tile_counts)
    np.testing.assert_array_equal(pg_auto.tile_row_pos, pg_none.tile_row_pos)


def test_skewed_graph_generator_deterministic():
    a = skewed_graph(256, kind="powerlaw", hub_in_degree=500, seed=5)
    b = skewed_graph(256, kind="powerlaw", hub_in_degree=500, seed=5)
    np.testing.assert_array_equal(a.src, b.src)
    np.testing.assert_array_equal(a.dst, b.dst)
    assert np.bincount(a.dst, minlength=256).max() <= 500
    with pytest.raises(ValueError, match="star"):
        skewed_graph(16, kind="ring")
