"""Elastic scaling integration test: a job checkpointed on a 4-device mesh
restores and CONTINUES TRAINING on an 8-device mesh (and vice versa) — the
checkpoint layer re-stripes logical arrays onto whatever mesh the restoring
job brings (dist/checkpoint.py). Each mesh size runs in its own subprocess
(jax locks the device count per process)."""
import os
import subprocess
import sys
import tempfile
import textwrap

import pytest

_TRAIN = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
import sys, json
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.dist.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.models.transformer import LMConfig, init_params
from repro.train.optim import AdamWConfig
from repro.train.steps import init_train_state, make_lm_train_step
from repro.data.synthetic import lm_batch

ckpt_dir, steps, devices = sys.argv[1], int(sys.argv[2]), {devices}
mesh = jax.make_mesh((devices,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
cfg = LMConfig(name="t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
               vocab=128, dtype=jnp.float32, attn_chunk=16)
ocfg = AdamWConfig(lr=1e-3, total_steps=100)
state = init_train_state(init_params(jax.random.key(0), cfg), ocfg)
# FSDP-shard the d_ff dim of the FFN weights over this mesh's data axis
shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
start = 0
if latest_step(ckpt_dir) is not None:
    state, meta = restore_checkpoint(ckpt_dir, state, shardings=shardings)
    start = meta["next_step"]
else:
    state = jax.device_put(state, shardings)
step = jax.jit(make_lm_train_step(cfg, ocfg), donate_argnums=0)
with mesh:
    for i in range(start, start + steps):
        b = lm_batch(seed=0, step=i, batch=devices, seq=32, vocab=cfg.vocab)
        batch = jax.device_put(
            {{k: jnp.asarray(v) for k, v in b.items()}},
            NamedSharding(mesh, P("data", None)),
        )
        state, m = step(state, batch)
save_checkpoint(ckpt_dir, start + steps, state, meta={{"next_step": start + steps}})
print(json.dumps({{"loss": float(m["loss"]), "step": start + steps}}))
"""


def _run(devices: int, ckpt: str, steps: int) -> dict:
    import json

    code = textwrap.dedent(_TRAIN.format(devices=devices))
    res = subprocess.run(
        [sys.executable, "-c", code, ckpt, str(steps)],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},  # libtpu present: pin the CPU backend
        cwd="/root/repo",
    )
    assert res.returncode == 0, f"STDOUT:{res.stdout}\nSTDERR:{res.stderr}"
    return json.loads(res.stdout.strip().splitlines()[-1])


def test_elastic_4_to_8_devices(tmp_path):
    """Train 3 steps on 4 devices, resume + train 3 more on 8 devices; the
    result equals an uninterrupted 6-step single-mesh run (same global batch
    stream): elasticity without divergence."""
    a = str(tmp_path / "elastic")
    r1 = _run(4, a, 3)
    assert r1["step"] == 3
    r2 = _run(8, a, 3)
    assert r2["step"] == 6
    # reference: 6 uninterrupted steps on one mesh... batch size differs by
    # devices (global batch = devices) so exact-match only holds per-mesh;
    # here we assert the resumed run is finite and progressed.
    import numpy as np

    assert np.isfinite(r2["loss"])


def test_elastic_same_mesh_exact(tmp_path):
    """Same mesh size: interrupted(3+3) == uninterrupted(6) loss exactly."""
    a = str(tmp_path / "int")
    _run(4, a, 3)
    r_int = _run(4, a, 3)
    b = str(tmp_path / "unint")
    r_unint = _run(4, b, 6)
    assert abs(r_int["loss"] - r_unint["loss"]) < 1e-6
