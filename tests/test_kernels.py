"""Per-kernel validation: shape/dtype sweeps against the ref.py oracles,
all in interpret mode (CPU); plus hypothesis property tests."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.csr_gather_reduce import gather_reduce, prepare_tiles
from repro.kernels.csr_gather_reduce.ref import gather_reduce_reference
from repro.kernels.embedding_bag import embedding_bag
from repro.kernels.embedding_bag.ref import embedding_bag_reference
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import gqa_attention_reference
from repro.kernels.segment_softmax import segment_softmax
from repro.kernels.segment_softmax.ref import segment_softmax_reference

RNG = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# csr_gather_reduce — the graph-core accumulator
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "v,e,g,vb,eb,kind,dtype",
    [
        (64, 300, 128, 8, 16, "min", np.uint32),
        (64, 300, 128, 8, 16, "sum", np.float32),
        (128, 1000, 256, 16, 32, "min", np.float32),
        (32, 10, 64, 8, 8, "sum", np.float32),
        (256, 2048, 512, 32, 128, "min", np.uint32),
        (64, 64, 64, 64, 8, "sum", np.float32),  # single row block
    ],
)
def test_gather_reduce_sweep(v, e, g, vb, eb, kind, dtype):
    dst = np.sort(RNG.integers(0, v, size=e)).astype(np.int32)
    src = RNG.integers(0, g, size=e).astype(np.int32)
    valid = RNG.random(e) < 0.9
    if dtype == np.uint32:
        ident = float(np.iinfo(np.uint32).max)
        payload = RNG.integers(0, 1000, size=g).astype(dtype)
    else:
        ident = 0.0 if kind == "sum" else float(np.finfo(np.float32).max)
        payload = RNG.random(g).astype(np.float32)
    tiles = prepare_tiles(src, dst, valid, num_rows=v, vb=vb, eb=eb)
    out_k = gather_reduce(jnp.asarray(payload), tiles, kind=kind, identity=ident)
    out_r = gather_reduce_reference(
        jnp.asarray(payload), jnp.asarray(src), jnp.asarray(dst),
        jnp.asarray(valid), v, kind=kind, identity=ident,
    )
    if kind == "min" and dtype != np.uint32:
        out_r = jnp.where(jnp.isinf(out_r), jnp.asarray(ident, out_r.dtype), out_r)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-6)


def test_gather_reduce_weighted_min_plus():
    v, e, g = 64, 400, 128
    dst = np.sort(RNG.integers(0, v, size=e)).astype(np.int32)
    src = RNG.integers(0, g, size=e).astype(np.int32)
    w = RNG.random(e).astype(np.float32)
    inf = float(np.finfo(np.float32).max)
    payload = RNG.random(g).astype(np.float32)
    payload[::5] = inf  # unreached vertices stay saturated
    tiles = prepare_tiles(src, dst, np.ones(e, bool), num_rows=v, vb=8, eb=16, weights=w)
    out_k = gather_reduce(
        jnp.asarray(payload), tiles, kind="min", edge_op="add", identity=inf
    )
    out_r = gather_reduce_reference(
        jnp.asarray(payload), jnp.asarray(src), jnp.asarray(dst),
        jnp.asarray(np.ones(e, bool)), v, kind="min", identity=inf,
        weights=jnp.asarray(w),
    )
    out_r = jnp.where(jnp.isinf(out_r), inf, out_r)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-6)


@given(
    v=st.sampled_from([16, 32, 64]),
    e=st.integers(1, 400),
    kind=st.sampled_from(["min", "sum"]),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=15, deadline=None)
def test_gather_reduce_property(v, e, kind, seed):
    r = np.random.default_rng(seed)
    dst = np.sort(r.integers(0, v, size=e)).astype(np.int32)
    src = r.integers(0, 64, size=e).astype(np.int32)
    valid = r.random(e) < 0.8
    ident = 0.0 if kind == "sum" else float(np.finfo(np.float32).max)
    payload = r.random(64).astype(np.float32)
    tiles = prepare_tiles(src, dst, valid, num_rows=v, vb=8, eb=8)
    out_k = gather_reduce(jnp.asarray(payload), tiles, kind=kind, identity=ident)
    out_r = gather_reduce_reference(
        jnp.asarray(payload), jnp.asarray(src), jnp.asarray(dst),
        jnp.asarray(valid), v, kind=kind, identity=ident,
    )
    if kind == "min":
        out_r = jnp.where(jnp.isinf(out_r), ident, out_r)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-5)


# ---------------------------------------------------------------------------
# embedding_bag
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "n,d,b,length,mode,bpt",
    [
        (100, 16, 16, 10, "sum", 8),
        (1000, 32, 32, 7, "mean", 4),
        (50, 8, 8, 1, "sum", 8),
        (64, 128, 24, 20, "mean", 8),
        (128, 64, 8, 33, "sum", 2),
    ],
)
def test_embedding_bag_sweep(n, d, b, length, mode, bpt):
    table = RNG.random((n, d), np.float32)
    ids = RNG.integers(-1, n, (b, length)).astype(np.int32)
    out_k = embedding_bag(
        jnp.asarray(table), jnp.asarray(ids), mode=mode, use_pallas=True,
        bags_per_tile=bpt,
    )
    out_r = embedding_bag_reference(jnp.asarray(table), jnp.asarray(ids), mode=mode)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-5)


def test_embedding_bag_all_padding_bag():
    table = RNG.random((10, 4), np.float32)
    ids = np.full((8, 5), -1, np.int32)
    out = embedding_bag(jnp.asarray(table), jnp.asarray(ids), use_pallas=True)
    np.testing.assert_allclose(np.asarray(out), 0.0)


# ---------------------------------------------------------------------------
# segment_softmax
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("v,e,vb,eb", [(64, 300, 8, 16), (32, 40, 8, 8), (128, 2000, 16, 64)])
def test_segment_softmax_sweep(v, e, vb, eb):
    from repro.kernels.csr_gather_reduce.ops import prepare_tiles as prep

    dst = np.sort(RNG.integers(0, v, size=e)).astype(np.int32)
    valid = RNG.random(e) < 0.85
    scores = (RNG.random(e).astype(np.float32) - 0.5) * 10
    tiles = prep(np.zeros(e, np.int32), dst, valid, num_rows=v, vb=vb, eb=eb)
    out_k = segment_softmax(
        jnp.asarray(scores), jnp.asarray(dst), jnp.asarray(valid), v,
        use_pallas=True, tiles=tiles,
    )
    out_r = segment_softmax_reference(
        jnp.asarray(scores), jnp.asarray(dst), jnp.asarray(valid), v
    )
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-5, atol=1e-7)
    # per-segment weights sum to one
    seg = np.zeros(v)
    np.add.at(seg, dst[valid], np.asarray(out_k)[valid])
    nonempty = np.bincount(dst[valid], minlength=v) > 0
    np.testing.assert_allclose(seg[nonempty], 1.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,hq,hkv,s,d,bq,bk,causal",
    [
        (2, 4, 2, 64, 16, 16, 16, True),
        (1, 8, 8, 128, 32, 32, 64, True),
        (2, 6, 3, 96, 8, 32, 32, False),
        (1, 4, 1, 64, 64, 64, 16, True),
        (1, 2, 2, 32, 128, 16, 32, True),
    ],
)
def test_flash_attention_sweep(b, hq, hkv, s, d, bq, bk, causal):
    q = RNG.standard_normal((b, hq, s, d)).astype(np.float32)
    k = RNG.standard_normal((b, hkv, s, d)).astype(np.float32)
    v = RNG.standard_normal((b, hkv, s, d)).astype(np.float32)
    out_k = flash_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=causal,
        use_pallas=True, block_q=bq, block_k=bk,
    )
    out_r = gqa_attention_reference(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=causal
    )
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=2e-5, atol=2e-5)


def test_flash_matches_chunked_xla_twin():
    """The XLA chunked attention used in models must agree with the Pallas
    kernel — they implement the same recurrence."""
    from repro.models.layers import chunked_gqa_attention

    q = RNG.standard_normal((2, 4, 64, 16)).astype(np.float32)
    k = RNG.standard_normal((2, 2, 64, 16)).astype(np.float32)
    v = RNG.standard_normal((2, 2, 64, 16)).astype(np.float32)
    a = flash_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), causal=True,
        use_pallas=True, block_q=16, block_k=16,
    )
    b = chunked_gqa_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), chunk=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)
