"""Fault-tolerance tests: checkpoint atomicity/integrity/GC, elastic restore,
kill-and-resume exactness, straggler monitor."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.checkpoint import (
    latest_step,
    list_steps,
    restore_checkpoint,
    save_checkpoint,
)
from repro.dist.fault_tolerance import CheckpointPolicy, StepMonitor, run_with_recovery


def _toy_state(seed=0):
    k = jax.random.key(seed)
    return {
        "w": jax.random.normal(k, (8, 8)),
        "opt": {"mu": jnp.zeros((8, 8)), "step": jnp.int32(0)},
    }


def test_checkpoint_roundtrip(tmp_path):
    state = _toy_state()
    save_checkpoint(str(tmp_path), 3, state, meta={"next_step": 3, "seed": 7})
    like = jax.tree.map(lambda x: jnp.zeros_like(x), state)
    restored, meta = restore_checkpoint(str(tmp_path), like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert meta["seed"] == 7


def test_checkpoint_gc_keeps_newest(tmp_path):
    state = _toy_state()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(str(tmp_path), s, state, keep=2)
    assert list_steps(str(tmp_path)) == [4, 5]


def test_checkpoint_integrity_check(tmp_path):
    state = _toy_state()
    path = save_checkpoint(str(tmp_path), 1, state)
    # corrupt one leaf
    victim = os.path.join(path, "leaf_00000.npy")
    arr = np.load(victim)
    arr.flat[0] += 1
    np.save(victim, arr)
    with pytest.raises(IOError, match="checksum"):
        restore_checkpoint(str(tmp_path), state, step=1)


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    save_checkpoint(str(tmp_path), 1, _toy_state())
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_kill_and_resume_exact(tmp_path):
    """A 'preempted' run resumed from checkpoint produces the exact same
    final state as an uninterrupted run (deterministic data cursor)."""

    def make_step():
        def step(state, i):
            # deterministic per-step data (simulating the synthetic pipeline)
            x = jnp.float32(i + 1)
            return {"w": state["w"] + x}, {"w": state["w"]}

        return step

    def init():
        return {"w": jnp.float32(0.0)}

    # uninterrupted
    pol_a = CheckpointPolicy(directory=str(tmp_path / "a"), every_steps=2)
    final_a, _ = run_with_recovery(make_step(), init, 7, pol_a)

    # interrupted after 4 steps, then resumed
    pol_b = CheckpointPolicy(directory=str(tmp_path / "b"), every_steps=2)
    run_with_recovery(make_step(), init, 4, pol_b)
    assert latest_step(str(tmp_path / "b")) == 4
    final_b, _ = run_with_recovery(make_step(), init, 7, pol_b)
    assert float(final_a["w"]) == float(final_b["w"])


def test_step_retry_on_transient_failure(tmp_path):
    calls = {"n": 0, "step2_attempts": 0}

    def flaky_step(state, i):
        calls["n"] += 1
        if i == 2:
            calls["step2_attempts"] += 1
            if calls["step2_attempts"] <= 2:  # fails twice, then recovers
                raise RuntimeError("transient")
        return state, {}

    pol = CheckpointPolicy(directory=str(tmp_path), every_steps=100)
    state, _ = run_with_recovery(flaky_step, lambda: {"w": jnp.float32(0)}, 5, pol)
    assert calls["n"] == 7  # 5 successes + 2 retries
    assert calls["step2_attempts"] == 3


def test_straggler_monitor_flags_slow_steps():
    mon = StepMonitor(deadline_factor=3.0)
    for i in range(10):
        mon.record(i, 0.1)
    assert mon.record(10, 1.0)  # 10x median -> straggler
    assert not mon.record(11, 0.12)
    s = mon.summary()
    assert s["stragglers"] == 1 and s["steps"] == 12


def test_elastic_restore_resharding(tmp_path):
    """Restore onto a DIFFERENT sharding than the save used (elastic)."""
    state = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    save_checkpoint(str(tmp_path), 1, state)
    mesh = jax.make_mesh((1,), ("x",), axis_types=(jax.sharding.AxisType.Auto,))
    from jax.sharding import NamedSharding, PartitionSpec as P

    shardings = {"w": NamedSharding(mesh, P("x", None))}
    restored, _ = restore_checkpoint(str(tmp_path), state, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(state["w"]))
    assert restored["w"].sharding == shardings["w"]


def test_pagerank_kill_and_resume_reaches_identical_convergence(tmp_path):
    """Checkpoint hygiene on the real engine: a PageRank run driven step-wise
    through run_with_recovery, killed mid-run, resumed via latest_step from
    its newest checkpoint, lands on the BITWISE-same converged labels as an
    uninterrupted run (and on the oracle ranks) — the engine label tree
    (rank / inv_deg / mask / scalar n) round-trips through save/restore."""
    import repro.core.graph as G
    from repro.core.engine import (
        EngineOptions,
        make_iteration,
        prepare_labels,
        unpad_labels,
    )
    from repro.core.partition import PartitionConfig, partition_2d
    from repro.core.problems import pagerank
    from repro.core.reference import pagerank_reference

    g = G.rmat(8, 6, seed=4)
    pg = partition_2d(g, PartitionConfig(p=2, l=2, lane=8))
    prob = pagerank(tol=0.0)  # fixed-step power iteration
    iteration = jax.jit(make_iteration(prob, pg, EngineOptions()))

    def init():
        return prepare_labels(prob, g, pg)

    def step_fn(state, i):
        return iteration(state), {}

    steps = 40
    pol_a = CheckpointPolicy(directory=str(tmp_path / "a"), every_steps=15)
    final_a, _ = run_with_recovery(step_fn, init, steps, pol_a)

    pol_b = CheckpointPolicy(directory=str(tmp_path / "b"), every_steps=15)
    run_with_recovery(step_fn, init, 20, pol_b)  # 'preempted' after 20 steps
    assert latest_step(str(tmp_path / "b")) == 15  # newest completed ckpt
    final_b, _ = run_with_recovery(step_fn, init, steps, pol_b)  # resume @ 15

    a = unpad_labels({k: np.asarray(v) for k, v in final_a.items()}, pg)
    b = unpad_labels({k: np.asarray(v) for k, v in final_b.items()}, pg)
    np.testing.assert_array_equal(a["label"], b["label"])  # bitwise
    np.testing.assert_allclose(a["label"], pagerank_reference(g), atol=1e-4)


def test_compression_error_feedback_unit():
    from repro.dist.compression import int8_compress, int8_decompress, topk_sparsify

    x = jnp.asarray(np.random.default_rng(0).standard_normal(1000), jnp.float32)
    q, s = int8_compress(x)
    err = jnp.abs(int8_decompress(q, s) - x).max()
    assert float(err) <= float(s) * 0.51 + 1e-6  # half-step quantization error
    sp, mask = topk_sparsify(x, 0.1)
    assert int(mask.sum()) >= 100
    np.testing.assert_allclose(np.asarray(sp[mask]), np.asarray(x[mask]))
