"""Delta ingest (ISSUE 9): ``apply_edge_deltas`` re-tiles ONLY the dirty
(core, phase) buckets, yet the result is BIT-IDENTICAL to a from-scratch
``partition_2d`` of the grown edge list (docs/tile_layout.md §10).

The equivalence argument under test: the cold path sorts the whole edge list
with one stable argsort on (bucket, lidx); a dirty bucket's merged slice —
old dst-sorted slice ++ delta edges in insertion order, stably re-sorted by
lidx — reproduces exactly that tie order, and per-bucket layout decisions
(LPT packing, 'auto' split threshold, E_pad rounding) are local, so clean
buckets never move. Composition across flushes means N incremental flushes
== one cold repartition of the final graph.
"""
import dataclasses

import numpy as np
import pytest

import repro.core.graph as G
from repro.core.engine import EngineOptions, run
from repro.core.partition import (
    PartitionConfig,
    apply_edge_deltas,
    bucket_coords,
    partition_2d,
)
from repro.core.problems import bfs, sssp, wcc
from repro.data.synthetic import edge_insertion_stream, skewed_graph
from repro.serve import DeltaBuffer


def _weighted(g, seed=0):
    w = (np.random.default_rng(seed).random(g.num_edges) + 0.1).astype(np.float32)
    return G.COOGraph(src=g.src, dst=g.dst, num_vertices=g.num_vertices, weights=w)


def _grown(g, src, dst, w=None):
    return G.COOGraph(
        src=np.concatenate([g.src, np.asarray(src, g.src.dtype)]),
        dst=np.concatenate([g.dst, np.asarray(dst, g.dst.dtype)]),
        num_vertices=g.num_vertices,
        weights=(
            np.concatenate([g.weights, np.asarray(w, np.float32)])
            if g.weights is not None else None
        ),
    )


def assert_partitions_identical(pa, pb):
    """Every field of the two PartitionedGraphs, bit for bit."""
    for f in dataclasses.fields(pa):
        a, b = getattr(pa, f.name), getattr(pb, f.name)
        if f.name == "config":
            assert a == b, "config"
            continue
        if a is None or b is None:
            assert a is None and b is None, f.name
            continue
        if isinstance(a, np.ndarray):
            assert a.dtype == b.dtype and a.shape == b.shape, (
                f.name, a.dtype, b.dtype, a.shape, b.shape
            )
            assert np.array_equal(a, b), f.name
        else:
            assert a == b, (f.name, a, b)


# ---------------------------------------------------------------------------
# bit-identity vs a from-scratch repartition


def test_single_flush_bit_identity_weighted():
    g = _weighted(G.symmetrize(G.rmat(7, 4, seed=1)), seed=2)
    cfg = PartitionConfig(p=4, l=2)
    pg = partition_2d(g, cfg)
    rng = np.random.default_rng(3)
    src = rng.integers(0, g.num_vertices, 40)
    dst = rng.integers(0, g.num_vertices, 40)
    w = rng.random(40).astype(np.float32)
    new_pg, report = apply_edge_deltas(pg, src, dst, w)
    assert report.edges_added == 40
    assert 0 < report.buckets_retiled <= report.total_buckets
    assert_partitions_identical(new_pg, partition_2d(_grown(g, src, dst, w), cfg))


def test_multi_flush_composes():
    """Two sequential flushes == one cold repartition of the final graph."""
    g = _weighted(G.symmetrize(G.rmat(7, 4, seed=2)), seed=4)
    cfg = PartitionConfig(p=2, l=2)
    pg = partition_2d(g, cfg)
    cur_g, cur_pg = g, pg
    for batch_seed in (5, 6):
        rng = np.random.default_rng(batch_seed)
        src = rng.integers(0, g.num_vertices, 24)
        dst = rng.integers(0, g.num_vertices, 24)
        w = rng.random(24).astype(np.float32)
        cur_pg, _ = apply_edge_deltas(cur_pg, src, dst, w)
        cur_g = _grown(cur_g, src, dst, w)
    assert_partitions_identical(cur_pg, partition_2d(cur_g, cfg))


def test_stride_permutation_flush():
    """The stride perm is applied to delta endpoints exactly as partition_2d
    applies it to the base edges."""
    g = G.symmetrize(G.rmat(7, 4, seed=3))
    cfg = PartitionConfig(p=2, l=2, stride=10)
    pg = partition_2d(g, cfg)
    assert pg.perm is not None
    rng = np.random.default_rng(7)
    src = rng.integers(0, g.num_vertices, 32)
    dst = rng.integers(0, g.num_vertices, 32)
    new_pg, _ = apply_edge_deltas(pg, src, dst)
    assert_partitions_identical(new_pg, partition_2d(_grown(g, src, dst), cfg))


def test_hub_split_bucket_flush():
    """Deltas landing on an already-split hub bucket re-run the two-level
    split with the recomputed 'auto' threshold — still bit-identical."""
    g = skewed_graph(256, kind="star", hub_in_degree=700, avg_degree=2, seed=7)
    cfg = PartitionConfig(p=2, l=2, lane=8, tile_vb=32, tile_eb=32)
    pg = partition_2d(g, cfg)
    assert pg.split_rows > 0, "precondition: the hub must be split"
    hub = int(np.argmax(np.bincount(g.dst, minlength=g.num_vertices)))
    rng = np.random.default_rng(8)
    src = rng.integers(0, g.num_vertices, 64)
    dst = np.full(64, hub, dtype=np.int64)  # pile onto the hub
    new_pg, report = apply_edge_deltas(pg, src, dst)
    assert report.buckets_retiled < report.total_buckets
    assert_partitions_identical(new_pg, partition_2d(_grown(g, src, dst), cfg))


def test_pos_to_split_mode_transition():
    """A delta that pushes one row over the split threshold flips the layout
    from pos-mode (tile_row_pos) to split-mode — clean buckets' row maps are
    derived mechanically, and the result still matches cold."""
    g = G.symmetrize(G.rmat(7, 3, seed=4))
    cfg = PartitionConfig(p=2, l=2, lane=8, tile_vb=32, tile_eb=32)
    pg = partition_2d(g, cfg)
    assert pg.tile_split_map is None, "precondition: no split before the delta"
    rng = np.random.default_rng(9)
    src = rng.integers(0, g.num_vertices, 600)
    dst = np.zeros(600, dtype=np.int64)  # one monster row
    new_pg, report = apply_edge_deltas(pg, src, dst)
    assert report.mode_changed and new_pg.tile_split_map is not None
    assert_partitions_identical(new_pg, partition_2d(_grown(g, src, dst), cfg))


def test_edge_pad_growth():
    """A delta overflowing a bucket's E_pad grows the flat arrays by the cold
    rounding rule."""
    g = G.symmetrize(G.rmat(6, 3, seed=5))
    cfg = PartitionConfig(p=2, l=2, edge_pad=8)
    pg = partition_2d(g, cfg)
    rng = np.random.default_rng(10)
    n = 2 * pg.edge_pad  # guaranteed past any per-bucket slack
    src = rng.integers(0, g.num_vertices, n)
    dst = rng.integers(0, g.num_vertices, n)
    new_pg, report = apply_edge_deltas(pg, src, dst)
    assert report.grew_edge_pad and new_pg.edge_pad > pg.edge_pad
    assert_partitions_identical(new_pg, partition_2d(_grown(g, src, dst), cfg))


def test_label_equality_after_streamed_insertions():
    """The acceptance criterion: BFS/WCC/SSSP labels on the delta-retiled
    partition are bit-identical to a cold repartition — on a hub graph where
    the insertions hit the split bucket."""
    g0 = skewed_graph(192, kind="star", hub_in_degree=500, avg_degree=2, seed=11)
    g = _weighted(g0, seed=12)
    cfg = PartitionConfig(p=2, l=2, lane=8, tile_vb=32, tile_eb=32)
    pg = partition_2d(g, cfg)
    assert pg.split_rows > 0
    cur_g, cur_pg = g, pg
    for batch in edge_insertion_stream(
        48, g.num_vertices, num_batches=2, hub_bias=0.7, weighted=True, seed=13
    ):
        src, dst, w = batch
        cur_pg, _ = apply_edge_deltas(cur_pg, src, dst, w)
        cur_g = _grown(cur_g, src, dst, w)
    cold_pg = partition_2d(cur_g, cfg)
    assert_partitions_identical(cur_pg, cold_pg)
    for prob in (bfs(0), wcc(), sssp(0)):
        ra = run(prob, cur_g, cur_pg, EngineOptions())
        rb = run(prob, cur_g, cold_pg, EngineOptions())
        assert ra.iterations == rb.iterations, prob.name
        for k in ra.labels:
            assert np.array_equal(ra.labels[k], rb.labels[k]), (prob.name, k)


# ---------------------------------------------------------------------------
# O(B): a flush touching B buckets rebuilds O(B) packed bytes, not O(p*l)


def test_flush_is_o_dirty_buckets():
    g = G.symmetrize(G.rmat(8, 6, seed=6))
    cfg = PartitionConfig(p=4, l=4)
    pg = partition_2d(g, cfg)
    assert pg.p * pg.l == 16
    vpc, sub = pg.vertices_per_core, pg.sub_size
    # confine the delta to bucket (core 0, phase 0): dst < vpc, src < sub
    rng = np.random.default_rng(14)
    src = rng.integers(0, sub, 20)
    dst = rng.integers(0, vpc, 20)
    core, phase, _, _ = bucket_coords(pg, src, dst)
    assert set(zip(core.tolist(), phase.tolist())) == {(0, 0)}
    new_pg, report = apply_edge_deltas(pg, src, dst)
    assert report.buckets_retiled == 1 and report.total_buckets == 16
    # bytes-level witness: one bucket's slice of the stacked stream
    assert report.tile_bytes_repacked < report.tile_bytes_total
    assert report.repacked_fraction == pytest.approx(1 / 16, rel=0.05)
    assert_partitions_identical(new_pg, partition_2d(_grown(g, src, dst), cfg))


def test_empty_delta_is_identity():
    g = G.symmetrize(G.rmat(6, 3, seed=7))
    pg = partition_2d(g, PartitionConfig(p=2, l=2))
    new_pg, report = apply_edge_deltas(pg, np.zeros(0, np.int64), np.zeros(0, np.int64))
    assert new_pg is pg
    assert report.edges_added == 0 and report.buckets_retiled == 0


# ---------------------------------------------------------------------------
# validation + DeltaBuffer


def test_delta_validation():
    g = _weighted(G.symmetrize(G.rmat(6, 3, seed=8)), seed=15)
    cfg = PartitionConfig(p=2, l=2)
    pg = partition_2d(g, cfg)
    with pytest.raises(ValueError):  # out-of-range vertex id
        apply_edge_deltas(pg, [0], [g.num_vertices], [1.0])
    with pytest.raises(ValueError):  # weighted partition, unweighted delta
        apply_edge_deltas(pg, [0], [1])
    gu = G.symmetrize(G.rmat(6, 3, seed=8))
    pgu = partition_2d(gu, cfg)
    with pytest.raises(ValueError):  # unweighted partition, weighted delta
        apply_edge_deltas(pgu, [0], [1], [1.0])
    bare = dataclasses.replace(pgu, config=None)
    with pytest.raises(ValueError):  # no partition_2d provenance
        apply_edge_deltas(bare, [0], [1])
    with pytest.raises(ValueError):
        DeltaBuffer(bare)


def test_delta_buffer_staging():
    g = G.symmetrize(G.rmat(6, 3, seed=9))
    pg = partition_2d(g, PartitionConfig(p=2, l=2))
    buf = DeltaBuffer(pg, auto_flush_edges=8)
    assert buf.pending_edges == 0 and not buf.should_flush()
    assert buf.stage([1, 2], [3, 4]) == 2
    core, phase, _, _ = bucket_coords(pg, np.array([1, 2]), np.array([3, 4]))
    assert buf.dirty_buckets == frozenset(zip(core.tolist(), phase.tolist()))
    assert buf.stage([5] * 6, [6] * 6) == 6
    assert buf.pending_edges == 8 and buf.should_flush()
    src, dst, w = buf.pending()  # read-only: does NOT clear
    assert src.tolist() == [1, 2, 5, 5, 5, 5, 5, 5] and w is None
    assert buf.pending_edges == 8
    new_pg, report = buf.flush(pg)
    assert report.edges_added == 8
    assert buf.pending_edges == 0 and buf.dirty_buckets == frozenset()
    assert_partitions_identical(new_pg, partition_2d(_grown(g, src, dst), pg.config))
    with pytest.raises(ValueError):  # bad edges fail at stage time
        buf.stage([0], [g.num_vertices])


def test_in_neighbors_matches_coo():
    g = G.symmetrize(G.rmat(6, 4, seed=10))
    for cfg in (PartitionConfig(p=2, l=2), PartitionConfig(p=2, l=2, stride=10)):
        pg = partition_2d(g, cfg)
        for v in (0, 1, 17, g.num_vertices - 1):
            got = np.sort(pg.in_neighbors(v))
            want = np.sort(g.src[g.dst == v])
            assert np.array_equal(got, want.astype(got.dtype)), (cfg.stride, v)
