"""Out-of-core streaming partition build — ISSUE 10.

The streaming contract (docs/tile_layout.md §11): ``partition_2d_streaming``
over any chunking of the same edge sequence is BIT-IDENTICAL to
``partition_2d`` over the materialized array — every packed/flat field, both
row-map modes (LPT ``row_pos`` and hub-split ``row_orig``/``split_map``),
both src-bit regimes, weighted or not, memmap-backed or RAM. Plus: the
two-pass chunk protocol (re-iterable required, one-shot generators rejected,
deterministic replay verified), the seeded graph500-style RMAT stream
(``repro.data.rmat``), the ``choose_src_bits`` 16→32 boundary at exactly
``p * sub_size == 2**16``, delta-flush compatibility with memmap-backed
partitions, and ``memory_report`` accounting.
"""
import numpy as np
import pytest

import repro.core.graph as G
from repro.core.engine import EngineOptions, run
from repro.core.partition import (
    PartitionConfig,
    coo_edge_chunks,
    partition_2d,
    partition_2d_streaming,
)
from repro.core.problems import bfs
from repro.data.rmat import RMATStream, materialize, rmat_chunks
from repro.data.synthetic import skewed_graph
from repro.kernels.csr_gather_reduce.ops import (
    DSTB16_LIMIT,
    SRC16_LIMIT,
    choose_src_bits,
)

# every array field whose bit-identity defines streaming == in-memory
IDENTITY_FIELDS = (
    "src_gidx", "dst_lidx", "valid", "weights", "bucket_sizes",
    "tile_word", "tile_word_hi", "tile_counts", "tile_weights",
    "tile_coverage", "tile_row_pos", "tile_row_orig", "tile_split_map",
    "push_word", "push_word_hi", "push_counts", "push_weights",
    "push_coverage",
)


def assert_identical(a, b):
    for name in IDENTITY_FIELDS:
        va, vb = getattr(a, name), getattr(b, name)
        assert (va is None) == (vb is None), name
        if va is not None:
            assert np.array_equal(np.asarray(va), np.asarray(vb)), name
    for name in ("p", "l", "sub_size", "num_vertices", "num_edges",
                 "src_bits", "split_rows", "push_block"):
        assert getattr(a, name) == getattr(b, name), name


def _hub_graph():
    """Two dominant hubs on a small vertex set: triggers hub-row splitting
    under a low threshold while staying sub-second to partition."""
    return skewed_graph(96, kind="star", hub_in_degree=600, num_hubs=2, seed=5)


def _sparse_graph(num_vertices, num_edges, seed=0, weighted=False):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, num_vertices, num_edges).astype(np.uint32)
    dst = rng.integers(0, num_vertices, num_edges).astype(np.uint32)
    w = rng.random(num_edges).astype(np.float32) if weighted else None
    return G.COOGraph(src=src, dst=dst, num_vertices=num_vertices, weights=w)


class TestChooseSrcBits:
    def test_src_boundary_exact(self):
        # 16-bit holds up to and INCLUDING 2**16 gathered offsets (the field
        # stores offsets 0..2**16-1; gathered_size is the exclusive bound)
        assert choose_src_bits(SRC16_LIMIT, 128) == 16
        assert choose_src_bits(SRC16_LIMIT + 1, 128) == 32

    def test_vb_boundary_exact(self):
        assert choose_src_bits(1024, DSTB16_LIMIT) == 16
        assert choose_src_bits(1024, DSTB16_LIMIT + 1) == 32

    def test_end_to_end_boundary(self):
        # p * sub_size == V / l. V = 2**17, l = 2 → gathered EXACTLY 2**16:
        # the last 16-bit layout. Doubling V crosses into the 32-bit regime
        # (hi words appear). Streaming must agree in both regimes.
        cfg = PartitionConfig(p=2, l=2, tile_vb=1024, build_push=False)
        for v_log2, bits in ((17, 16), (18, 32)):
            g = _sparse_graph(1 << v_log2, 400, seed=3)
            pg = partition_2d(g, cfg)
            assert pg.p * pg.sub_size == (1 << v_log2) // 2
            assert pg.src_bits == bits
            assert (pg.tile_word_hi is not None) == (bits == 32)
            ps = partition_2d_streaming(
                coo_edge_chunks(g, 64), g.num_vertices, cfg
            )
            assert_identical(ps, pg)


class TestStreamingIdentity:
    def test_split_map_mode(self):
        # forced low threshold → virtual rows → row_orig/split_map layout
        g = _hub_graph()
        cfg = PartitionConfig(p=2, l=2, tile_vb=16, tile_eb=16,
                              split_threshold=16)
        pg = partition_2d(g, cfg)
        assert pg.tile_row_orig is not None and pg.split_rows > 0
        ps = partition_2d_streaming(coo_edge_chunks(g, 97), g.num_vertices, cfg)
        assert_identical(ps, pg)

    def test_row_pos_mode(self):
        # splitting off, LPT balancing on → row_pos permutation layout
        g = _hub_graph()
        cfg = PartitionConfig(p=2, l=2, tile_vb=16, tile_eb=16,
                              split_threshold=None)
        pg = partition_2d(g, cfg)
        assert pg.tile_row_orig is None and pg.tile_row_pos is not None
        ps = partition_2d_streaming(coo_edge_chunks(g, 97), g.num_vertices, cfg)
        assert_identical(ps, pg)

    @pytest.mark.parametrize("split_threshold", [16, None])
    def test_engine_labels_agree(self, split_threshold):
        g = _hub_graph()
        cfg = PartitionConfig(p=2, l=2, tile_vb=16, tile_eb=16,
                              split_threshold=split_threshold)
        pg = partition_2d(g, cfg)
        ps = partition_2d_streaming(coo_edge_chunks(g, 97), g.num_vertices, cfg)
        prob = bfs(3)
        opts = EngineOptions(backend="xla")
        ra = run(prob, g, pg, opts)
        rb = run(prob, g, ps, opts)
        assert ra.iterations == rb.iterations
        assert np.array_equal(
            np.asarray(ra.labels["label"]), np.asarray(rb.labels["label"])
        )

    def test_chunk_size_invariance(self):
        g = _sparse_graph(256, 900, seed=7, weighted=True)
        cfg = PartitionConfig(p=2, l=2, tile_vb=32)
        ref = partition_2d_streaming(coo_edge_chunks(g, 1 << 20),
                                     g.num_vertices, cfg)
        for chunk in (1, 7, 113):
            ps = partition_2d_streaming(coo_edge_chunks(g, chunk),
                                        g.num_vertices, cfg)
            assert_identical(ps, ref)

    def test_stride_permutation(self):
        g = _sparse_graph(300, 700, seed=9)
        cfg = PartitionConfig(p=2, l=2, stride=10)
        ps = partition_2d_streaming(coo_edge_chunks(g, 41), g.num_vertices, cfg)
        assert_identical(ps, partition_2d(g, cfg))


class TestChunkProtocol:
    def test_one_shot_generator_rejected(self):
        g = _sparse_graph(64, 100)
        gen = ((g.src[i:i + 10], g.dst[i:i + 10]) for i in range(0, 100, 10))
        with pytest.raises(TypeError, match="replay"):
            partition_2d_streaming(gen, 64, PartitionConfig(p=2, l=2))

    def test_list_of_chunks_accepted(self):
        g = _sparse_graph(64, 100, seed=2)
        chunks = [(g.src[i:i + 33], g.dst[i:i + 33]) for i in range(0, 100, 33)]
        cfg = PartitionConfig(p=2, l=2)
        assert_identical(
            partition_2d_streaming(chunks, 64, cfg), partition_2d(g, cfg)
        )

    def test_empty_graph_one_empty_chunk(self):
        g = G.COOGraph(src=np.zeros(0, np.uint32), dst=np.zeros(0, np.uint32),
                       num_vertices=64)
        cfg = PartitionConfig(p=2, l=2)
        ps = partition_2d_streaming(coo_edge_chunks(g), 64, cfg)
        assert ps.num_edges == 0
        assert_identical(ps, partition_2d(g, cfg))

    def test_mixed_weighted_chunks_rejected(self):
        s = np.arange(8, dtype=np.int64)
        w = np.ones(8, np.float32)
        chunks = [(s, s, w), (s, s)]  # second chunk drops the weights
        with pytest.raises(ValueError, match="weight"):
            partition_2d_streaming(chunks, 64, PartitionConfig(p=2, l=2))

    def test_out_of_range_vertex_rejected(self):
        s = np.array([0, 70], dtype=np.int64)
        with pytest.raises(ValueError):
            partition_2d_streaming([(s, s)], 64, PartitionConfig(p=2, l=2))


class TestRMATStream:
    def test_deterministic_and_replayable(self):
        st = rmat_chunks(8, 8, seed=11, chunk_edges=500)
        a = [(s.copy(), d.copy()) for s, d in st()]
        b = list(st())
        assert len(a) == st.num_chunks
        for (sa, da), (sb, db) in zip(a, b):
            assert np.array_equal(sa, sb) and np.array_equal(da, db)
        other = rmat_chunks(8, 8, seed=12, chunk_edges=500)
        assert not all(
            np.array_equal(x[0], y[0]) for x, y in zip(a, other())
        )

    def test_counts_and_bounds(self):
        st = RMATStream(scale=7, edge_factor=4, seed=3, symmetric=True)
        g = materialize(st)
        assert st.num_vertices == 1 << 7
        assert g.num_edges == st.num_edges == 2 * 4 * (1 << 7)
        assert int(g.src.max()) < st.num_vertices
        assert int(g.dst.max()) < st.num_vertices

    def test_stream_is_valid_chunks_argument(self):
        st = rmat_chunks(8, 6, seed=5, chunk_edges=300, weighted=True)
        cfg = PartitionConfig(p=2, l=2, tile_vb=32)
        ps = partition_2d_streaming(st, st.num_vertices, cfg)
        assert_identical(ps, partition_2d(materialize(st), cfg))


class TestMemmapAndDelta:
    def test_memmap_identical_and_runs(self, tmp_path):
        st = rmat_chunks(8, 8, seed=1, chunk_edges=400)
        cfg = PartitionConfig(p=2, l=2, tile_vb=32)
        g = materialize(st)
        pg = partition_2d(g, cfg)
        pm = partition_2d_streaming(st, st.num_vertices, cfg,
                                    memmap_dir=str(tmp_path))
        assert isinstance(pm.tile_word, np.memmap)
        assert_identical(pm, pg)
        prob, opts = bfs(3), EngineOptions(backend="xla")
        ra, rb = run(prob, g, pg, opts), run(prob, g, pm, opts)
        assert np.array_equal(
            np.asarray(ra.labels["label"]), np.asarray(rb.labels["label"])
        )

    def test_delta_flush_against_memmap_partition(self, tmp_path):
        # the serving contract (serve/delta.py): a flush against a
        # memmap-backed partition must equal a cold rebuild of the grown
        # edge list — apply_edge_deltas reads bucket slices (memmap is an
        # ndarray subclass) and emits plain RAM arrays
        from repro.serve.delta import DeltaBuffer

        st = rmat_chunks(8, 8, seed=4, chunk_edges=300)
        cfg = PartitionConfig(p=2, l=2, tile_vb=32)
        pm = partition_2d_streaming(st, st.num_vertices, cfg,
                                    memmap_dir=str(tmp_path))
        buf = DeltaBuffer(pm)
        new_src = np.array([1, 33, 200, 7], dtype=np.int64)
        new_dst = np.array([250, 2, 9, 7], dtype=np.int64)
        buf.stage(new_src, new_dst)
        new_pg, report = buf.flush(pm)
        assert report.edges_added == 4
        # the flushed partition must not alias the on-disk build artifacts
        # (serve/delta.py promises they are deletable after the flush)
        assert not any(
            isinstance(getattr(new_pg, f), np.memmap)
            for f in IDENTITY_FIELDS
            if getattr(new_pg, f) is not None
        )

        g = materialize(st)
        grown = G.COOGraph(
            src=np.concatenate([np.asarray(g.src, np.int64), new_src]).astype(np.uint32),
            dst=np.concatenate([np.asarray(g.dst, np.int64), new_dst]).astype(np.uint32),
            num_vertices=g.num_vertices,
        )
        assert_identical(new_pg, partition_2d(grown, cfg))


class TestMemoryReport:
    def test_totals_and_fields(self):
        g = _sparse_graph(256, 800, seed=6)
        pg = partition_2d(g, PartitionConfig(p=2, l=2, tile_vb=32))
        rep = pg.memory_report()
        assert rep["device_total_bytes"] == sum(rep["device"].values())
        assert rep["host_flat_total_bytes"] == sum(rep["host_flat"].values())
        assert rep["total_bytes"] == (
            rep["device_total_bytes"] + rep["host_flat_total_bytes"]
        )
        assert rep["device"]["tile_word"] == pg.tile_word.nbytes
        assert rep["bytes_per_edge"] > rep["device_bytes_per_edge"] > 0
        assert "push_word" in rep["device"]

    def test_pull_only_drops_push_fields(self):
        g = _sparse_graph(256, 800, seed=6)
        pg = partition_2d(
            g, PartitionConfig(p=2, l=2, tile_vb=32, build_push=False)
        )
        rep = pg.memory_report()
        assert not any(k.startswith("push") for k in rep["device"])
