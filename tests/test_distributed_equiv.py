"""Distributed-vs-fused equivalence suite + the packed-stream structural
proof — what keeps ``core/distributed.py``'s claims honest:

  * ``run_distributed`` (compressed, channel-sharded, one device per memory
    channel) matches ``run(backend='pallas')`` BIT-IDENTICALLY for the min
    problems (BFS / WCC / SSSP) and to reassociation tolerance for PageRank,
    including a hub-split graph (two-level reduce crossing devices).
  * jaxpr inspection: the distributed engine's traced program consumes the
    packed ``tile_word`` + ``tile_counts`` stream and NEVER materializes a
    flat per-edge (l, E_pad) src/dst/valid array on any device — the
    single Pallas phase-reduce implementation is what runs on every channel.

Multi-device cases run in subprocesses with 8 forced host devices (jax locks
the device count at first init)."""
import subprocess
import sys
import textwrap

FLAGS = "--xla_force_host_platform_device_count=8"

# the same sum-reassociation contract as the single-process suite
_PR_TOL = "rtol=2e-5, atol=1e-8"


def run_sub(code: str):
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True,
        text=True,
        # JAX_PLATFORMS=cpu: the container ships libtpu; without the pin the
        # subprocess probes the (absent) TPU and collectives can hang
        env={"XLA_FLAGS": FLAGS, "PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
        timeout=900,
    )
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}"
    return res.stdout


PRELUDE = """
import numpy as np, jax, jax.numpy as jnp
import repro.dist  # jax>=0.6 API shims on 0.4.x
import repro.core.graph as G
from repro.core.partition import PartitionConfig, partition_2d
from repro.core.problems import bfs, wcc, sssp, pagerank
from repro.core.engine import EngineOptions, run
from repro.core.distributed import run_distributed, build_distributed_run
from repro.data.synthetic import skewed_graph
mesh4 = jax.make_mesh((4,), ("graph",), axis_types=(jax.sharding.AxisType.Auto,))
"""


def test_distributed_matches_fused_min_problems_bit_identical():
    """BFS/WCC/SSSP: channel-sharded compressed engine == fused single-process
    engine, labels AND iteration counts, with stride mapping on."""
    run_sub(
        PRELUDE
        + """
g = G.symmetrize(G.rmat(10, 8, seed=3))
pg = partition_2d(g, PartitionConfig(p=4, l=2, lane=4, stride=100))
for prob in (bfs(7), wcc(), sssp(7)):
    a = run(prob, g, pg, EngineOptions(backend="pallas"))
    b = run_distributed(prob, g, pg, mesh4)
    assert np.array_equal(a.labels["label"], b.labels["label"]), prob.name
    assert a.iterations == b.iterations, (prob.name, a.iterations, b.iterations)
    assert a.converged and b.converged, prob.name
print("OK")
"""
    )


def test_distributed_matches_fused_pagerank_tolerance():
    run_sub(
        PRELUDE
        + f"""
g = G.rmat(10, 8, seed=3)
pg = partition_2d(g, PartitionConfig(p=4, l=2, lane=4))
a = run(pagerank(tol=1e-5), g, pg, EngineOptions(backend="pallas"))
b = run_distributed(pagerank(tol=1e-5), g, pg, mesh4)
assert np.allclose(a.labels["label"], b.labels["label"], {_PR_TOL})
assert a.iterations == b.iterations
print("OK")
"""
    )


def test_distributed_matches_fused_on_hub_split_graph():
    """The two-level reduce (hub-row splitting) survives channel sharding:
    virtual-row partials fold on each device exactly as in-process."""
    run_sub(
        PRELUDE
        + f"""
g = skewed_graph(n=512, kind="star", hub_in_degree=1500, avg_degree=2, seed=7)
pg = partition_2d(g, PartitionConfig(p=4, l=2, lane=8, tile_vb=32))
assert pg.split_rows > 0, "graph must actually trigger hub splitting"
for prob in (bfs(3), wcc(), sssp(3)):
    a = run(prob, g, pg, EngineOptions(backend="pallas"))
    b = run_distributed(prob, g, pg, mesh4)
    assert np.array_equal(a.labels["label"], b.labels["label"]), prob.name
    assert a.iterations == b.iterations, prob.name
a = run(pagerank(tol=1e-4), g, pg, EngineOptions(backend="pallas"))
b = run_distributed(pagerank(tol=1e-4), g, pg, mesh4)
assert np.allclose(a.labels["label"], b.labels["label"], {_PR_TOL})
print("OK")
"""
    )


def test_distributed_dynamic_skip_matches_static_and_oracle():
    """Frontier-aware dynamic scheduling under channel sharding: the
    per-channel frontier words ride the crossbar, every device takes the same
    density-switch branch, and results + iteration counts stay bit-identical
    to both the static distributed schedule and the XLA oracle. The frontier
    engine (its changed-mask doubling as the exact live frontier) reaches the
    same fixed point."""
    run_sub(
        PRELUDE
        + """
from repro.core.frontier import run_distributed_frontier

g = G.symmetrize(G.rmat(10, 6, seed=11))
pg = partition_2d(g, PartitionConfig(p=4, l=2, lane=4, stride=100))
assert pg.tile_coverage is not None
static = EngineOptions(dynamic_tile_skip=False)
for prob in (bfs(2), wcc(), sssp(2)):
    x = run(prob, g, pg, EngineOptions(backend="xla"))
    d = run_distributed(prob, g, pg, mesh4)  # dynamic_tile_skip defaults on
    s = run_distributed(prob, g, pg, mesh4, opts=static)
    assert np.array_equal(d.labels["label"], x.labels["label"]), prob.name
    assert np.array_equal(s.labels["label"], x.labels["label"]), prob.name
    assert d.iterations == s.iterations == x.iterations, (
        prob.name, d.iterations, s.iterations, x.iterations)
    f, stats = run_distributed_frontier(prob, g, pg, mesh4, budget=64)
    assert np.array_equal(f.labels["label"], x.labels["label"]), prob.name
print("OK")
"""
    )


def test_distributed_streams_packed_words_only():
    """Structural proof (acceptance): the traced distributed program's inputs
    are the packed word/count (+ split-map) arrays, each device's sub-jaxpr
    touches the (1, l, R, T, Eb) shard, and NO flat per-edge int32/bool array
    — neither (p, l, E_pad) at the top level nor (l, E_pad)/(1, l, E_pad) per
    device — exists anywhere in the program. The single-process XLA oracle
    keeps its flat arrays (positive control elsewhere in the suite), so this
    check cannot pass vacuously."""
    run_sub(
        PRELUDE
        + """
from repro.core.engine import prepare_labels

g = G.symmetrize(G.rmat(9, 8, seed=5))
pg = partition_2d(g, PartitionConfig(p=4, l=2, lane=4))
prob = bfs(0)
run_fn = build_distributed_run(prob, pg, mesh4)
labels = prepare_labels(prob, g, pg)
jaxpr = jax.make_jaxpr(run_fn.traceable)(labels)

avals = []
def walk(jp):
    for vs in (jp.invars, jp.constvars):
        for v in vs:
            if hasattr(v, "aval") and hasattr(v.aval, "shape"):
                avals.append((tuple(v.aval.shape), str(v.aval.dtype)))
    for eqn in jp.eqns:
        for v in eqn.outvars:
            if hasattr(v, "aval") and hasattr(v.aval, "shape"):
                avals.append((tuple(v.aval.shape), str(v.aval.dtype)))
        for sub in jax.core.jaxprs_in_params(eqn.params):
            walk(sub.jaxpr if hasattr(sub, "jaxpr") else sub)
walk(jaxpr.jaxpr)
shapes = {s for s, _ in avals}

# the packed stream IS consumed: full stack at the top, one channel's shard
# ((1, l, R, T, Eb) word + (1, l, R) counts) inside the shard_map body
word_full = pg.tile_word.shape
counts_full = pg.tile_counts.shape
assert word_full in shapes, sorted(shapes)
assert counts_full in shapes
assert (1,) + word_full[1:] in shapes
assert (1,) + counts_full[1:] in shapes

# NO flat per-edge array on any device: every (..., l, E_pad) int32/bool
# aval is banned (the pre-refactor engine shipped three per device)
e_pad = pg.edge_pad
flat = [
    (s, d) for s, d in avals
    if len(s) >= 2 and s[-1] == e_pad and s[-2] == pg.l
    and d in ("int32", "bool")
]
assert not flat, flat
print("OK", len(avals))
"""
    )


def test_channel_shards_are_device_local():
    """place_channel_shards puts core q's packed stream on device q: the
    per-device shard of every array is the (1, ...) slice of its core."""
    run_sub(
        PRELUDE
        + """
from repro.core.distributed import place_channel_shards

g = G.symmetrize(G.rmat(9, 6, seed=2))
pg = partition_2d(g, PartitionConfig(p=4, l=2, lane=4))
consts = place_channel_shards(bfs(0), pg, mesh4, "graph")
assert consts["w"] is None  # BFS maps no edge weight
for key in ("word", "counts"):
    arr = consts[key]
    full = np.asarray(getattr(pg, "tile_" + ("word" if key == "word" else "counts")))
    for shard in arr.addressable_shards:
        q = shard.index[0].start or 0
        assert shard.data.shape == (1,) + full.shape[1:]
        np.testing.assert_array_equal(np.asarray(shard.data)[0], full[q])
print("OK")
"""
    )
