"""Direction-optimizing traversal (ISSUE 8): push-mode scatter engine with
per-iteration push/pull switching.

Equivalence contract: for min/or problems, every direction policy — pull-only
(the PR 6 schedule byte-for-byte), forced push, and the Beamer alpha/beta
'auto' switch — produces labels AND iteration counts bit-identical to the XLA
oracle, across the fused engine, the distributed engine, and the
frontier-compressed engine. Sum problems stay pull-only (scatter order across
skipped source blocks is arbitrary; only idempotent monotone reduces admit
it), so ``direction='push'`` on PageRank must raise.

Structural contract (mirror of the laneless-stream proof): a forced-push
iteration materializes NO per-phase (p, R, T, Eb) pull-side gather slice —
the push stream's source-binned (p, B, Tp, Ebp) slice is the only edge-word
intermediate — checked on the fused jaxpr here and on the distributed
shard_map jaxpr in the check-dist job.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.core.graph as G
from repro.core import frontier_words as fwords
from repro.core.engine import (
    EngineOptions,
    _make_iteration,
    prepare_labels,
    run,
    run_frontier_trace,
)
from repro.core.partition import PartitionConfig, partition_2d
from repro.core.problems import bfs, bfs_multi, pagerank, sssp, wcc
from repro.data.synthetic import path_grid_graph

from test_distributed import PRELUDE, run_sub

CFG = dict(p=2, l=2, lane=8, tile_vb=32, tile_eb=32)


def _shuffled_path(width=256, seed=11):
    return path_grid_graph(width, 1, shuffle=True, seed=seed)


def _weighted_rmat(seed=11):
    rng = np.random.default_rng(seed)
    g0 = G.symmetrize(G.rmat(8, 6, seed=seed))
    w = (rng.random(g0.num_edges) + 0.1).astype(np.float32)
    return G.COOGraph(src=g0.src, dst=g0.dst, num_vertices=g0.num_vertices,
                      weights=w)


def _bulge_graph(length=108, fan=20, at=54, seed=7):
    """A shuffled path with a ``fan``-leaf bulge at hop ``at``: the BFS
    wavefront popcount runs thin (1-3 bits), spikes to ~fan+1 when the hub is
    reached, then runs thin again — the deterministic band-crossing the
    hysteresis test needs. Returns (graph, root) with root at the path end."""
    src = list(range(length - 1)) + [at] * fan
    dst = list(range(1, length)) + list(range(length, length + fan))
    src, dst = np.asarray(src), np.asarray(dst)
    s, d = np.concatenate([src, dst]), np.concatenate([dst, src])
    n = length + fan
    perm = np.random.default_rng(seed).permutation(n).astype(np.uint32)
    g = G.COOGraph(src=perm[s], dst=perm[d], num_vertices=n)
    return g, int(perm[0])


def _assert_same_labels(prob, res, ref):
    for k in ref.labels:
        np.testing.assert_array_equal(
            np.asarray(res.labels[k]), np.asarray(ref.labels[k]))
    assert res.iterations == ref.iterations, (res.iterations, ref.iterations)


# ---------------------------------------------------------------------------
# forced-direction override: every policy is bit-identical for min/or
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("gname", ["path", "rmat"])
def test_forced_direction_bit_identical_min_problems(gname):
    if gname == "path":
        g, root = _shuffled_path(), 0
    else:
        g, root = G.symmetrize(G.rmat(8, 6, seed=13)), 3
    pg = partition_2d(g, PartitionConfig(**CFG))
    for prob in (bfs(root), wcc()):
        ref = run(prob, g, pg, EngineOptions(backend="xla"))
        for d in ("pull", "auto", "push"):
            res = run(prob, g, pg, EngineOptions(direction=d))
            _assert_same_labels(prob, res, ref)


def test_forced_push_sssp_bit_identical():
    g = _weighted_rmat()
    pg = partition_2d(g, PartitionConfig(**CFG))
    prob = sssp(3)
    ref = run(prob, g, pg, EngineOptions(backend="xla"))
    for d in ("pull", "auto", "push"):
        _assert_same_labels(prob, run(prob, g, pg, EngineOptions(direction=d)),
                            ref)


def test_forced_push_or_problem_lane_rows():
    """Packed multi-source BFS ('or' reduce): a push pass scatters each
    changed vertex's whole K-wide lane row; every dist column must still
    match the single-root runs."""
    g = _shuffled_path(128, seed=5)
    roots = [0, 31, 77, 90]
    pg = partition_2d(g, PartitionConfig(**CFG))
    prob = bfs_multi(roots)
    ref = run(prob, g, pg, EngineOptions(direction="pull"))
    for d in ("auto", "push"):
        res = run(prob, g, pg, EngineOptions(direction=d))
        _assert_same_labels(prob, res, ref)
    dist = np.asarray(ref.labels["dist"])
    for j, r in enumerate(roots):
        single = run(bfs(r), g, pg, EngineOptions(direction="push"))
        np.testing.assert_array_equal(dist[:, j], single.labels["label"])


def test_forced_push_requires_admissible_path():
    g = _shuffled_path(128, seed=5)
    pg = partition_2d(g, PartitionConfig(**CFG))
    # sum stays pull-only: scatter order across skipped blocks reassociates
    with pytest.raises(ValueError, match="push"):
        run(pagerank(tol=1e-4), g, pg, EngineOptions(direction="push"))
    # no partition-time push stream
    pg_nopush = partition_2d(g, PartitionConfig(**CFG, build_push=False))
    with pytest.raises(ValueError, match="push"):
        run(bfs(0), g, pg_nopush, EngineOptions(direction="push"))
    # dynamic scheduling off: the frontier carry feeds switch + active map
    with pytest.raises(ValueError, match="push"):
        run(bfs(0), g, pg, EngineOptions(direction="push",
                                         dynamic_tile_skip=False))
    with pytest.raises(ValueError, match="direction"):
        EngineOptions(direction="sideways")
    with pytest.raises(ValueError, match="alpha"):
        EngineOptions(direction_alpha=0.5, direction_beta=0.1)
    # ...but 'auto' on a pull-only partition silently stays pull
    res = run(bfs(0), g, pg_nopush, EngineOptions(direction="auto"))
    ref = run(bfs(0), g, pg_nopush, EngineOptions(direction="pull"))
    _assert_same_labels(bfs(0), res, ref)


# ---------------------------------------------------------------------------
# degenerate frontiers: all-push and all-pull runs
# ---------------------------------------------------------------------------


def test_all_push_run_start_narrow():
    """alpha = beta = 2.0: every popcount (even iteration 0's full frontier)
    sits below the threshold, so every iteration takes the push arm."""
    g, root = _shuffled_path(128, seed=5), 0
    pg = partition_2d(g, PartitionConfig(**CFG))
    opts = EngineOptions(direction="auto", direction_alpha=2.0,
                         direction_beta=2.0)
    trace = run_frontier_trace(bfs(root), g, pg, opts)
    assert set(trace["direction"]) == {"push"}, trace["direction"][:6]
    assert trace["push_iterations"] == trace["iterations"]
    ref = run(bfs(root), g, pg, EngineOptions(backend="xla"))
    np.testing.assert_array_equal(
        np.asarray(trace["labels"]["label"]), np.asarray(ref.labels["label"]))
    assert trace["iterations"] == ref.iterations


def test_all_pull_dense_frontier():
    # natural: BFS from the hub of a symmetrized pure star floods every leaf
    # in iteration 0 and converges on the wide frontier — the popcount never
    # drops into the push band
    g = G.symmetrize(G.star(256))
    pg = partition_2d(g, PartitionConfig(**CFG))
    trace = run_frontier_trace(bfs(0), g, pg, EngineOptions(direction="auto"))
    assert set(trace["direction"]) == {"pull"}, trace["direction"]
    assert trace["push_iterations"] == 0
    # degenerate thresholds: alpha = beta = 0 can never fire (pop < 0 is
    # false), so 'auto' runs pull-only even on a thin wavefront
    gp, root = _shuffled_path(128, seed=5), 0
    pgp = partition_2d(gp, PartitionConfig(**CFG))
    opts = EngineOptions(direction="auto", direction_alpha=0.0,
                         direction_beta=0.0)
    tr = run_frontier_trace(bfs(root), gp, pgp, opts)
    assert set(tr["direction"]) == {"pull"}
    ref = run(bfs(root), gp, pgp, EngineOptions(direction="pull"))
    np.testing.assert_array_equal(
        np.asarray(tr["labels"]["label"]), np.asarray(ref.labels["label"]))
    assert tr["iterations"] == ref.iterations


# ---------------------------------------------------------------------------
# the alpha/beta hysteresis band
# ---------------------------------------------------------------------------


def test_switch_hysteresis_stays_push_inside_band():
    """The bulge graph's popcount spikes into (alpha_thr, beta_thr) mid-run:
    with the band, hysteresis holds the push direction through the spike;
    with beta == alpha (no band), the same spike flips the engine back to
    pull for those iterations — and both runs stay bit-identical."""
    g, root = _bulge_graph()
    pg = partition_2d(g, PartitionConfig(**CFG))
    total_bits = pg.p * pg.l * pg.sub_size
    alpha = 8.5 / total_bits   # thr ~8: above the thin wavefront (1-3 bits)
    beta = 34.5 / total_bits   # thr ~34: above the ~21-bit bulge spike
    assert int(total_bits * alpha) > 4
    assert int(total_bits * alpha) < 21 < int(total_bits * beta)
    hyst = run_frontier_trace(
        bfs(root), g, pg,
        EngineOptions(direction="auto", direction_alpha=alpha,
                      direction_beta=beta))
    flat = run_frontier_trace(
        bfs(root), g, pg,
        EngineOptions(direction="auto", direction_alpha=alpha,
                      direction_beta=alpha))
    # iteration 0 always pulls (full frontier); the band then holds push
    # through the bulge spike...
    assert hyst["direction"][0] == "pull"
    assert set(hyst["direction"][1:]) == {"push"}, hyst["direction"]
    # ...while the no-band run flips back to pull at the spike and re-enters
    # push after it
    mid = flat["direction"][1:]
    assert "pull" in mid, flat["direction"]
    first_pull = 1 + mid.index("pull")
    assert "push" in flat["direction"][1:first_pull], flat["direction"]
    assert "push" in flat["direction"][first_pull:], flat["direction"]
    # both policies are schedule-only: identical labels and iteration counts
    np.testing.assert_array_equal(np.asarray(hyst["labels"]["label"]),
                                  np.asarray(flat["labels"]["label"]))
    assert hyst["iterations"] == flat["iterations"]


def test_multi_query_union_popcount_shifts_crossover():
    """K lanes switch per batch on the UNION popcount against a threshold
    scaled by 1/K: the same graph that runs all-push at K=1 falls back to
    pull for most iterations at K=4 (union frontier ~K-wide, threshold
    K-fold lower)."""
    g = _shuffled_path(256, seed=11)
    pg = partition_2d(g, PartitionConfig(**CFG))
    total_bits = pg.p * pg.l * pg.sub_size
    alpha = 12.5 / total_bits  # K=1 thr ~12; K=4 thr ~3
    opts = EngineOptions(direction="auto", direction_alpha=alpha,
                         direction_beta=alpha)
    tr1 = run_frontier_trace(bfs(0), g, pg, opts)
    tr4 = run_frontier_trace(bfs_multi([0, 64, 128, 192]), g, pg, opts)
    frac1 = tr1["push_iterations"] / tr1["iterations"]
    frac4 = tr4["push_iterations"] / tr4["iterations"]
    assert frac1 > 0.9, (tr1["push_iterations"], tr1["iterations"])
    assert frac4 < 0.5 * frac1, (frac4, frac1)


# ---------------------------------------------------------------------------
# structural: a push iteration reads no pull-side gather slice
# ---------------------------------------------------------------------------


def _aval_shapes(fn, *args):
    jaxpr = jax.make_jaxpr(fn)(*args)
    shapes = set()

    def walk(jp):
        for vs in (jp.invars, jp.constvars):
            for v in vs:
                if hasattr(v, "aval") and hasattr(v.aval, "shape"):
                    shapes.add((tuple(v.aval.shape), str(v.aval.dtype)))
        for eqn in jp.eqns:
            for v in eqn.outvars:
                if hasattr(v, "aval") and hasattr(v.aval, "shape"):
                    shapes.add((tuple(v.aval.shape), str(v.aval.dtype)))
            for sub in jax.core.jaxprs_in_params(eqn.params):
                walk(sub.jaxpr if hasattr(sub, "jaxpr") else sub)

    walk(jaxpr.jaxpr)
    return shapes


def test_push_iteration_jaxpr_has_no_pull_gather():
    """push_eb != tile_eb makes the two streams' slice shapes disjoint, so
    the assertion is unambiguous: the forced-push iteration's jaxpr carries
    the (p, B, Tp, Ebp) push slice and NO (p, R, T, Eb) pull slice — the
    dense pull-side gather never materializes."""
    g = _shuffled_path(128, seed=5)
    pg = partition_2d(g, PartitionConfig(**CFG, push_eb=128))
    pull_slice = (pg.p,) + pg.tile_word.shape[2:]
    push_slice = (pg.p,) + pg.push_word.shape[2:]
    assert pull_slice[-1] != push_slice[-1]  # disjoint by construction
    prob = bfs(0)
    labels = prepare_labels(prob, g, pg)
    fw0 = fwords.full_frontier_words(pg.l, pg.sub_size, lead=(pg.p,))
    shapes = _aval_shapes(
        _make_iteration(prob, pg, EngineOptions(direction="push")),
        labels, fw0, jnp.bool_(False))
    assert (push_slice, "int32") in shapes, sorted(shapes)
    assert (pull_slice, "int32") not in shapes, pull_slice
    # the auto iteration carries BOTH arms (the lax.cond chooses at runtime)
    shapes_auto = _aval_shapes(
        _make_iteration(prob, pg, EngineOptions(direction="auto")),
        labels, fw0, jnp.bool_(False))
    assert (push_slice, "int32") in shapes_auto
    assert (pull_slice, "int32") in shapes_auto


def test_push_jaxpr_distributed_no_pull_gather():
    """The same structural proof on the sharded engine: inside the shard_map
    body the per-channel forced-push iteration slices the (1, B, Tp, Ebp)
    push shard and never the (1, R, T, Eb) pull shard."""
    run_sub(
        PRELUDE
        + """
from repro.core.distributed import build_distributed_run
from repro.core.engine import EngineOptions, prepare_labels
from repro.core.partition import PartitionConfig, partition_2d
from repro.core.problems import bfs
from repro.data.synthetic import path_grid_graph

g = path_grid_graph(128, 1, shuffle=True, seed=5)
pg = partition_2d(g, PartitionConfig(p=4, l=2, lane=8, tile_vb=32,
                                     tile_eb=32, push_eb=128))
prob = bfs(0)
run_fn = build_distributed_run(prob, pg, mesh4,
                               opts=EngineOptions(direction="push"))
labels = prepare_labels(prob, g, pg)
jaxpr = jax.make_jaxpr(run_fn.traceable)(labels)

shapes = set()
def walk(jp):
    for vs in (jp.invars, jp.constvars):
        for v in vs:
            if hasattr(v, "aval") and hasattr(v.aval, "shape"):
                shapes.add((tuple(v.aval.shape), str(v.aval.dtype)))
    for eqn in jp.eqns:
        for v in eqn.outvars:
            if hasattr(v, "aval") and hasattr(v.aval, "shape"):
                shapes.add((tuple(v.aval.shape), str(v.aval.dtype)))
        for sub in jax.core.jaxprs_in_params(eqn.params):
            walk(sub.jaxpr if hasattr(sub, "jaxpr") else sub)
walk(jaxpr.jaxpr)

pull_slice = (1,) + pg.tile_word.shape[2:]
push_slice = (1,) + pg.push_word.shape[2:]
assert pull_slice[-1] != push_slice[-1]
assert (push_slice, "int32") in shapes, sorted(shapes)
assert (pull_slice, "int32") not in shapes, pull_slice
print("OK")
"""
    )


# ---------------------------------------------------------------------------
# distributed + frontier-compressed engines: same switch, same bits
# ---------------------------------------------------------------------------


def test_direction_switch_distributed_and_frontier_equiv():
    run_sub(
        PRELUDE
        + """
from repro.core.distributed import run_distributed
from repro.core.engine import EngineOptions, run
from repro.core.frontier import run_distributed_frontier
from repro.core.partition import PartitionConfig, partition_2d
from repro.core.problems import bfs, wcc
from repro.data.synthetic import path_grid_graph

g = path_grid_graph(96, 4, shuffle=True, seed=5)
pg = partition_2d(g, PartitionConfig(p=4, l=2, lane=8, tile_vb=32,
                                     tile_eb=32))
for prob in (bfs(0), wcc()):
    ref = run(prob, g, pg, EngineOptions(backend="xla"))
    for d in ("pull", "auto", "push"):
        opts = EngineOptions(direction=d)
        rd = run_distributed(prob, g, pg, mesh4, opts=opts)
        rf, _ = run_distributed_frontier(prob, g, pg, mesh4, opts=opts)
        for r in (rd, rf):
            for k in ref.labels:
                assert np.array_equal(r.labels[k], ref.labels[k]), (d, k)
            assert r.iterations == ref.iterations, (d, r.iterations)
print("OK")
"""
    )
