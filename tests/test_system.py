"""End-to-end system tests: the paper pipeline (load -> partition -> iterate
-> read back), engine x kernel integration, data pipeline determinism, and
cell construction for every (arch x shape) on a mini mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.graph as G
from repro.configs.registry import ARCHS
from repro.core.engine import EngineOptions, run
from repro.core.partition import PartitionConfig, partition_2d
from repro.core.problems import bfs, pagerank, wcc
from repro.core.reference import bfs_reference, pagerank_reference
from repro.data.neighbor_sampler import NeighborSampler
from repro.data.synthetic import lm_batch, recsys_batch


def test_paper_pipeline_end_to_end():
    """The full GraphScale flow of Fig. 8: host loads + partitions the graph,
    engine iterates with all optimizations on, labels come back in original
    vertex order, and the partitioned graph is reusable across problems."""
    g = G.symmetrize(G.rmat(11, 8, seed=9))
    pg = partition_2d(
        g, PartitionConfig(p=4, l=4, lane=8, stride=100, scratch_size=None)
    )
    # 1) BFS
    r_bfs = run(bfs(3), g, pg, EngineOptions(immediate_updates=True))
    assert np.array_equal(r_bfs.labels["label"], bfs_reference(g, 3))
    # 2) same partitions reused for WCC (paper: "partitioned graph can be
    #    used multiple times by loading new vertex labels")
    r_wcc = run(wcc(), g, pg, EngineOptions())
    assert r_wcc.converged
    # 3) PageRank on the directed graph
    gd = G.rmat(11, 8, seed=9)
    pgd = partition_2d(gd, PartitionConfig(p=4, l=2, lane=8))
    r_pr = run(pagerank(), gd, pgd, EngineOptions())
    np.testing.assert_allclose(r_pr.labels["label"], pagerank_reference(gd), atol=1e-4)


def test_scratch_size_derives_subintervals():
    g = G.symmetrize(G.rmat(10, 4, seed=1))
    pg = partition_2d(g, PartitionConfig(p=2, l=1, lane=8, scratch_size=128))
    assert pg.sub_size <= 128
    assert pg.l >= 2


def test_engine_kernel_tiles_path():
    """The Pallas accumulator (interpret mode) reproduces the engine's phase
    reduction on real partitioned data."""
    from repro.kernels.csr_gather_reduce import gather_reduce, prepare_tiles

    g = G.symmetrize(G.rmat(9, 6, seed=5))
    pg = partition_2d(g, PartitionConfig(p=2, l=2, lane=8))
    labels = np.full(pg.padded_vertices, 0xFFFFFFFF, dtype=np.uint32)
    labels[7] = 0
    labels = labels.reshape(pg.p, pg.vertices_per_core)
    m = 0
    payload = np.where(labels == 0xFFFFFFFF, labels, labels + 1)
    sub = payload[:, m * pg.sub_size : (m + 1) * pg.sub_size].reshape(-1)
    ident = float(np.uint32(0xFFFFFFFF))
    for core in range(pg.p):
        tiles = prepare_tiles(
            pg.src_gidx[core, m], pg.dst_lidx[core, m], pg.valid[core, m],
            num_rows=pg.vertices_per_core, vb=8, eb=16,
        )
        out_k = gather_reduce(jnp.asarray(sub), tiles, kind="min", identity=ident)
        ref = jax.ops.segment_min(
            jnp.where(jnp.asarray(pg.valid[core, m]),
                      jnp.asarray(sub)[pg.src_gidx[core, m]],
                      jnp.uint32(0xFFFFFFFF)),
            jnp.asarray(pg.dst_lidx[core, m]),
            num_segments=pg.vertices_per_core,
        )
        np.testing.assert_array_equal(np.asarray(out_k), np.asarray(ref))


def test_data_pipeline_deterministic():
    a = lm_batch(7, 42, 4, 16, 1000)
    b = lm_batch(7, 42, 4, 16, 1000)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = recsys_batch(1, 2, 8, 10, 100, 10)
    d = recsys_batch(1, 2, 8, 10, 100, 10)
    np.testing.assert_array_equal(c["hist_items"], d["hist_items"])
    e = recsys_batch(1, 3, 8, 10, 100, 10)
    assert not np.array_equal(c["hist_items"], e["hist_items"])


def test_neighbor_sampler_shapes_and_validity():
    g = G.symmetrize(G.rmat(12, 8, seed=0))
    s = NeighborSampler(g, fanouts=(5, 3), d_feat=16)
    batch, labels = s.sample(seed=0, step=0, batch_nodes=64)
    assert batch.node_feat.shape == (s.max_nodes(64), 16)
    assert batch.edge_src.shape == (s.max_edges(64),)
    ne = int(batch.edge_mask.sum())
    assert 0 < ne <= s.max_edges(64)
    src = np.asarray(batch.edge_src)[np.asarray(batch.edge_mask)]
    dst = np.asarray(batch.edge_dst)[np.asarray(batch.edge_mask)]
    nm = np.asarray(batch.node_mask)
    assert nm[src].all() and nm[dst].all()
    assert labels.shape == (64,)
    b2, _ = s.sample(seed=0, step=0, batch_nodes=64)
    np.testing.assert_array_equal(np.asarray(batch.edge_src), np.asarray(b2.edge_src))


def test_registry_has_all_ten_archs():
    from repro.configs.registry import ASSIGNED_IDS

    assert len(ASSIGNED_IDS) == 10
    assert set(ASSIGNED_IDS) <= set(ARCHS)
    assert {a.family for a in ARCHS.values()} == {"lm", "gnn", "recsys"}
    for arch in ARCHS.values():
        assert arch.smoke is not None
        assert len(arch.shapes) == 4


from conftest import requires_dist  # noqa: E402


@requires_dist
def test_all_cells_build_on_mini_mesh():
    """Cell construction (struct trees, spec trees, shardings) for every
    (arch x shape) — catches tree-structure mismatches without compiling."""
    from repro.launch.cells import build_cell

    mesh = jax.make_mesh(
        (1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2
    )
    from repro.configs.registry import ASSIGNED_IDS

    built = assigned = 0
    for arch in ARCHS.values():
        for shape in arch.shapes:
            cell = build_cell(arch, shape.name, mesh)
            jax.tree.map(lambda x: x, cell.args)  # validates tree structures
            assert cell.meta["model_flops"] > 0
            built += 1
            assigned += arch.arch_id in ASSIGNED_IDS
    assert assigned == 40  # the required 40 cells
    assert built == 4 * len(ARCHS)


def test_roofline_collective_parser():
    from repro.launch.roofline import collective_bytes

    hlo = """
  %ag = f32[16,1024]{1,0} all-gather(f32[16,64]{1,0} %x), replica_groups=[16,16]<=[256], dimensions={1}
  %ar = bf16[8,128]{1,0} all-reduce(bf16[8,128]{1,0} %y), replica_groups={{0,1,2,3}}, to_apply=%sum
  %cp = f32[4]{0} collective-permute(f32[4]{0} %z), source_target_pairs={{0,1}}
"""
    out = collective_bytes(hlo, 256)
    ag = 16 * 1024 * 4 * 15 / 16
    ar = 2 * 8 * 128 * 2 * 3 / 4
    cp = 16
    assert abs(out["bytes_by_kind"]["all-gather"] - ag) < 1
    assert abs(out["bytes_by_kind"]["all-reduce"] - ar) < 1
    assert abs(out["bytes_by_kind"]["collective-permute"] - cp) < 1
