"""Forward-port the jax >= 0.6 multi-device API onto jax 0.4.x.

The distributed engine, ``repro.dist``, and their tests are written against
the modern public surface — ``jax.shard_map`` (with ``check_vma``),
``jax.make_mesh(..., axis_types=...)``, ``jax.sharding.AxisType`` — while this
container ships jax 0.4.37, where the same machinery lives under
``jax.experimental.shard_map.shard_map`` (with ``check_rep``) and
``jax.make_mesh`` takes no ``axis_types``. Rather than forking every call
site, ``install()`` grafts thin adapters onto the ``jax`` namespace once, so
one codebase runs unmodified on both versions:

  * ``jax.shard_map``          -> experimental shard_map; ``check_vma`` maps to
                                  ``check_rep`` (same meaning: replication /
                                  varying-manual-axes checking).
  * ``jax.make_mesh``          -> wrapped to swallow ``axis_types`` (0.4.x
                                  meshes are implicitly Auto on every axis,
                                  which is exactly what the callers request).
  * ``jax.sharding.AxisType``  -> a stand-in enum with ``Auto`` / ``Explicit``
                                  members (only ever passed through to
                                  ``make_mesh``, never inspected).

On a modern jax every attribute already exists and ``install()`` is a no-op —
the adapters never shadow a real API. Imported (and installed) by
``repro.core.distributed``, ``repro.core.frontier``, ``repro.launch.mesh``,
and ``repro.dist``; import order therefore never matters for library code.
Scripts that call ``jax.make_mesh`` before importing any repro module must
import one of those first (the tests do).
"""
from __future__ import annotations

import enum
import functools

import jax

__all__ = ["install"]


class _AxisType(enum.Enum):
    """Stand-in for jax.sharding.AxisType on 0.4.x (Auto/Explicit/Manual)."""

    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def _make_shard_map():
    from jax.experimental.shard_map import shard_map as _legacy_shard_map

    @functools.wraps(_legacy_shard_map)
    def shard_map(f, mesh=None, in_specs=None, out_specs=None, **kwargs):
        # modern name -> legacy name; both toggle the replication checker
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _legacy_shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs
        )

    return shard_map


def _wrap_make_mesh(real_make_mesh):
    @functools.wraps(real_make_mesh)
    def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kwargs):
        # 0.4.x meshes have no axis types (everything is Auto) — drop them.
        return real_make_mesh(axis_shapes, axis_names, **kwargs)

    make_mesh._repro_compat = True
    return make_mesh


def install() -> None:
    """Idempotently install the modern-API adapters. No-op on jax >= 0.6."""
    if not hasattr(jax, "shard_map"):
        jax.shard_map = _make_shard_map()
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _AxisType
        # make_mesh exists on 0.4.37 but rejects axis_types; wrap it so call
        # sites written for the modern signature work. Only wrap when AxisType
        # itself was missing (i.e. we are definitely on the legacy API).
        if not getattr(jax.make_mesh, "_repro_compat", False):
            jax.make_mesh = _wrap_make_mesh(jax.make_mesh)


install()
