"""Graph data structures and synthetic generators.

Host-side construction is numpy (the paper's host code loads + partitions the
graph on the CPU before transferring partitions to device memory); device-side
structures are jnp arrays with static shapes.

Two representations, mirroring the paper's Fig. 1 trade-off:
  * ``COOGraph``   — edge list (src, dst), 8 bytes/edge. What HitGraph/ThunderGP
                     consume (synchronous edge-centric baselines).
  * ``CSRGraph``   — compressed sparse row, 4 bytes/edge + 4 bytes/vertex
                     pointers. What GraphScale consumes (inverse CSR: row v
                     stores the *in*-neighbors of v, enabling pull-based flow).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

__all__ = [
    "COOGraph",
    "CSRGraph",
    "coo_to_csr",
    "csr_to_coo",
    "inverse_coo",
    "symmetrize",
    "deduplicate",
    "out_degrees",
    "in_degrees",
    "rmat",
    "erdos_renyi",
    "grid_2d",
    "chain",
    "star",
    "complete",
    "karate_club",
    "bytes_per_edge",
]


@dataclasses.dataclass(frozen=True)
class COOGraph:
    """Edge-list graph. ``src[i] -> dst[i]`` is a directed edge."""

    src: np.ndarray  # (E,) uint32
    dst: np.ndarray  # (E,) uint32
    num_vertices: int
    weights: Optional[np.ndarray] = None  # (E,) float32 (SSSP)

    @property
    def num_edges(self) -> int:
        return int(self.src.shape[0])

    def validate(self) -> "COOGraph":
        assert self.src.shape == self.dst.shape
        if self.num_edges:
            assert int(self.src.max()) < self.num_vertices
            assert int(self.dst.max()) < self.num_vertices
        if self.weights is not None:
            assert self.weights.shape == self.src.shape
        return self


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """CSR adjacency. ``indices[indptr[v]:indptr[v+1]]`` are v's neighbors.

    When built via ``inverse_coo`` + ``coo_to_csr`` this is the paper's
    *inverse* CSR: row v holds the in-neighbors of v (pull-based data flow).
    """

    indptr: np.ndarray  # (V+1,) uint64-safe int64
    indices: np.ndarray  # (E,) uint32
    num_vertices: int
    weights: Optional[np.ndarray] = None  # (E,) aligned with indices

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]


def coo_to_csr(g: COOGraph) -> CSRGraph:
    """Sort edges by src and build pointer array (row = src)."""
    order = np.argsort(g.src, kind="stable")
    src = g.src[order]
    indices = g.dst[order].astype(np.uint32)
    weights = g.weights[order] if g.weights is not None else None
    counts = np.bincount(src, minlength=g.num_vertices)
    indptr = np.zeros(g.num_vertices + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSRGraph(indptr=indptr, indices=indices, num_vertices=g.num_vertices, weights=weights)


def csr_to_coo(g: CSRGraph) -> COOGraph:
    src = np.repeat(
        np.arange(g.num_vertices, dtype=np.uint32), np.diff(g.indptr).astype(np.int64)
    )
    return COOGraph(src=src, dst=g.indices.astype(np.uint32), num_vertices=g.num_vertices, weights=g.weights)


def inverse_coo(g: COOGraph) -> COOGraph:
    """Reverse every edge. inverse + coo_to_csr == the paper's inverse CSR."""
    return COOGraph(src=g.dst, dst=g.src, num_vertices=g.num_vertices, weights=g.weights)


def symmetrize(g: COOGraph) -> COOGraph:
    """Add reverse edges (WCC works on the undirected closure)."""
    src = np.concatenate([g.src, g.dst])
    dst = np.concatenate([g.dst, g.src])
    w = np.concatenate([g.weights, g.weights]) if g.weights is not None else None
    return deduplicate(COOGraph(src=src, dst=dst, num_vertices=g.num_vertices, weights=w))


def deduplicate(g: COOGraph) -> COOGraph:
    key = g.src.astype(np.int64) * g.num_vertices + g.dst.astype(np.int64)
    _, idx = np.unique(key, return_index=True)
    w = g.weights[idx] if g.weights is not None else None
    return COOGraph(src=g.src[idx], dst=g.dst[idx], num_vertices=g.num_vertices, weights=w)


def out_degrees(g: COOGraph) -> np.ndarray:
    return np.bincount(g.src, minlength=g.num_vertices).astype(np.int64)


def in_degrees(g: COOGraph) -> np.ndarray:
    return np.bincount(g.dst, minlength=g.num_vertices).astype(np.int64)


# ---------------------------------------------------------------------------
# Generators (Table III stand-ins; no network access in this container, so the
# real-world SNAP graphs are replaced by generators with matched statistics).
# ---------------------------------------------------------------------------


def rmat(
    scale: int,
    edge_factor: int = 16,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
    dedup: bool = True,
) -> COOGraph:
    """Graph500 R-MAT generator (the paper's rmat-24-16 / rmat-21-86)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab, abc = a + b, a + b + c
    for _bit in range(scale):
        # quadrant probabilities: a (00), b (01), c (10), d (11)
        r = rng.random(m)
        src_bit = (r >= ab).astype(np.int64)  # quadrant c or d -> src high bit
        dst_bit = (((r >= a) & (r < ab)) | (r >= abc)).astype(np.int64)  # b or d
        src = (src << 1) | src_bit
        dst = (dst << 1) | dst_bit
    g = COOGraph(src=src.astype(np.uint32), dst=dst.astype(np.uint32), num_vertices=n)
    return deduplicate(g) if dedup else g


def erdos_renyi(n: int, m: int, seed: int = 0) -> COOGraph:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=m, dtype=np.int64)
    dst = rng.integers(0, n, size=m, dtype=np.int64)
    keep = src != dst
    return deduplicate(
        COOGraph(src=src[keep].astype(np.uint32), dst=dst[keep].astype(np.uint32), num_vertices=n)
    )


def grid_2d(rows: int, cols: int) -> COOGraph:
    """Road-network-like high-diameter graph (roadnet-ca stand-in)."""
    idx = np.arange(rows * cols).reshape(rows, cols)
    right = np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], axis=0)
    down = np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], axis=0)
    e = np.concatenate([right, down], axis=1)
    g = COOGraph(src=e[0].astype(np.uint32), dst=e[1].astype(np.uint32), num_vertices=rows * cols)
    return symmetrize(g)


def chain(n: int) -> COOGraph:
    src = np.arange(n - 1, dtype=np.uint32)
    return COOGraph(src=src, dst=src + 1, num_vertices=n)


def star(n: int) -> COOGraph:
    """Hub 0 -> spokes 1..n-1 (wiki-talk-like low average degree)."""
    dst = np.arange(1, n, dtype=np.uint32)
    return COOGraph(src=np.zeros(n - 1, dtype=np.uint32), dst=dst, num_vertices=n)


def complete(n: int) -> COOGraph:
    s, d = np.meshgrid(np.arange(n), np.arange(n), indexing="ij")
    keep = s != d
    return COOGraph(
        src=s[keep].astype(np.uint32), dst=d[keep].astype(np.uint32), num_vertices=n
    )


def karate_club() -> COOGraph:
    """Zachary's karate club — a tiny real graph embedded for exact oracles."""
    edges = [
        (0, 1), (0, 2), (0, 3), (0, 4), (0, 5), (0, 6), (0, 7), (0, 8), (0, 10),
        (0, 11), (0, 12), (0, 13), (0, 17), (0, 19), (0, 21), (0, 31), (1, 2),
        (1, 3), (1, 7), (1, 13), (1, 17), (1, 19), (1, 21), (1, 30), (2, 3),
        (2, 7), (2, 8), (2, 9), (2, 13), (2, 27), (2, 28), (2, 32), (3, 7),
        (3, 12), (3, 13), (4, 6), (4, 10), (5, 6), (5, 10), (5, 16), (6, 16),
        (8, 30), (8, 32), (8, 33), (9, 33), (13, 33), (14, 32), (14, 33),
        (15, 32), (15, 33), (18, 32), (18, 33), (19, 33), (20, 32), (20, 33),
        (22, 32), (22, 33), (23, 25), (23, 27), (23, 29), (23, 32), (23, 33),
        (24, 25), (24, 27), (24, 31), (25, 31), (26, 29), (26, 33), (27, 33),
        (28, 31), (28, 33), (29, 32), (29, 33), (30, 32), (30, 33), (31, 32),
        (31, 33), (32, 33),
    ]
    e = np.asarray(edges, dtype=np.uint32)
    return COOGraph(src=e[:, 0], dst=e[:, 1], num_vertices=34)


def bytes_per_edge(g: COOGraph, compressed: bool) -> float:
    """Fig. 1 metric: memory traffic per edge for edge-list vs CSR."""
    if compressed:
        return (4.0 * g.num_edges + 4.0 * (g.num_vertices + 1)) / max(g.num_edges, 1)
    return 8.0 * g.num_edges / max(g.num_edges, 1)
