"""Asynchronous pull-based vertex-centric engine (paper §III-A/B) —
single-process reference implementation.

The ``p`` graph cores are simulated with a leading array dimension and
``vmap``-style batched ops; the crossbar is the phase-m gathered label block
(see ``core/distributed.py`` for the multi-device shard_map engine whose
numerics match this one exactly — tested).

Execution structure per iteration (paper Fig. 4):
  for phase m in range(l):                  # meta-partition M_m
    1. prefetch: slice sub-interval m of every core's payload and concatenate
       -> gathered block (the label scratch pads, crossbar-visible)
    2. process: gather per-edge source payloads, apply the map UDF, reduce by
       destination (the prefix-adder accumulator), and
    3. apply: min-problems with ``immediate_updates`` merge into the live
       label array NOW (asynchronous — later phases of this iteration see the
       new labels); otherwise contributions accumulate and merge at iteration
       end (synchronous / Jacobi).

Shapes are static; invalid (padding) edges contribute the reduce identity.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import PartitionedGraph
from repro.core.problems import Problem

__all__ = ["EngineOptions", "EngineResult", "prepare_labels", "run", "unpad_labels"]


@dataclasses.dataclass(frozen=True)
class EngineOptions:
    immediate_updates: bool = True  # paper opt 1: async write-back to scratch
    prefetch_skipping: bool = True  # paper opt 2: skip re-prefetch when l == 1
    max_iters: int = 1000
    use_kernel: bool = False  # route segment-reduce through the Pallas kernel
    kernel_interpret: bool = True  # interpret mode (CPU validation)


@dataclasses.dataclass
class EngineResult:
    labels: Dict[str, np.ndarray]  # unpadded, original vertex ids
    iterations: int
    converged: bool


def prepare_labels(problem: Problem, g, pg: PartitionedGraph) -> Dict[str, jnp.ndarray]:
    """Init labels on host, apply stride permutation, reshape to (p, Vl)."""
    padded = pg.padded_vertices
    labels = problem.init_labels(g, padded)
    out = {}
    for k, v in labels.items():
        v = np.asarray(v)
        if v.ndim == 1 and v.shape[0] == padded:
            if pg.perm is not None:
                # perm is a bijection on [0, V): every slot < V is re-assigned,
                # slots >= V keep their natural padding init values.
                moved = v.copy()
                moved[pg.perm[: pg.num_vertices]] = v[: pg.num_vertices]
                v = moved
            v = v.reshape(pg.p, pg.vertices_per_core)
        out[k] = jnp.asarray(v)
    return out


def unpad_labels(
    labels: Dict[str, jnp.ndarray], pg: PartitionedGraph
) -> Dict[str, np.ndarray]:
    """Back to original vertex ids (undo stride permutation + padding)."""
    out = {}
    for k, v in labels.items():
        v = np.asarray(v)
        if v.ndim == 2 and v.shape == (pg.p, pg.vertices_per_core):
            flat = v.reshape(-1)
            if pg.perm is not None:
                flat = flat[pg.perm[: pg.num_vertices]]
            else:
                flat = flat[: pg.num_vertices]
            out[k] = flat
        else:
            out[k] = v
    return out


def _segment_reduce(kind: str, contrib, dst, num_segments: int, identity):
    if kind == "min":
        return jax.ops.segment_min(
            contrib, dst, num_segments=num_segments, indices_are_sorted=True
        )
    return jax.ops.segment_sum(
        contrib, dst, num_segments=num_segments, indices_are_sorted=True
    )


def _phase_contributions(problem: Problem, pg: PartitionedGraph, labels, m, opts):
    """Steps 1+2: prefetch (gather crossbar block) and process (map+reduce)."""
    payload = problem.src_transform(labels)  # (p, Vl) elementwise
    # prefetch phase: sub-interval m of every core -> gathered block (p*sub,)
    sub = jax.lax.dynamic_slice_in_dim(payload, m * pg.sub_size, pg.sub_size, axis=1)
    gathered = sub.reshape(pg.gathered_size)

    src_gidx = jnp.asarray(pg.src_gidx)  # (p, l, E)
    dst_lidx = jnp.asarray(pg.dst_lidx)
    valid = jnp.asarray(pg.valid)
    sg = jax.lax.dynamic_index_in_dim(src_gidx, m, axis=1, keepdims=False)  # (p, E)
    dl = jax.lax.dynamic_index_in_dim(dst_lidx, m, axis=1, keepdims=False)
    vm = jax.lax.dynamic_index_in_dim(valid, m, axis=1, keepdims=False)
    w = None
    if pg.weights is not None:
        w = jax.lax.dynamic_index_in_dim(jnp.asarray(pg.weights), m, axis=1, keepdims=False)

    svals = jnp.take(gathered, sg, axis=0)  # (p, E) crossbar label reads
    contrib = problem.edge_map(svals, w)
    identity = jnp.asarray(problem.identity, dtype=contrib.dtype)
    contrib = jnp.where(vm, contrib, identity)

    if opts.use_kernel:
        from repro.kernels.csr_gather_reduce import ops as kops

        reduced = kops.segment_reduce_rows(
            contrib,
            dl,
            num_rows=pg.vertices_per_core,
            kind=problem.reduce_kind,
            identity=problem.identity,
            interpret=opts.kernel_interpret,
        )
    else:
        reduced = jax.vmap(
            lambda c, d: _segment_reduce(
                problem.reduce_kind, c, d, pg.vertices_per_core, identity
            )
        )(contrib, dl)  # (p, Vl)
    return reduced


def _make_iteration(problem: Problem, pg: PartitionedGraph, opts: EngineOptions):
    is_min = problem.reduce_kind == "min"

    if is_min and opts.immediate_updates:

        def iteration(labels):
            def phase(m, labels):
                reduced = _phase_contributions(problem, pg, labels, m, opts)
                lab = labels[problem.merge_field]
                merged = jnp.minimum(lab, reduced.astype(lab.dtype))
                new = dict(labels)
                new[problem.merge_field] = merged
                return new

            return jax.lax.fori_loop(0, pg.l, phase, labels)

        return iteration

    # synchronous path: accumulate contributions, apply at iteration end
    def iteration(labels):
        lab = labels[problem.merge_field]
        acc_dtype = jnp.float32 if problem.reduce_kind == "sum" else lab.dtype
        acc0 = jnp.full(lab.shape, problem.identity, dtype=acc_dtype)

        def phase(m, acc):
            reduced = _phase_contributions(problem, pg, labels, m, opts)
            if problem.reduce_kind == "min":
                return jnp.minimum(acc, reduced.astype(acc.dtype))
            return acc + reduced.astype(acc.dtype)

        acc = jax.lax.fori_loop(0, pg.l, phase, acc0)
        if problem.reduce_kind == "min":
            new = dict(labels)
            new[problem.merge_field] = jnp.minimum(lab, acc.astype(lab.dtype))
            return new
        return problem.finalize(labels, acc)

    return iteration


@partial(jax.jit, static_argnames=("problem", "pg", "opts"))
def _run_jit(problem, pg, opts, labels):
    iteration = _make_iteration(problem, pg, opts)

    def cond(carry):
        _, it, changed = carry
        return jnp.logical_and(changed, it < opts.max_iters)

    def body(carry):
        labels, it, _ = carry
        new = iteration(labels)
        changed = problem.not_converged(labels, new)
        return new, it + 1, changed

    labels, iters, changed = jax.lax.while_loop(
        cond, body, (labels, jnp.int32(0), jnp.bool_(True))
    )
    return labels, iters, changed


_WRAP_CACHE: dict = {}


def _wrap(obj):
    """Identity-hashed static wrapper, cached so repeated runs share jit cache."""
    key = id(obj)
    hit = _WRAP_CACHE.get(key)
    if hit is not None and hit[0] is obj:
        return hit[1]
    w = _Hashable(obj)
    _WRAP_CACHE[key] = (obj, w)  # keep obj alive so id stays valid
    return w


def run(
    problem: Problem, g, pg: PartitionedGraph, opts: EngineOptions = EngineOptions()
) -> EngineResult:
    labels = prepare_labels(problem, g, pg)
    # opts is a frozen dataclass of primitives: hashable BY VALUE, so fresh
    # EngineOptions() instances hit the jit cache (id-wrapping it caused a
    # recompile per call — caught because benchmarks timed compiles).
    labels, iters, changed = _run_jit(_wrap(problem), _wrap(pg), opts, labels)
    return EngineResult(
        labels=unpad_labels(labels, pg),
        iterations=int(iters),
        converged=not bool(changed),
    )


class _Hashable:
    """Identity-hashed wrapper so dataclasses with arrays can be static args."""

    def __init__(self, obj):
        self._obj = obj

    def __getattr__(self, name):
        return getattr(self._obj, name)

    def __hash__(self):
        return id(self._obj)

    def __eq__(self, other):
        return isinstance(other, _Hashable) and self._obj is other._obj
