"""Asynchronous pull-based vertex-centric engine (paper §III-A/B) —
single-process reference implementation.

The ``p`` graph cores are simulated with a leading array dimension and
``vmap``-style batched ops; the crossbar is the phase-m gathered label block
(see ``core/distributed.py`` for the multi-device shard_map engine whose
numerics match this one exactly — tested).

Execution structure per iteration (paper Fig. 4):
  for phase m in range(l):                  # meta-partition M_m
    1. prefetch: slice sub-interval m of every core's payload and concatenate
       -> gathered block (the label scratch pads, crossbar-visible)
    2. process: gather per-edge source payloads, apply the map UDF, reduce by
       destination (the prefix-adder accumulator), and
    3. apply: min-problems with ``immediate_updates`` merge into the live
       label array NOW (asynchronous — later phases of this iteration see the
       new labels); otherwise contributions accumulate and merge at iteration
       end (synchronous / Jacobi).

Shapes are static; invalid (padding) edges contribute the reduce identity.

Two step-2 backends, selected by ``EngineOptions.backend``:

  * ``'pallas'`` (default — the primary path): one fused ``pallas_call`` per
    phase over grid (p, R, T) executes gather + edge map (incl. the SSSP
    saturating add) + segment reduce per tile, with the phase's gathered
    crossbar block resident in VMEM. The edge stream it reads is COMPRESSED
    (paper §III): each slot is one bit-packed int32 word (src | dstb | valid,
    decoded in-kernel; ``tile_word_hi`` carries the 32-bit-src fallback) and
    scalar-prefetched per-row-block tile counts let the kernel skip padding
    tiles outright. Per-edge values only ever exist in (Eb,)-tile registers —
    neither a (p, E_pad) contributions array nor an unpacked per-edge index
    array is materialized (the bandwidth property the paper's compressed
    accumulator is built around; asserted by jaxpr inspection in tests).
    Consumes the partition-time (p, l, R, T, Eb) packed stream on
    ``PartitionedGraph``; runs in interpret mode on CPU
    (``kernel_interpret=True``, correctness-grade timings) and compiled on
    real TPUs. Hub rows split at partition time reduce as independent
    virtual rows in-kernel (level 1); a gather-based second-level combine
    (``combine_split_rows``, the problem's reduce op + identity over the
    ``tile_split_map``) folds the partials into true rows before apply.
  * ``'xla'`` — the correctness oracle: materializes the (p, E_pad)
    contributions array via take/where and segment-reduces it. Bit-identical
    to the Pallas path for min problems; for sum problems (PageRank) results
    agree to float-summation-order reassociation.

Edge-index constants are converted to device arrays ONCE per trace, outside
the phase ``fori_loop`` body (they used to be re-wrapped per phase).

Frontier-aware dynamic tile scheduling (``EngineOptions.dynamic_tile_skip``,
on by default): min problems on the Pallas backend additionally carry a
frontier bitmap (``core/frontier_words.py``) across iterations — the packed
words of "which sources changed" — and each phase ANDs the partition-time
per-tile coverage bitmaps against the live frontier to skip REAL tiles none
of whose sources changed, on top of the static padding-tile skip. A density
switch (``lax.cond`` on frontier popcount vs ``dynamic_skip_density``) falls
back to the dense all-real-tiles path while the frontier is wide, and the
frontier doubles as the convergence check (empty frontier == converged),
replacing the separate ``not_converged`` label diff. The async path augments
the live frontier per phase with this iteration's merges, which makes the
dynamic schedule BIT-IDENTICAL per iteration to the dense async schedule
(monotone-min argument: every skipped tile's sources are unchanged since the
tile last ran, so its contributions are already merged) — same labels, same
iteration counts, just fewer tiles streamed.

Multi-query lane batching (docs/tile_layout.md §8): a ``Problem`` with
``lanes = K > 0`` carries a trailing lane axis on its payload — packed reach
words for multi-source BFS (``reduce_kind='or'``), a (…, K) label block for
SSSP/PPR — and one ``channel_phase_reduce_pallas`` launch updates all K
queries per tile decode; the compressed 4 B/edge word stream is fetched once
per tile regardless of K. 'or' problems always execute the synchronous
(level-synchronized) schedule — async multi-hop propagation within one
iteration would corrupt the level counter that recovers hop distances — and
stay eligible for dynamic tile skipping (OR is monotone like min); the
frontier words are the UNION over lanes, so a converged lane stops
contributing to the schedule without stopping the batch.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from functools import partial
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import frontier_words as fwords
from repro.core.partition import PartitionedGraph
from repro.core.problems import Problem

__all__ = [
    "EngineOptions",
    "EngineResult",
    "prepare_labels",
    "run",
    "run_frontier_trace",
    "unpad_labels",
    "make_iteration",
    "evict_from_cache",
    "dynamic_skip_enabled",
    "push_enabled",
    "channel_phase_reduce_pallas",
    "channel_phase_scatter_pallas",
    "channel_phase_reduce_xla",
]


_BACKENDS = ("pallas", "xla")


@dataclasses.dataclass(frozen=True)
class EngineOptions:
    immediate_updates: bool = True  # paper opt 1: async write-back to scratch
    prefetch_skipping: bool = True  # paper opt 2: skip re-prefetch when l == 1
    max_iters: int = 1000
    # 'pallas': fused gather-map-reduce kernel, the primary path (one launch
    # per phase covers all p cores). 'xla': materialize-then-reduce oracle.
    backend: str = "pallas"
    kernel_interpret: bool = True  # Pallas interpret mode (CPU); False on TPU
    # frontier-aware dynamic tile scheduling (min problems, pallas backend):
    # skip real tiles whose coverage bitmap misses the live frontier. Safe to
    # leave on: results and iteration counts are identical to the static
    # schedule (see module docstring); it only changes which tiles stream.
    dynamic_tile_skip: bool = True
    # dense fallback: while frontier popcount >= density * total source bits,
    # run the static all-real-tiles schedule (the coverage AND would skip
    # almost nothing on a wide frontier). 0.0 = always dense (static
    # schedule via the dynamic carry); > 1.0 = never dense.
    dynamic_skip_density: float = 0.5
    # multi-query lane batching: expected lane count K. None = accept whatever
    # the problem declares (including laneless); an int pins the batch width —
    # a mismatched problem raises, which is the serving loop's admission check
    # that a batch was assembled to the width the jit cache is warm for.
    lanes: int | None = None
    # direction-optimizing traversal (Beamer push/pull, docs/tile_layout.md
    # §9). 'auto' switches per iteration on the union-frontier popcount:
    # enter push below alpha * total source bits, stay push below beta
    # (hysteresis; both scaled by 1/K for a K-lane batch, since a push pass
    # scatters each vertex's whole lane row). 'push'/'pull' force one
    # direction — 'push' raises unless the problem/partition admit it.
    direction: str = "auto"
    direction_alpha: float = 0.02
    direction_beta: float = 0.1

    def __post_init__(self):
        if self.backend not in _BACKENDS:
            raise ValueError(f"backend must be one of {_BACKENDS}, got {self.backend!r}")
        if self.lanes is not None and self.lanes < 0:
            raise ValueError(f"lanes must be None or >= 0, got {self.lanes}")
        if self.direction not in ("auto", "push", "pull"):
            raise ValueError(
                f"direction must be 'auto', 'push' or 'pull', got {self.direction!r}"
            )
        if not 0.0 <= self.direction_alpha <= self.direction_beta:
            raise ValueError(
                "need 0 <= direction_alpha <= direction_beta, got "
                f"{self.direction_alpha} / {self.direction_beta}"
            )


def dynamic_skip_enabled(problem, pg, opts: EngineOptions) -> bool:
    """Frontier skipping is sound only for monotone reduces — min, and the
    bitwise OR of packed multi-source BFS lanes (a skipped tile's sources
    re-contribute values already merged); sum problems (PageRank) need every
    contribution every iteration and stay dense. Also requires the Pallas
    backend (the oracle materializes everything anyway) and partition-time
    coverage bitmaps."""
    return bool(
        opts.dynamic_tile_skip
        and opts.backend == "pallas"
        and problem.reduce_kind in ("min", "or")
        and getattr(pg, "tile_coverage", None) is not None
    )


def push_enabled(problem, pg, opts: EngineOptions) -> bool:
    """Push (scatter) direction is admissible: an idempotent monotone reduce
    (min/or — a skipped source block's contributions are already merged, and
    scatter order across blocks is arbitrary; sum stays pull-only), the
    Pallas backend, a partition-time push stream, and dynamic scheduling (the
    frontier carry is what both the switch and the push active map read).
    ``direction='pull'`` opts out entirely."""
    return bool(
        opts.direction != "pull"
        and getattr(pg, "push_word", None) is not None
        and dynamic_skip_enabled(problem, pg, opts)
    )


@dataclasses.dataclass
class EngineResult:
    labels: Dict[str, np.ndarray]  # unpadded, original vertex ids
    iterations: int
    converged: bool


def prepare_labels(problem: Problem, g, pg: PartitionedGraph) -> Dict[str, jnp.ndarray]:
    """Init labels on host, apply stride permutation, reshape to (p, Vl).

    Lane-batched label fields arrive as (padded, L) — K vector lanes or
    packed reach words — and become (p, Vl, L): the permutation moves rows,
    the lane axis rides along untouched."""
    padded = pg.padded_vertices
    labels = problem.init_labels(g, padded)
    out = {}
    for k, v in labels.items():
        v = np.asarray(v)
        if v.ndim in (1, 2) and v.shape[0] == padded:
            if pg.perm is not None:
                # perm is a bijection on [0, V): every slot < V is re-assigned,
                # slots >= V keep their natural padding init values.
                moved = v.copy()
                moved[pg.perm[: pg.num_vertices]] = v[: pg.num_vertices]
                v = moved
            v = v.reshape(pg.p, pg.vertices_per_core, *v.shape[1:])
        out[k] = jnp.asarray(v)
    return out


def unpad_labels(
    labels: Dict[str, jnp.ndarray], pg: PartitionedGraph
) -> Dict[str, np.ndarray]:
    """Back to original vertex ids (undo stride permutation + padding)."""
    out = {}
    for k, v in labels.items():
        v = np.asarray(v)
        if v.ndim in (2, 3) and v.shape[:2] == (pg.p, pg.vertices_per_core):
            flat = v.reshape(pg.padded_vertices, *v.shape[2:])
            if pg.perm is not None:
                flat = flat[pg.perm[: pg.num_vertices]]
            else:
                flat = flat[: pg.num_vertices]
            out[k] = flat
        else:
            out[k] = v
    return out


def _segment_reduce(kind: str, contrib, dst, num_segments: int, identity):
    # ``identity`` documents the caller's reduce identity; segment_min fills
    # empty segments with the dtype max (float inf / 0xFFFFFFFF for uint32 ==
    # INF_U32) and segment_sum with 0, which ARE the min/sum identities the
    # problems use — the same pairing the two-level split combine
    # (combine_split_rows) relies on. A kind whose identity is not the dtype
    # extreme would need an explicit fill here.
    if kind == "min":
        return jax.ops.segment_min(
            contrib, dst, num_segments=num_segments, indices_are_sorted=True
        )
    if kind == "or":
        # bitwise-OR segments by bit-plane decomposition: per bit, segment_max
        # of the 0/1 plane (empty segments fill with uint32 min == 0, the OR
        # identity). 32 segment ops — oracle-path only; the Pallas kernel does
        # the word-OR directly.
        out = jnp.zeros((num_segments,) + contrib.shape[1:], dtype=contrib.dtype)
        for b in range(32):
            plane = (contrib >> jnp.uint32(b)) & jnp.uint32(1)
            mx = jax.ops.segment_max(
                plane, dst, num_segments=num_segments, indices_are_sorted=True
            )
            out = out | (mx << jnp.uint32(b))
        return out
    return jax.ops.segment_sum(
        contrib, dst, num_segments=num_segments, indices_are_sorted=True
    )


def _edge_constants(problem: Problem, pg: PartitionedGraph, opts: EngineOptions):
    """Device-array edge constants, converted ONCE (hoisted out of the traced
    phase body — ``jnp.asarray`` on host numpy used to run inside it)."""
    if opts.backend == "pallas":
        # channel_arrays(problem) is the single source of truth for the packed
        # stream layout (word/word_hi/counts/w/row_pos/split_map with a
        # leading core == channel axis) AND the weight-streaming rule; the
        # distributed engine NamedSharding-places the same dict over the mesh.
        # Weightless edge_op streams NO weight array at all: the kernel adds
        # a constant 1.0 in registers.
        arrs = pg.channel_arrays(problem)
        return {
            k: (jnp.asarray(v) if v is not None else None) for k, v in arrs.items()
        }
    w = jnp.asarray(pg.weights) if pg.weights is not None else None
    return {
        "src": jnp.asarray(pg.src_gidx),  # (p, l, E_pad)
        "dst": jnp.asarray(pg.dst_lidx),
        "valid": jnp.asarray(pg.valid),
        "w": w,
    }


def phase_consts_at(consts, m):
    """Slice phase ``m`` out of every packed edge constant (axis 1 = phase).

    Works for both layouts of the leading channel axis: the single-process
    engine's full (p, l, ...) stack and a distributed device's (1, l, ...)
    shard — phase slicing is channel-local either way."""
    return {
        k: (
            jax.lax.dynamic_index_in_dim(v, m, axis=1, keepdims=False)
            if v is not None
            else None
        )
        for k, v in consts.items()
    }


def channel_phase_reduce_pallas(problem, pg, gathered, cm, opts, active=None):
    """THE fused gather-map-reduce primitive (steps 1+2 of a phase), channel
    local: ONE ``pallas_call`` over grid (n, R, T) does unpack + gather + map
    UDF + segment reduce against the phase's gathered crossbar block, reading
    the compressed word stream and skipping padding tiles. ``n`` is whatever
    the caller's channel axis holds — all ``p`` cores in the single-process
    engine, exactly 1 on a distributed device (one core per memory channel) —
    so both engines execute this one implementation.

    ``gathered`` is the (G,) crossbar block (locally sliced in-process;
    ``crossbar_exchange``-all-gathered across devices). ``cm`` is a phase
    slice of the packed constants (``phase_consts_at``). No (n, E_pad)
    per-edge array is materialized. With hub-row splitting the kernel output
    is over VIRTUAL rows and the second-level combine folds the partials into
    natural rows (still no per-edge materialization). Returns (n, Vl).

    ``active`` (traced (n, R, T) bool, from ``frontier_active_tiles`` — must
    already be ANDed with the real-tile mask) engages frontier-aware dynamic
    skipping: it is folded to the kernel's scalar-prefetched fetch map, which
    REPLACES the static tile counts, so inactive tiles are never decoded,
    gathered, or reduced this launch. None = the static schedule."""
    from repro.kernels.csr_gather_reduce.kernel import gather_reduce_cores_pallas
    from repro.kernels.csr_gather_reduce.ops import combine_split_rows

    reduced = gather_reduce_cores_pallas(
        gathered,
        cm["word"],
        cm["counts"],
        cm["word_hi"],
        cm["w"],
        fwords.active_fetch_map(active) if active is not None else None,
        num_rows=pg.packed_rows_per_core,
        vb=pg.tile_vb,
        src_bits=pg.src_bits,
        kind=problem.reduce_kind,
        edge_op=problem.edge_op,
        identity=problem.identity,
        interpret=opts.kernel_interpret,
    )  # (n, R*vb) level-1 reductions in packed (virtual-)row space
    if cm["split_map"] is not None:
        # level-2 reduce (hub-row splitting): fold each natural row's
        # virtual-row partials with the problem's reduce op; -1 padding
        # contributes the problem's identity, never a stray 0.
        reduced = combine_split_rows(
            reduced, cm["split_map"], kind=problem.reduce_kind,
            identity=problem.identity,
        )
    elif cm["row_pos"] is not None:  # undo degree-aware row packing
        pos = cm["row_pos"]
        if reduced.ndim == 3:  # trailing lane axis: size-1 index broadcasts
            pos = pos[..., None]
        reduced = jnp.take_along_axis(reduced, pos, axis=1)
    return reduced


def channel_phase_scatter_pallas(problem, pg, gathered, cm, opts, active=None):
    """Push-mode counterpart of ``channel_phase_reduce_pallas``: ONE
    ``pallas_call`` over grid (n, B, Tp) scatters the SOURCE-binned push
    stream of phase m into the whole per-core label row. ``cm`` is a phase
    slice of the push constants keyed like the pull ones (``word``/
    ``word_hi``/``counts``/``w``). ``active`` is the frontier-ANDed (n, B,
    Tp) mask over the push stream's own coverage words — on a narrow
    frontier only the blocks containing frontier sources stream, which is
    the whole point of the direction switch. Output rows are natural rows by
    construction (no row packing or hub splitting on the push side), so
    there is no level-2 fold. Returns (n, Vl[, L])."""
    from repro.kernels.csr_gather_reduce.kernel import scatter_reduce_cores_pallas

    return scatter_reduce_cores_pallas(
        gathered,
        cm["word"],
        cm["counts"],
        cm["word_hi"],
        cm["w"],
        fwords.active_fetch_map(active) if active is not None else None,
        num_rows=pg.vertices_per_core,
        src_bits=pg.push_src_bits,
        kind=problem.reduce_kind,
        edge_op=problem.edge_op,
        identity=problem.identity,
        interpret=opts.kernel_interpret,
    )


def channel_phase_reduce_xla(problem, pg, gathered, cm, opts):
    """Oracle form of the channel-local phase reduce: materialize (n, E_pad)
    contributions via take/where, then segment-reduce. ``cm`` holds the flat
    (n, E_pad) src/dst/valid slices of one phase."""
    svals = jnp.take(gathered, cm["src"], axis=0)  # (n, E[, L]) crossbar reads
    contrib = problem.edge_map(svals, cm["w"])
    identity = jnp.asarray(problem.identity, dtype=contrib.dtype)
    valid = cm["valid"]
    if contrib.ndim > valid.ndim:  # trailing lane axis broadcasts
        valid = valid[..., None]
    contrib = jnp.where(valid, contrib, identity)
    return jax.vmap(
        lambda c, d: _segment_reduce(
            problem.reduce_kind, c, d, pg.vertices_per_core, identity
        )
    )(contrib, cm["dst"])  # (n, Vl)


def _gather_local(problem, pg, labels, m):
    """Single-process crossbar: every core's phase-m sub-interval is a local
    slice of the (p, Vl[, L]) payload — concatenating them IS the gathered
    block ((G,) laneless, (G, L) with a multi-query lane axis)."""
    payload = problem.src_transform(labels)  # (p, Vl[, L]) elementwise
    sub = jax.lax.dynamic_slice_in_dim(payload, m * pg.sub_size, pg.sub_size, axis=1)
    return sub.reshape(pg.gathered_size, *payload.shape[2:])  # (G[, L])


def _phase_reduce_pallas(problem, pg, consts, labels, m, opts, active=None):
    gathered = _gather_local(problem, pg, labels, m)
    return channel_phase_reduce_pallas(
        problem, pg, gathered, phase_consts_at(consts, m), opts, active
    )


def _phase_reduce_xla(problem, pg, consts, labels, m, opts, active=None):
    assert active is None, "dynamic tile skipping requires the pallas backend"
    gathered = _gather_local(problem, pg, labels, m)
    return channel_phase_reduce_xla(
        problem, pg, gathered, phase_consts_at(consts, m), opts
    )


_PUSH_KEYS = (
    "push_word", "push_word_hi", "push_counts", "push_w", "push_coverage"
)


def make_iteration(
    problem: Problem,
    pg: PartitionedGraph,
    opts: EngineOptions,
    reduce_at_phase=None,
    phase_active=None,
    density_fn=None,
    with_stats: bool = False,
    push_reduce_at_phase=None,
    push_phase_active=None,
    push_phase_live=None,
):
    """Build one engine iteration (the l-phase loop + apply semantics).

    ``reduce_at_phase(m, labels[, active]) -> reduced`` supplies steps 1+2 of
    phase m; ``reduced`` must match ``labels[merge_field]``'s shape. When None
    (the single-process engine) it is built from the packed edge constants and
    the backend's channel phase reduce. The distributed engine passes its own
    — crossbar all-gather + the SAME ``channel_phase_reduce_pallas`` on a
    one-channel shard — so apply semantics (async min merge vs synchronous
    accumulate + finalize) exist exactly once.

    The returned ``iteration(labels, frontier=None)`` has two calling modes:

      * ``iteration(labels)`` — the static schedule, exactly the historical
        behavior (every real tile streams); returns the new labels.
      * ``iteration(labels, frontier)`` — frontier-aware dynamic scheduling
        (requires ``dynamic_skip_enabled``). ``frontier`` is the packed label
        -change bitmap of the previous iteration (``(..., l, Ws)`` uint32,
        ``full_frontier_words`` on iteration 0); returns ``(new_labels,
        new_frontier)`` — the new frontier is exactly the words of this
        iteration's label changes, so ``any(new_frontier != 0)`` IS the
        convergence check. With ``with_stats=True`` a third element is
        returned: ``{"active_tiles": int32, "use_dense": int32}``.

    Dynamic-mode hooks (the distributed engine overrides both; defaults are
    the single-process closures): ``phase_active(m, live_frontier, use_dense)
    -> (n, R, T) bool`` builds phase m's active-tile mask from the live
    frontier words; ``density_fn(frontier) -> int32`` is the global frontier
    popcount for the density switch (distributed: psum over channels, so
    every device takes the same ``lax.cond`` branch).

    Direction-optimizing traversal (``push_enabled``, docs/tile_layout.md
    §9): a third calling mode ``iteration(labels, frontier, prev_push)``
    adds the Beamer push/pull switch on top of dynamic scheduling.
    ``prev_push`` is last iteration's direction (bool; False on iteration
    0) and the return gains a trailing ``used_push`` bool BEFORE the stats
    element: ``(new_labels, new_frontier, used_push[, stats])``. The switch
    is taken once per iteration on the same union-frontier popcount the
    density switch reads — enter push below ``direction_alpha`` * total
    source bits, stay while below ``direction_beta`` (both scaled by 1/K
    for a K-lane batch) — and a ``lax.cond`` picks the pull phase loop or
    the push phase loop (same carry, bit-identical labels either way).
    Calls WITHOUT ``prev_push`` keep the exact legacy pull-only behavior
    and arity — the push machinery engages only when the caller threads
    the direction carry — EXCEPT under a forced ``direction='push'``,
    where every dynamic call runs the push loop directly (no cond, no
    pull-side stream in the jaxpr; legacy arity when ``prev_push`` is
    omitted). ``push_reduce_at_phase(m, labels, active)`` /
    ``push_phase_active(m, live_frontier)`` are the distributed overrides,
    mirroring the pull hooks (the push active map has no dense fallback:
    a wide frontier is what the switch itself avoids)."""
    if opts.lanes is not None and opts.lanes != problem.lanes:
        raise ValueError(
            f"EngineOptions.lanes={opts.lanes} but problem "
            f"{problem.name!r} declares lanes={problem.lanes}"
        )
    # 'or' (packed multi-source BFS) always runs the level-synchronized
    # schedule: its finalize recovers hop distances from a per-iteration
    # level counter, which async multi-hop propagation would corrupt. Both
    # immediate_updates settings therefore produce identical results.
    is_min = problem.reduce_kind == "min"
    dyn = dynamic_skip_enabled(problem, pg, opts)
    push_on = push_enabled(problem, pg, opts)
    forced_push = opts.direction == "push"
    if forced_push and not push_on:
        raise ValueError(
            "direction='push' requires an admissible push path: a min/or "
            "problem, the pallas backend, a partition built with "
            "build_push=True, and dynamic scheduling (dynamic_skip_enabled)"
        )
    if reduce_at_phase is None:
        consts = _edge_constants(problem, pg, opts)
        # coverage feeds phase_active below, never the phase reduce itself —
        # keep it out of the sliced consts so the static path's jaxpr is
        # untouched and the dynamic path slices it exactly once per phase.
        coverage = consts.pop("coverage", None)
        # the push stream likewise never enters the pull phase reduce; pop it
        # unconditionally so phase_consts_at never slices it on the pull path.
        push_raw = {k: consts.pop(k, None) for k in _PUSH_KEYS}
        reduce_fn = (
            _phase_reduce_pallas if opts.backend == "pallas" else _phase_reduce_xla
        )

        def reduce_at_phase(m, labels, active=None):
            return reduce_fn(problem, pg, consts, labels, m, opts, active)

        if dyn and phase_active is None:
            counts = consts["counts"]

            def phase_active(m, live_fw, use_dense):
                cov_m = jax.lax.dynamic_index_in_dim(
                    coverage, m, axis=1, keepdims=False
                )  # (p, R, T, Wc)
                cnt_m = jax.lax.dynamic_index_in_dim(
                    counts, m, axis=1, keepdims=False
                )  # (p, R)
                # core-major flatten of the cores' phase-m rows IS the
                # gathered-block word order (the layout contract).
                gfw = jax.lax.dynamic_index_in_dim(
                    live_fw, m, axis=-2, keepdims=False
                ).reshape(-1)
                return fwords.frontier_active_tiles(cov_m, gfw, cnt_m, use_dense)

        if push_on and push_phase_active is None:
            # push constants re-keyed to the canonical stream names so
            # phase_consts_at and the scatter primitive read one layout.
            push_cm_all = {
                "word": push_raw["push_word"],
                "word_hi": push_raw["push_word_hi"],
                "counts": push_raw["push_counts"],
                "w": push_raw["push_w"],
            }
            push_cov = push_raw["push_coverage"]
            push_counts = push_raw["push_counts"]

            def push_reduce_at_phase(m, labels, active):
                gathered = _gather_local(problem, pg, labels, m)
                return channel_phase_scatter_pallas(
                    problem, pg, gathered,
                    phase_consts_at(push_cm_all, m), opts, active,
                )

            def push_phase_active(m, live_fw):
                cov_m = jax.lax.dynamic_index_in_dim(
                    push_cov, m, axis=1, keepdims=False
                )  # (p, B, Tp, Wc)
                cnt_m = jax.lax.dynamic_index_in_dim(
                    push_counts, m, axis=1, keepdims=False
                )  # (p, B)
                gfw = jax.lax.dynamic_index_in_dim(
                    live_fw, m, axis=-2, keepdims=False
                ).reshape(-1)
                # no dense fallback: a wide frontier takes the pull branch
                # upstream, so the push map is always frontier-ANDed.
                return fwords.frontier_active_tiles(cov_m, gfw, cnt_m, None)

            def push_phase_live(m, live_fw):
                # phase-level skip: a phase none of whose sources are in the
                # live frontier scatters nothing (its reduce is the identity
                # for min/or), so the push arm drops the whole phase —
                # active map, kernel launch and merge included. This is the
                # coarsest grain of "stream only the frontier's out-tiles".
                return jnp.any(
                    jax.lax.dynamic_index_in_dim(
                        live_fw, m, axis=-2, keepdims=False
                    )
                    != 0
                )

    if push_on and push_phase_active is None:
        # a caller supplying its own reduce hooks (the distributed engine)
        # must supply the push hooks too to opt in; without them the
        # iteration stays pull-only.
        if forced_push:
            raise ValueError(
                "direction='push' with caller-supplied reduce hooks needs "
                "push_reduce_at_phase/push_phase_active"
            )
        push_on = False

    if dyn:
        # dense-fallback threshold over GLOBAL real source bits (the frontier
        # tail bits are never set, so popcount is over real sources only)
        dense_thr = jnp.int32(
            int(pg.p * pg.l * pg.sub_size * opts.dynamic_skip_density)
        )
        if density_fn is None:
            density_fn = fwords.frontier_popcount
    if push_on:
        # Beamer alpha/beta hysteresis over the SAME popcount, scaled by 1/K
        # for a K-lane batch: a push pass scatters each changed vertex's
        # whole lane row, so the per-frontier-bit push cost grows ~K-fold
        # and the crossover shifts down accordingly (switch per batch on the
        # union popcount, never per lane).
        lane_k = max(problem.lanes, 1)
        total_bits = pg.p * pg.l * pg.sub_size
        alpha_thr = jnp.int32(int(total_bits * opts.direction_alpha / lane_k))
        beta_thr = jnp.int32(int(total_bits * opts.direction_beta / lane_k))

    def _words_of(old, new):
        # lane-batched labels carry a trailing lane axis: the frontier is the
        # UNION over lanes (a tile streams iff any live query needs it).
        return fwords.frontier_words_from_labels(
            old, new, pg.l, pg.sub_size, lanes=problem.lanes > 0
        )

    def _stats(active_tiles, use_dense, use_push=None, pop=None):
        out = {
            "active_tiles": active_tiles,
            "use_dense": use_dense.astype(jnp.int32),
        }
        if use_push is not None:  # push-aware calls only (legacy keys stable)
            out["direction"] = use_push.astype(jnp.int32)  # 1 = push
            out["popcount"] = pop
        return out

    def _choose_push(pop, prev_push):
        """The per-iteration direction decision (one bool for the whole
        batch). Forced 'push' is handled by the callers — they run the push
        loop directly so no pull-side stream enters the jaxpr."""
        use_push = pop < alpha_thr
        if prev_push is not None:  # hysteresis: stay push while below beta
            use_push = use_push | (prev_push & (pop < beta_thr))
        return use_push

    if is_min and opts.immediate_updates:

        def _merge(labels, reduced):
            lab = labels[problem.merge_field]
            merged = jnp.minimum(lab, reduced.astype(lab.dtype))
            new = dict(labels)
            new[problem.merge_field] = merged
            return new, lab, merged

        def _static(labels):
            def phase(m, labels):
                return _merge(labels, reduce_at_phase(m, labels))[0]

            return jax.lax.fori_loop(0, pg.l, phase, labels)

        def _phase_loop(labels, fw_in, reduce_fn_m, active_fn_m,
                        phase_live_fn=None):
            """The async phase sweep, parameterized over direction: the pull
            and push arms differ ONLY in which stream reduces a phase and
            which coverage builds its active map — merge semantics, frontier
            augmentation, and the carry are shared, which is what makes the
            lax.cond arms line up. ``phase_live_fn`` (push arm only) skips a
            whole phase when none of its sources are live: the reduce would
            return the identity, so labels, frontier words and the active
            count are all unchanged — bit-identical, minus the phase's
            fixed cost."""

            def body(m, carry):
                labels, nf, n_act = carry
                active = active_fn_m(m, fw_in | nf)
                new, lab, merged = _merge(labels, reduce_fn_m(m, labels, active))
                nf = nf | _words_of(lab, merged)
                n_act = n_act + jnp.sum(active, dtype=jnp.int32)
                return new, nf, n_act

            def phase(m, carry):
                # live frontier = last iteration's changes OR this
                # iteration's so-far — async phases see fresh labels, so the
                # schedule must track them to stay identical to dense async.
                if phase_live_fn is None:
                    return body(m, carry)
                return jax.lax.cond(
                    phase_live_fn(m, fw_in | carry[1]),
                    lambda c: body(m, c),
                    lambda c: c,
                    carry,
                )

            return jax.lax.fori_loop(
                0, pg.l, phase, (labels, jnp.zeros_like(fw_in), jnp.int32(0))
            )

        def _dynamic(labels, fw_in, prev_push=None):
            pop = density_fn(fw_in)
            use_dense = pop >= dense_thr

            def _pull(labels):
                return _phase_loop(
                    labels, fw_in, reduce_at_phase,
                    lambda m, live: phase_active(m, live, use_dense),
                )

            if not push_on or (prev_push is None and not forced_push):
                # legacy pull-only dynamic call — byte-for-byte the PR 6 path
                labels, nf, n_act = _pull(labels)
                if with_stats:
                    return labels, nf, _stats(n_act, use_dense)
                return labels, nf

            def _push(labels):
                return _phase_loop(
                    labels, fw_in, push_reduce_at_phase,
                    lambda m, live: push_phase_active(m, live),
                    phase_live_fn=push_phase_live,
                )

            if forced_push:
                use_push = jnp.bool_(True)
                labels, nf, n_act = _push(labels)
            else:
                use_push = _choose_push(pop, prev_push)
                labels, nf, n_act = jax.lax.cond(use_push, _push, _pull, labels)
            # monotone min: the union of per-phase change words == the words
            # of (labels in vs labels out) — nf IS the next frontier.
            if prev_push is None:  # forced push, legacy arity
                if with_stats:
                    return labels, nf, _stats(n_act, use_dense, use_push, pop)
                return labels, nf
            if with_stats:
                return labels, nf, use_push, _stats(n_act, use_dense, use_push, pop)
            return labels, nf, use_push

        def iteration(labels, frontier=None, prev_push=None):
            if frontier is None:
                if prev_push is not None:
                    raise ValueError("prev_push requires a frontier")
                return _static(labels)
            if not dyn:
                raise ValueError(
                    "iteration got a frontier but dynamic skipping is "
                    "disabled (see dynamic_skip_enabled)"
                )
            if prev_push is not None and not push_on:
                raise ValueError(
                    "iteration got prev_push but the push direction is not "
                    "admissible (see push_enabled)"
                )
            return _dynamic(labels, frontier, prev_push)

        return iteration

    # synchronous path: accumulate contributions, apply at iteration end
    def iteration(labels, frontier=None, prev_push=None):
        if frontier is None and prev_push is not None:
            raise ValueError("prev_push requires a frontier")
        if frontier is not None and not dyn:
            raise ValueError(
                "iteration got a frontier but dynamic skipping is disabled "
                "(see dynamic_skip_enabled)"
            )
        if prev_push is not None and not push_on:
            raise ValueError(
                "iteration got prev_push but the push direction is not "
                "admissible (see push_enabled)"
            )
        lab = labels[problem.merge_field]
        acc_dtype = jnp.float32 if problem.reduce_kind == "sum" else lab.dtype
        acc0 = jnp.full(lab.shape, problem.identity, dtype=acc_dtype)
        dynamic = frontier is not None
        pop = density_fn(frontier) if dynamic else None
        use_dense = pop >= dense_thr if dynamic else None
        n_act0 = jnp.int32(0)
        push_aware = dynamic and push_on and (prev_push is not None or forced_push)

        def acc_loop(reduce_fn_m, active_fn_m, phase_live_fn=None):
            def body(m, carry):
                acc, n_act = carry
                if dynamic:
                    # synchronous phases only see LAST iteration's labels, so
                    # the input frontier alone is the live frontier.
                    active = active_fn_m(m, frontier)
                    n_act = n_act + jnp.sum(active, dtype=jnp.int32)
                    reduced = reduce_fn_m(m, labels, active)
                else:
                    reduced = reduce_fn_m(m, labels)
                if problem.reduce_kind == "min":
                    return jnp.minimum(acc, reduced.astype(acc.dtype)), n_act
                if problem.reduce_kind == "or":
                    return acc | reduced.astype(acc.dtype), n_act
                return acc + reduced.astype(acc.dtype), n_act

            def phase(m, carry):
                # push arm phase-level skip (see _phase_loop): a phase with
                # no live sources contributes the reduce identity
                if phase_live_fn is None:
                    return body(m, carry)
                return jax.lax.cond(
                    phase_live_fn(m, frontier),
                    lambda c: body(m, c),
                    lambda c: c,
                    carry,
                )

            return jax.lax.fori_loop(0, pg.l, phase, (acc0, n_act0))

        def _pull_loop(_=None):
            return acc_loop(
                reduce_at_phase,
                (lambda m, fw: phase_active(m, fw, use_dense)) if dynamic else None,
            )

        use_push = None
        if push_aware:

            def _push_loop(_=None):
                return acc_loop(
                    push_reduce_at_phase,
                    lambda m, fw: push_phase_active(m, fw),
                    phase_live_fn=push_phase_live,
                )

            if forced_push:
                use_push = jnp.bool_(True)
                acc, n_act = _push_loop()
            else:
                use_push = _choose_push(pop, prev_push)
                acc, n_act = jax.lax.cond(use_push, _push_loop, _pull_loop, None)
        else:
            acc, n_act = _pull_loop()

        def _ret(new, nf):
            extras = ()
            if prev_push is not None:
                extras += (use_push,)
            if with_stats:
                extras += (_stats(n_act, use_dense, use_push, pop if push_aware else None),)
            return (new, nf) + extras if extras else (new, nf)

        if problem.reduce_kind == "min":
            new = dict(labels)
            merged = jnp.minimum(lab, acc.astype(lab.dtype))
            new[problem.merge_field] = merged
            if dynamic:
                return _ret(new, _words_of(lab, merged))
            return new
        new = problem.finalize(labels, acc)
        if dynamic:  # 'or' problems: monotone, so frontier scheduling applies
            return _ret(new, _words_of(lab, new[problem.merge_field]))
        return new

    return iteration


# the historical private name (tests and callers predate the public API)
_make_iteration = make_iteration


@partial(jax.jit, static_argnames=("problem", "pg", "opts"))
def _run_jit(problem, pg, opts, labels):
    iteration = _make_iteration(problem, pg, opts)
    if dynamic_skip_enabled(problem, pg, opts):
        # frontier-carried loop: the per-iteration label-change words both
        # schedule the next iteration's tiles AND are the convergence check
        # (empty frontier == no label changed == problem.not_converged False
        # for the monotone min problems dynamic skipping admits).
        fw0 = fwords.full_frontier_words(pg.l, pg.sub_size, lead=(pg.p,))
        if push_enabled(problem, pg, opts):
            # direction-optimizing: thread last iteration's direction through
            # the carry for the alpha/beta hysteresis (False on iteration 0 —
            # the full frontier always takes the pull branch under 'auto').

            def cond(carry):
                _, _, it, changed, _ = carry
                return jnp.logical_and(changed, it < opts.max_iters)

            def body(carry):
                labels, fw, it, _, dirp = carry
                new, nf, dirn = iteration(labels, fw, dirp)
                return new, nf, it + 1, jnp.any(nf != jnp.uint32(0)), dirn

            labels, _, iters, changed, _ = jax.lax.while_loop(
                cond, body,
                (labels, fw0, jnp.int32(0), jnp.bool_(True), jnp.bool_(False)),
            )
            return labels, iters, changed

        def cond(carry):
            _, _, it, changed = carry
            return jnp.logical_and(changed, it < opts.max_iters)

        def body(carry):
            labels, fw, it, _ = carry
            new, nf = iteration(labels, fw)
            return new, nf, it + 1, jnp.any(nf != jnp.uint32(0))

        labels, _, iters, changed = jax.lax.while_loop(
            cond, body, (labels, fw0, jnp.int32(0), jnp.bool_(True))
        )
        return labels, iters, changed

    def cond(carry):
        _, it, changed = carry
        return jnp.logical_and(changed, it < opts.max_iters)

    def body(carry):
        labels, it, _ = carry
        new = iteration(labels)
        changed = problem.not_converged(labels, new)
        return new, it + 1, changed

    labels, iters, changed = jax.lax.while_loop(
        cond, body, (labels, jnp.int32(0), jnp.bool_(True))
    )
    return labels, iters, changed


# LRU-bounded (was unbounded: a serving loop running many graphs pinned every
# Problem/PartitionedGraph ever run for the life of the process). Eviction is
# safe — the wrapper is only a jit cache key, so re-wrapping an evicted object
# costs one retrace, never wrong results. The `hit[0] is obj` guard also
# covers id() reuse after eviction frees the old object.
_WRAP_CACHE: OrderedDict = OrderedDict()
_WRAP_CACHE_MAX = 128


def _wrap(obj):
    """Identity-hashed static wrapper, cached so repeated runs share jit cache."""
    key = id(obj)
    hit = _WRAP_CACHE.get(key)
    if hit is not None and hit[0] is obj:
        _WRAP_CACHE.move_to_end(key)
        return hit[1]
    w = _Hashable(obj)
    _WRAP_CACHE[key] = (obj, w)  # keep obj alive so id stays valid
    while len(_WRAP_CACHE) > _WRAP_CACHE_MAX:
        _WRAP_CACHE.popitem(last=False)
    return w


def evict_from_cache(obj) -> bool:
    """Drop a retired object (typically the pre-flush ``PartitionedGraph``)
    from the static-wrapper cache.

    A delta flush (``partition.apply_edge_deltas``) returns a NEW partition
    object — every trace keyed on the old wrapper baked the old packed words
    in as constants, so the old entry can never serve the updated graph and
    only pins dead label/coverage constants (and the retired arrays
    themselves) until 128 newer entries push it out. The serving loop calls
    this on every flush. Returns True if an entry was evicted."""
    return _WRAP_CACHE.pop(id(obj), None) is not None


def run(
    problem: Problem,
    g,
    pg: PartitionedGraph,
    opts: EngineOptions = EngineOptions(),
    labels: Dict[str, jnp.ndarray] | None = None,
) -> EngineResult:
    """Run ``problem`` to convergence. ``labels`` (a ``prepare_labels`` tree)
    overrides the problem's own init — the serving loop's warm-cache hook: a
    multi-query problem's traced computation depends only on its lane count,
    never on the root VALUES (those live in the label init), so admission
    batches reuse ONE template problem as the jit cache key and feed each
    batch's roots through ``labels`` without retracing (launch/serve.py)."""
    if labels is None:
        labels = prepare_labels(problem, g, pg)
    # opts is a frozen dataclass of primitives: hashable BY VALUE, so fresh
    # EngineOptions() instances hit the jit cache (id-wrapping it caused a
    # recompile per call — caught because benchmarks timed compiles).
    labels, iters, changed = _run_jit(_wrap(problem), _wrap(pg), opts, labels)
    return EngineResult(
        labels=unpad_labels(labels, pg),
        iterations=int(iters),
        converged=not bool(changed),
    )


def run_frontier_trace(
    problem: Problem, g, pg: PartitionedGraph, opts: EngineOptions = EngineOptions()
) -> dict:
    """Host-stepped dynamic run that records the per-iteration schedule.

    Same numerics as ``run`` (one jitted ``iteration(labels, frontier)`` per
    step), but stepped from the host so each iteration's active-tile count
    can be read back. Returns a dict with the final ``labels`` /
    ``iterations`` / ``converged`` plus ``dynamic_skipped_tile_fraction`` — a
    per-iteration list over the SAME denominator as the static
    ``pg.skipped_tile_fraction`` (all (core, phase, row-block) x T_max tile
    slots; a push iteration's fraction uses the push stream's own (core,
    phase, src-block) x Tp_max denominator, since that is the stream it
    scheduled against) — ``dense_iterations`` (how often the density switch
    took the wide-frontier fallback), ``direction`` (the per-iteration
    'push'/'pull' choice; all-'pull' when the push path is off), and
    ``push_iterations``."""
    if not dynamic_skip_enabled(problem, pg, opts):
        raise ValueError(
            "run_frontier_trace needs dynamic skipping: a min problem, the "
            "pallas backend, coverage bitmaps, and dynamic_tile_skip=True"
        )
    labels = prepare_labels(problem, g, pg)
    step = jax.jit(make_iteration(problem, pg, opts, with_stats=True))
    fw = fwords.full_frontier_words(pg.l, pg.sub_size, lead=(pg.p,))
    push_on = push_enabled(problem, pg, opts)
    total_tiles = pg.tile_counts.size * pg.tile_word.shape[3]
    total_push_tiles = (
        pg.push_counts.size * pg.push_word.shape[3] if push_on else 0
    )
    prev = jnp.bool_(False)
    fractions, directions = [], []
    dense_iters, it, converged = 0, 0, False
    while it < opts.max_iters:
        if push_on:
            labels, fw, prev, stats = step(labels, fw, prev)
            pushed = bool(stats["direction"])
        else:
            labels, fw, stats = step(labels, fw)
            pushed = False
        total = total_push_tiles if pushed else total_tiles
        fractions.append(1.0 - int(stats["active_tiles"]) / max(total, 1))
        directions.append("push" if pushed else "pull")
        dense_iters += int(stats["use_dense"])
        it += 1
        if not bool(jnp.any(fw != jnp.uint32(0))):  # free convergence check
            converged = True
            break
    return {
        "labels": unpad_labels(labels, pg),
        "iterations": it,
        "converged": converged,
        "dynamic_skipped_tile_fraction": fractions,
        "mean_dynamic_skipped_tile_fraction": (
            float(np.mean(fractions)) if fractions else 0.0
        ),
        "dense_iterations": dense_iters,
        "direction": directions,
        "push_iterations": directions.count("push"),
    }


class _Hashable:
    """Identity-hashed wrapper so dataclasses with arrays can be static args."""

    def __init__(self, obj):
        self._obj = obj

    def __getattr__(self, name):
        return getattr(self._obj, name)

    def __hash__(self):
        return id(self._obj)

    def __eq__(self, other):
        return isinstance(other, _Hashable) and self._obj is other._obj
