"""Pure-numpy oracles for the graph problems (test ground truth).

Deliberately simple O(V+E) / O(V*E) implementations with no JAX — these define
correctness for both engines and the Pallas kernels.
"""
from __future__ import annotations

from collections import deque

import numpy as np

from repro.core.graph import COOGraph, coo_to_csr, symmetrize
from repro.core.problems import INF_U32

__all__ = ["bfs_reference", "wcc_reference", "sssp_reference", "pagerank_reference"]


def bfs_reference(g: COOGraph, root: int) -> np.ndarray:
    csr = coo_to_csr(g)
    dist = np.full(g.num_vertices, INF_U32, dtype=np.uint32)
    dist[root] = 0
    q = deque([root])
    while q:
        u = q.popleft()
        for v in csr.neighbors(u):
            if dist[v] == INF_U32:
                dist[v] = dist[u] + 1
                q.append(v)
    return dist


def wcc_reference(g: COOGraph) -> np.ndarray:
    und = symmetrize(g)
    csr = coo_to_csr(und)
    comp = np.full(g.num_vertices, INF_U32, dtype=np.uint32)
    for s in range(g.num_vertices):
        if comp[s] != INF_U32:
            continue
        comp[s] = s  # min id in component == first unvisited in increasing order
        q = deque([s])
        while q:
            u = q.popleft()
            for v in csr.neighbors(u):
                if comp[v] == INF_U32:
                    comp[v] = s
                    q.append(v)
    return comp


def sssp_reference(g: COOGraph, root: int) -> np.ndarray:
    """Bellman-Ford (weights default 1.0)."""
    w = g.weights if g.weights is not None else np.ones(g.num_edges, dtype=np.float32)
    inf = np.finfo(np.float32).max
    dist = np.full(g.num_vertices, inf, dtype=np.float32)
    dist[root] = 0.0
    for _ in range(g.num_vertices):
        cand = dist[g.src] + w
        cand[dist[g.src] >= inf] = inf
        new = dist.copy()
        np.minimum.at(new, g.dst, cand.astype(np.float32))
        if np.array_equal(new, dist):
            break
        dist = new
    return dist


def pagerank_reference(
    g: COOGraph, damping: float = 0.85, tol: float = 1e-6, max_iters: int = 1000
) -> np.ndarray:
    """Power iteration with the paper's formula (no dangling redistribution)."""
    n = g.num_vertices
    outdeg = np.bincount(g.src, minlength=n).astype(np.float64)
    inv = np.zeros(n)
    inv[outdeg > 0] = 1.0 / outdeg[outdeg > 0]
    rank = np.full(n, 1.0 / n)
    for _ in range(max_iters):
        z = rank * inv
        acc = np.zeros(n)
        np.add.at(acc, g.dst, z[g.src])
        new = (1.0 - damping) / n + damping * acc
        if np.max(np.abs(new - rank)) < tol:
            rank = new
            break
        rank = new
    return rank.astype(np.float32)
