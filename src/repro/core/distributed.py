"""Multi-device GraphScale engine: shard_map + phased all-gather crossbar,
streaming the COMPRESSED per-channel edge layout.

Mapping (docs/distributed.md §1): one mesh device per graph core / memory
channel. Vertex labels are sharded over the ``graph`` mesh axis; at phase
``m`` every device contributes its active sub-interval to an ``all_gather``
(``crossbar_exchange``) — the bulk-ICI equivalent of the paper's two-level
vertex-label crossbar — and then serves all of its edge label reads from that
local gathered block (the scratch pad).

What each channel streams is the point (docs/distributed.md §3): the
per-device edge constants are this core's shard of the partition-time packed
stream — ``tile_word``/``tile_word_hi`` bit-packed index words, the
scalar-prefetched ``tile_counts`` that skip padding tiles, and the hub-split
``split_map`` — exactly the arrays ``PartitionedGraph.channel_arrays()``
hands the single-process engine. Phase processing is literally the same
function (``engine.channel_phase_reduce_pallas``, the one Pallas phase-reduce
implementation) invoked with a channel axis of 1, so every compression win
(4-8 index B/edge, tile skipping, two-level hub reduce) crosses each
channel's HBM unchanged; the flat (l, E_pad) src/dst/valid arrays are never
shipped to, or materialized on, any device (jaxpr-asserted in
tests/test_distributed_equiv.py).

The engine is numerically the single-process engine run with p remote
channels: min problems are bit-identical, sum problems (PageRank) agree to
float reassociation (tested in tests/test_distributed_equiv.py — the
equivalence suite that keeps this docstring honest).

Multi-query lane batching rides through unchanged (docs/tile_layout.md §8):
a lane-batched label shard is (1, Vl, L) — the squeeze/re-expand rules and
the axis-0 ``crossbar_exchange`` are lane-oblivious, so each phase
all-gathers (sub, L) payload rows and one ``channel_phase_reduce_pallas``
launch per channel updates all K queries. The dynamic-skip frontier words
are the UNION over lanes (built inside ``make_iteration``), and both the
density popcount and the convergence check are psum'd exactly as in the
laneless engine, so every channel takes the same branch while individual
lanes converge at different iterations (tests/test_multi_query.py).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import jax_compat

jax_compat.install()  # jax.shard_map / make_mesh(axis_types) / AxisType on 0.4.x

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import frontier_words as fwords  # noqa: E402
from repro.core.engine import (  # noqa: E402
    EngineOptions,
    EngineResult,
    channel_phase_reduce_pallas,
    channel_phase_scatter_pallas,
    dynamic_skip_enabled,
    make_iteration,
    phase_consts_at,
    prepare_labels,
    push_enabled,
    unpad_labels,
)
from repro.core.partition import PartitionedGraph  # noqa: E402
from repro.core.problems import Problem  # noqa: E402

__all__ = [
    "crossbar_exchange",
    "place_channel_shards",
    "shard_labels",
    "build_distributed_run",
    "run_distributed",
]

# fixed flattening order for the packed per-channel constants (shard_map takes
# positional args; None entries are elided per problem/partition). The push_*
# entries are the source-binned scatter stream for direction-optimizing
# traversal (docs/tile_layout.md §9) — dropped by channel_arrays for sum
# problems, exactly like coverage.
_CONST_KEYS = (
    "word", "word_hi", "counts", "w", "row_pos", "split_map", "coverage",
    "push_word", "push_word_hi", "push_counts", "push_w", "push_coverage",
)


def crossbar_exchange(sub_payload: jnp.ndarray, axis: str) -> jnp.ndarray:
    """The two-level crossbar, TPU edition (docs/distributed.md §2): replicate
    the p active sub-intervals so every later label read is a local (VMEM)
    gather.

    ``sub_payload``: this device's active sub-interval, (sub, ...) floats/ints.
    Returns the gathered block (p * sub, ...).
    """
    return jax.lax.all_gather(sub_payload, axis, axis=0, tiled=True)


def place_channel_shards(
    problem: Problem, pg: PartitionedGraph, mesh: Mesh, axis: str = "graph"
) -> Dict[str, jnp.ndarray]:
    """NamedSharding-place the packed per-channel edge stream: the core axis
    of every ``channel_arrays()`` entry becomes the ``axis`` mesh axis, so
    device q holds exactly core q's compressed shard (ragged R/T already
    padded uniform at partition time; the weight-streaming rule lives in
    ``channel_arrays`` itself)."""
    arrs = pg.channel_arrays(problem)
    out = {}
    for k, v in arrs.items():
        if v is None:
            out[k] = None
        else:
            spec = P(axis, *([None] * (v.ndim - 1)))
            out[k] = jax.device_put(jnp.asarray(v), NamedSharding(mesh, spec))
    return out


def _label_specs(labels, axis):
    # read ndim off the value itself (works for np/jnp arrays AND tracers —
    # jax.make_jaxpr traces through run_fn.traceable; np.asarray would throw)
    return {
        k: (P(axis) if getattr(v, "ndim", 0) >= 1 else P())
        for k, v in labels.items()
    }


def build_distributed_run(
    problem: Problem,
    pg: PartitionedGraph,
    mesh: Mesh,
    axis: str = "graph",
    opts: EngineOptions = EngineOptions(),
):
    """Returns run_fn(labels) -> (labels, iters, changed); labels pre-sharded
    over ``axis``. ``run_fn.traceable`` is the un-jitted composite (constants
    bound) for jaxpr inspection by the equivalence suite."""
    if opts.backend != "pallas":
        raise ValueError(
            "run_distributed streams the compressed per-channel layout and "
            "has exactly one phase-reduce implementation — the Pallas one; "
            f"backend={opts.backend!r} has no distributed variant (run the "
            "XLA oracle through core.engine.run instead)"
        )
    consts = place_channel_shards(problem, pg, mesh, axis)  # raises if no tiles
    const_keys = tuple(k for k in _CONST_KEYS if consts[k] is not None)
    const_vals = tuple(consts[k] for k in const_keys)
    sub_size = pg.sub_size
    dyn = dynamic_skip_enabled(problem, pg, opts)

    def body(labels, *cvals):
        # shard_map blocks keep a leading core dim of size 1 -> squeeze labels
        # to this device's (Vl,) shard; the packed constants KEEP theirs (the
        # channel phase reduce runs the (n=1, R, T) kernel grid directly).
        labels = {
            k: (v[0] if getattr(v, "ndim", 0) >= 1 and v.shape[0] == 1 else v)
            for k, v in labels.items()
        }
        cm_all = dict(zip(const_keys, cvals))
        cm_all.update({k: None for k in _CONST_KEYS if k not in const_keys})
        # coverage feeds the active-tile schedule below, not the phase reduce
        coverage = cm_all.pop("coverage")
        # the push stream never enters the pull phase reduce: pop it and
        # re-key to the canonical stream names for the scatter primitive.
        push_cm = {
            "word": cm_all.pop("push_word"),
            "word_hi": cm_all.pop("push_word_hi"),
            "counts": cm_all.pop("push_counts"),
            "w": cm_all.pop("push_w"),
        }
        push_coverage = cm_all.pop("push_coverage")

        def reduce_at_phase(m, labels_local, active=None):
            payload = problem.src_transform(labels_local)  # (Vl,) elementwise
            sub = jax.lax.dynamic_slice_in_dim(
                payload, m * sub_size, sub_size, axis=0
            )
            gathered = crossbar_exchange(sub, axis)  # (G,) scratch pad
            reduced = channel_phase_reduce_pallas(
                problem, pg, gathered, phase_consts_at(cm_all, m), opts, active
            )  # (1, Vl)
            return reduced[0]

        phase_active = density_fn = None
        if dyn:
            counts = cm_all["counts"]  # (1, l, R) this channel's shard

            def phase_active(m, live_fw, use_dense):
                # the per-channel frontier words ride the SAME crossbar as
                # the labels: all-gathering the p phase-m (Ws,) slices in
                # core order yields exactly the gathered-block word layout
                # the coverage bitmaps index (docs/tile_layout.md §7).
                cov_m = jax.lax.dynamic_index_in_dim(
                    coverage, m, axis=1, keepdims=False
                )  # (1, R, T, Wc)
                cnt_m = jax.lax.dynamic_index_in_dim(
                    counts, m, axis=1, keepdims=False
                )  # (1, R)
                local = jax.lax.dynamic_index_in_dim(
                    live_fw, m, axis=-2, keepdims=False
                )  # (Ws,)
                gfw = crossbar_exchange(local, axis)  # (p * Ws,)
                return fwords.frontier_active_tiles(cov_m, gfw, cnt_m, use_dense)

            def density_fn(fw):
                # GLOBAL popcount: every channel sees the same density and
                # takes the same lax.cond branch (collectives inside the
                # dense/dynamic arms must line up across devices).
                return jax.lax.psum(fwords.frontier_popcount(fw), axis)

        push_on = push_enabled(problem, pg, opts)
        push_reduce_at_phase = push_phase_active = None
        if push_on:

            def push_reduce_at_phase(m, labels_local, active):
                payload = problem.src_transform(labels_local)
                sub = jax.lax.dynamic_slice_in_dim(
                    payload, m * sub_size, sub_size, axis=0
                )
                gathered = crossbar_exchange(sub, axis)
                reduced = channel_phase_scatter_pallas(
                    problem, pg, gathered, phase_consts_at(push_cm, m), opts,
                    active,
                )  # (1, Vl)
                return reduced[0]

            def push_phase_active(m, live_fw):
                cov_m = jax.lax.dynamic_index_in_dim(
                    push_coverage, m, axis=1, keepdims=False
                )  # (1, B, Tp, Wc)
                cnt_m = jax.lax.dynamic_index_in_dim(
                    push_cm["counts"], m, axis=1, keepdims=False
                )  # (1, B)
                local = jax.lax.dynamic_index_in_dim(
                    live_fw, m, axis=-2, keepdims=False
                )  # (Ws,)
                gfw = crossbar_exchange(local, axis)  # (p * Ws,)
                return fwords.frontier_active_tiles(cov_m, gfw, cnt_m, None)

            def push_phase_live(m, live_fw):
                # phase-level skip, collective edition: the GLOBAL any() via
                # psum so every channel takes the same lax.cond branch (the
                # skipped arm elides the crossbar all-gathers, which must
                # line up across devices).
                local = jnp.any(
                    jax.lax.dynamic_index_in_dim(
                        live_fw, m, axis=-2, keepdims=False
                    )
                    != 0
                )
                return jax.lax.psum(local.astype(jnp.int32), axis) > 0

        iteration = make_iteration(
            problem, pg, opts, reduce_at_phase, phase_active, density_fn,
            push_reduce_at_phase=push_reduce_at_phase,
            push_phase_active=push_phase_active,
            push_phase_live=push_phase_live if push_on else None,
        )

        if dyn and push_on:
            # direction-carried loop: the switch reads the PSUM'd popcount
            # (density_fn), so every channel chooses the same direction and
            # the crossbar collectives inside each arm line up.

            def cond(carry):
                _, _, it, changed, _ = carry
                return jnp.logical_and(changed, it < opts.max_iters)

            def step(carry):
                labels, fw, it, _, dirp = carry
                new, nf, dirn = iteration(labels, fw, dirp)
                changed = (
                    jax.lax.psum(
                        jnp.any(nf != jnp.uint32(0)).astype(jnp.int32), axis
                    )
                    > 0
                )
                return new, nf, it + 1, changed, dirn

            fw0 = fwords.full_frontier_words(pg.l, sub_size)  # (l, Ws) local
            labels, _, iters, changed, _ = jax.lax.while_loop(
                cond, step,
                (labels, fw0, jnp.int32(0), jnp.bool_(True), jnp.bool_(False)),
            )
        elif dyn:

            def cond(carry):
                _, _, it, changed = carry
                return jnp.logical_and(changed, it < opts.max_iters)

            def step(carry):
                labels, fw, it, _ = carry
                new, nf = iteration(labels, fw)
                changed = (
                    jax.lax.psum(
                        jnp.any(nf != jnp.uint32(0)).astype(jnp.int32), axis
                    )
                    > 0
                )  # free convergence check: stop when EVERY frontier is empty
                return new, nf, it + 1, changed

            fw0 = fwords.full_frontier_words(pg.l, sub_size)  # (l, Ws) local
            labels, _, iters, changed = jax.lax.while_loop(
                cond, step, (labels, fw0, jnp.int32(0), jnp.bool_(True))
            )
        else:

            def cond(carry):
                _, it, changed = carry
                return jnp.logical_and(changed, it < opts.max_iters)

            def step(carry):
                labels, it, _ = carry
                new = iteration(labels)
                local_changed = problem.not_converged(labels, new)
                changed = (
                    jax.lax.psum(local_changed.astype(jnp.int32), axis) > 0
                )  # cores agree to stop only when NO core changed
                return new, it + 1, changed

            labels, iters, changed = jax.lax.while_loop(
                cond, step, (labels, jnp.int32(0), jnp.bool_(True))
            )
        labels = {
            k: (
                v[None]
                if getattr(v, "ndim", 0) >= 1 and v.shape[0] == pg.vertices_per_core
                else v
            )
            for k, v in labels.items()
        }
        return labels, iters, changed

    def make_fn(labels):
        in_specs = (
            _label_specs(labels, axis),
            *(P(axis, *([None] * (v.ndim - 1))) for v in const_vals),
        )
        out_specs = (_label_specs(labels, axis), P(), P())
        return jax.shard_map(
            body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )

    # jit once per label-tree structure: a fresh jax.jit around a fresh
    # shard_map closure on every call would retrace + recompile every time
    # (caught because the channel-scaling bench timed compiles, not steps)
    jitted: dict = {}

    def run_fn(labels):
        key = tuple(sorted((k, getattr(v, "ndim", 0)) for k, v in labels.items()))
        fn = jitted.get(key)
        if fn is None:
            fn = jitted[key] = jax.jit(make_fn(labels))
        return fn(labels, *const_vals)

    run_fn.traceable = lambda labels: make_fn(labels)(labels, *const_vals)
    run_fn.const_keys = const_keys
    return run_fn


def shard_labels(labels: Dict, mesh: Mesh, axis: str = "graph") -> Dict:
    """device_put a prepare_labels() dict over the mesh: core axis of every
    (p, Vl) array -> the ``axis`` mesh axis, scalars replicated."""
    return {
        k: jax.device_put(
            v, NamedSharding(mesh, P(axis) if getattr(v, "ndim", 0) >= 1 else P())
        )
        for k, v in labels.items()
    }


def run_distributed(
    problem: Problem,
    g,
    pg: PartitionedGraph,
    mesh: Mesh,
    axis: str = "graph",
    opts: EngineOptions = EngineOptions(),
) -> EngineResult:
    """Convenience end-to-end: init labels, shard, run, unpad."""
    assert pg.p == mesh.shape[axis], (pg.p, dict(mesh.shape))
    sharded = shard_labels(prepare_labels(problem, g, pg), mesh, axis)
    run_fn = build_distributed_run(problem, pg, mesh, axis, opts)
    out, iters, changed = run_fn(sharded)
    return EngineResult(
        labels=unpad_labels({k: np.asarray(v) for k, v in out.items()}, pg),
        iterations=int(iters),
        converged=not bool(changed),
    )
