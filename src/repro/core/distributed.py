"""Multi-device GraphScale engine: shard_map + phased all-gather crossbar.

Mapping (DESIGN.md §2): one mesh device per graph core / memory channel. Vertex
labels are sharded over the ``graph`` mesh axis; at phase ``m`` every device
contributes its active sub-interval to an ``all_gather`` — the bulk-ICI
equivalent of the paper's two-level vertex-label crossbar — and then serves all
of its edge label reads from that local gathered block (the scratch pad).

The engine is payload-shape agnostic: payloads may be (Vl,) scalar labels
(BFS/WCC/SSSP/PR) or (Vl, D) feature rows (GNN message passing re-uses this
exact code path), so the paper's technique is a first-class distributed sparse
substrate, not a demo.

Numerics are bit-identical to ``core/engine.py`` (tested): the single-process
engine is the oracle for this one.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.engine import EngineOptions, _wrap, unpad_labels
from repro.core.partition import PartitionedGraph
from repro.core.problems import Problem

__all__ = ["crossbar_exchange", "build_distributed_run", "run_distributed"]


def crossbar_exchange(sub_payload: jnp.ndarray, axis: str) -> jnp.ndarray:
    """The two-level crossbar, TPU edition: replicate the p active
    sub-intervals so every later label read is a local (VMEM) gather.

    ``sub_payload``: this device's active sub-interval, (sub, ...) floats/ints.
    Returns the gathered block (p * sub, ...).
    """
    return jax.lax.all_gather(sub_payload, axis, axis=0, tiled=True)


def _device_iteration(problem, pg, opts, axis, labels, sg, dl, vm, w):
    """One iteration on ONE device's shard. labels fields: (Vl,) or scalar."""
    sub_size, l, vpc = pg.sub_size, pg.l, pg.vertices_per_core
    is_min = problem.reduce_kind == "min"

    def phase_reduce(m, labels):
        payload = problem.src_transform(labels)  # (Vl, ...) elementwise
        sub = jax.lax.dynamic_slice_in_dim(payload, m * sub_size, sub_size, axis=0)
        gathered = crossbar_exchange(sub, axis)  # (p*sub, ...)
        sg_m = jax.lax.dynamic_index_in_dim(sg, m, axis=0, keepdims=False)  # (E,)
        dl_m = jax.lax.dynamic_index_in_dim(dl, m, axis=0, keepdims=False)
        vm_m = jax.lax.dynamic_index_in_dim(vm, m, axis=0, keepdims=False)
        w_m = (
            jax.lax.dynamic_index_in_dim(w, m, axis=0, keepdims=False)
            if w is not None
            else None
        )
        svals = jnp.take(gathered, sg_m, axis=0)  # (E, ...) scratch-pad reads
        contrib = problem.edge_map(svals, w_m)
        identity = jnp.asarray(problem.identity, dtype=contrib.dtype)
        mask = vm_m.reshape(vm_m.shape + (1,) * (contrib.ndim - 1))
        contrib = jnp.where(mask, contrib, identity)
        if is_min:
            return jax.ops.segment_min(
                contrib, dl_m, num_segments=vpc, indices_are_sorted=True
            )
        return jax.ops.segment_sum(
            contrib, dl_m, num_segments=vpc, indices_are_sorted=True
        )

    if is_min and opts.immediate_updates:

        def phase(m, labels):
            reduced = phase_reduce(m, labels)
            lab = labels[problem.merge_field]
            new = dict(labels)
            new[problem.merge_field] = jnp.minimum(lab, reduced.astype(lab.dtype))
            return new

        return jax.lax.fori_loop(0, l, phase, labels)

    lab = labels[problem.merge_field]
    acc_dtype = jnp.float32 if problem.reduce_kind == "sum" else lab.dtype
    acc0 = jnp.full(lab.shape, problem.identity, dtype=acc_dtype)

    def phase(m, acc):
        reduced = phase_reduce(m, labels)
        if is_min:
            return jnp.minimum(acc, reduced.astype(acc.dtype))
        return acc + reduced.astype(acc.dtype)

    acc = jax.lax.fori_loop(0, l, phase, acc0)
    if is_min:
        new = dict(labels)
        new[problem.merge_field] = jnp.minimum(lab, acc.astype(lab.dtype))
        return new
    return problem.finalize(labels, acc)


def build_distributed_run(
    problem: Problem,
    pg: PartitionedGraph,
    mesh: Mesh,
    axis: str = "graph",
    opts: EngineOptions = EngineOptions(),
):
    """Returns run_fn(labels) -> (labels, iters, changed); labels pre-sharded
    over ``axis``."""

    def body(labels, sg, dl, vm, w):
        # shard_map blocks: leading p-dim of size 1 on each device -> squeeze
        labels = {k: (v[0] if getattr(v, "ndim", 0) >= 1 and v.shape[0] == 1 else v) for k, v in labels.items()}
        sg, dl, vm = sg[0], dl[0], vm[0]
        w = w[0] if w is not None else None

        def cond(carry):
            _, it, changed = carry
            return jnp.logical_and(changed, it < opts.max_iters)

        def step(carry):
            labels, it, _ = carry
            new = _device_iteration(problem, pg, opts, axis, labels, sg, dl, vm, w)
            local_changed = problem.not_converged(labels, new)
            changed = (
                jax.lax.psum(local_changed.astype(jnp.int32), axis) > 0
            )  # cores agree to stop only when NO core changed (processor ctrl)
            return new, it + 1, changed

        labels, iters, changed = jax.lax.while_loop(
            cond, step, (labels, jnp.int32(0), jnp.bool_(True))
        )
        labels = {k: (v[None] if getattr(v, "ndim", 0) >= 1 and v.shape[0] == pg.vertices_per_core else v) for k, v in labels.items()}
        return labels, iters, changed

    label_spec = lambda v: P(axis) if v.ndim >= 1 else P()  # noqa: E731
    edge_spec = P(axis, None, None)

    def make_specs(labels, has_w):
        in_specs = (
            {k: label_spec(np.asarray(v)) for k, v in labels.items()},
            edge_spec,
            edge_spec,
            edge_spec,
            edge_spec if has_w else None,
        )
        out_specs = (
            {k: label_spec(np.asarray(v)) for k, v in labels.items()},
            P(),
            P(),
        )
        return in_specs, out_specs

    def run_fn(labels):
        has_w = pg.weights is not None
        in_specs, out_specs = make_specs(labels, has_w)
        fn = jax.shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False)
        sg = jnp.asarray(pg.src_gidx)
        dl = jnp.asarray(pg.dst_lidx)
        vm = jnp.asarray(pg.valid)
        w = jnp.asarray(pg.weights) if has_w else None
        return jax.jit(fn)(labels, sg, dl, vm, w)

    return run_fn


def run_distributed(
    problem: Problem,
    g,
    pg: PartitionedGraph,
    mesh: Mesh,
    axis: str = "graph",
    opts: EngineOptions = EngineOptions(),
):
    """Convenience end-to-end: init labels, shard, run, unpad."""
    from repro.core.engine import prepare_labels

    assert pg.p == mesh.shape[axis], (pg.p, dict(mesh.shape))
    labels = prepare_labels(problem, g, pg)  # dict of (p, Vl) + scalars
    sharded = {}
    for k, v in labels.items():
        spec = P(axis) if getattr(v, "ndim", 0) >= 1 else P()
        sharded[k] = jax.device_put(v, NamedSharding(mesh, spec))
    run_fn = build_distributed_run(problem, pg, mesh, axis, opts)
    out, iters, changed = run_fn(sharded)
    from repro.core.engine import EngineResult

    return EngineResult(
        labels=unpad_labels({k: np.asarray(v) for k, v in out.items()}, pg),
        iterations=int(iters),
        converged=not bool(changed),
    )
