"""Frontier-compressed crossbar exchange (beyond-paper, docs/distributed.md §5).

The paper's crossbar always moves full label requests. For monotone
min-problems (BFS/WCC/SSSP) the set of labels that changed since a core last
broadcast its sub-interval — the *frontier* — collapses as the run converges;
late iterations touch a handful of vertices. This engine variant keeps a
replicated CACHE of every phase's gathered block and, per phase, exchanges
only (index, value) pairs of changed labels under a static ``budget`` K,
falling back to the full all-gather when any core's frontier exceeds K
(decided collectively with a pmax, so all cores take the same branch).

Wire cost per phase:  sparse  p * K * 8 bytes   vs   full  p * sub * 4 bytes
— a win whenever the widest per-core frontier < sub/2·K... i.e. nearly every
iteration after the expansion peak.

Semantics are IDENTICAL to the dense engine (tested): the cache is updated
with exactly the labels the dense path would re-gather.

Edge processing streams the COMPRESSED per-channel layout through
``channel_phase_reduce_pallas`` — the same single phase-reduce implementation
both engines run — against the cache row (which IS the phase's gathered
block), so the frontier engine no longer ships the flat (p, l, E_pad)
``src_gidx``/``dst_lidx``/``valid`` arrays that the compression work removed
from everything else (they used to double the resident edge footprint here),
and SSSP edge weights now flow through the packed weight stream instead of
being silently dropped. The exchange's changed-mask doubles as an EXACT live
frontier for dynamic tile scheduling: its word-packed form, all-gathered over
the same crossbar, drives ``frontier_active_tiles`` so tiles none of whose
sources changed since this phase's last broadcast are skipped outright
(iteration 0 is forced dense — the initial cache rows were never reduced).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import jax_compat

jax_compat.install()  # jax.shard_map on 0.4.x

from jax.sharding import Mesh, NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core import frontier_words as fwords  # noqa: E402
from repro.core.distributed import (  # noqa: E402
    _CONST_KEYS,
    place_channel_shards,
)
from repro.core.engine import (  # noqa: E402
    EngineOptions,
    EngineResult,
    channel_phase_reduce_pallas,
    channel_phase_scatter_pallas,
    dynamic_skip_enabled,
    phase_consts_at,
    prepare_labels,
    push_enabled,
    unpad_labels,
)
from repro.core.partition import PartitionedGraph
from repro.core.problems import Problem

__all__ = ["run_distributed_frontier", "frontier_wire_bytes"]


def _sparse_exchange(changed, payload_sub, cache_row, sub, axis, budget):
    """Exchange changed entries only; returns (new cache row, overflowed?).

    ``changed`` is a (sub,) per-VERTEX mask — for lane-batched payloads
    (sub, L) it is the caller's union over lanes, and each exchanged entry
    carries the vertex's whole L-wide payload row (one index word amortized
    over L lane values)."""
    count = changed.sum()
    max_count = jax.lax.pmax(count, axis)

    def sparse(cache_row):
        big = jnp.int32(sub)
        idx = jnp.where(changed, jnp.arange(sub, dtype=jnp.int32), big)
        idx = jnp.sort(idx)[:budget]  # changed indices first (padded with sub)
        vals = payload_sub[jnp.minimum(idx, sub - 1)]
        all_idx = jax.lax.all_gather(idx, axis, axis=0)  # (p, K)
        all_vals = jax.lax.all_gather(vals, axis, axis=0)  # (p, K[, L])
        p = all_idx.shape[0]
        base = jnp.arange(p, dtype=jnp.int32)[:, None] * sub
        flat_pos = jnp.where(all_idx < sub, base + all_idx, p * sub).reshape(-1)
        flat_val = all_vals.reshape(-1, *all_vals.shape[2:])
        padded = jnp.concatenate([cache_row, cache_row[-1:]])
        padded = padded.at[flat_pos].set(flat_val)
        return padded[:-1]

    def full(cache_row):
        return jax.lax.all_gather(payload_sub, axis, axis=0, tiled=True)

    overflow = max_count > budget
    new_row = jax.lax.cond(overflow, full, sparse, cache_row)
    return new_row, overflow, count


def run_distributed_frontier(
    problem: Problem,
    g,
    pg: PartitionedGraph,
    mesh: Mesh,
    axis: str = "graph",
    opts: EngineOptions = EngineOptions(),
    budget: int = 64,
) -> Tuple[EngineResult, Dict[str, np.ndarray]]:
    """Min-problem engine with frontier-compressed exchange. Returns the
    result plus per-run wire statistics (sparse phases vs full phases)."""
    assert problem.reduce_kind == "min" and opts.immediate_updates
    assert pg.p == mesh.shape[axis]
    if opts.backend != "pallas":
        raise ValueError(
            "run_distributed_frontier streams the compressed per-channel "
            f"layout (the Pallas phase reduce); backend={opts.backend!r} has "
            "no frontier variant"
        )
    sub, l, vpc = pg.sub_size, pg.l, pg.vertices_per_core

    consts = place_channel_shards(problem, pg, mesh, axis)  # raises if no tiles
    const_keys = tuple(k for k in _CONST_KEYS if consts[k] is not None)
    const_vals = tuple(consts[k] for k in const_keys)
    dyn = dynamic_skip_enabled(problem, pg, opts)
    push_on = push_enabled(problem, pg, opts)
    forced_push = opts.direction == "push"
    ws = fwords.words_per_sub(sub)
    word_pad = ws * fwords.WORD_BITS - sub
    # per-PHASE density threshold: a phase's frontier lives in the p active
    # sub-intervals (p * sub source bits), not the whole vertex set
    dense_thr = jnp.int32(int(pg.p * sub * opts.dynamic_skip_density))
    # per-phase direction switch over the same per-phase source bits. The
    # choice is STATELESS here (no cross-iteration hysteresis): each phase's
    # exchange count is an exact frontier popcount, so alpha alone decides —
    # forced 'push' only yields to the mandatory-dense iteration 0.
    lane_k = max(problem.lanes, 1)
    alpha_thr = jnp.int32(int(pg.p * sub * opts.direction_alpha / lane_k))
    if forced_push and not push_on:
        raise ValueError(
            "direction='push' requires a push stream (PartitionConfig."
            "build_push), a min/or reduce and dynamic tile scheduling"
        )

    labels0 = prepare_labels(problem, g, pg)
    sharded = {
        k: jax.device_put(
            v, NamedSharding(mesh, P(axis) if getattr(v, "ndim", 0) >= 1 else P())
        )
        for k, v in labels0.items()
    }

    def body(labels, *cvals):
        labels = {k: (v[0] if getattr(v, "ndim", 0) >= 1 and v.shape[0] == 1 else v)
                  for k, v in labels.items()}
        cm_all = dict(zip(const_keys, cvals))
        cm_all.update({k: None for k in _CONST_KEYS if k not in const_keys})
        coverage = cm_all.pop("coverage")
        # the push stream never enters the pull phase reduce: pop it and
        # re-key to the canonical stream names for the scatter primitive.
        push_cm = {
            "word": cm_all.pop("push_word"),
            "word_hi": cm_all.pop("push_word_hi"),
            "counts": cm_all.pop("push_counts"),
            "w": cm_all.pop("push_w"),
        }
        push_coverage = cm_all.pop("push_coverage")
        my_core = jax.lax.axis_index(axis)  # selects this core's cache slice
        payload0 = problem.src_transform(labels)
        # cache rows start from the true initial gathered blocks (one full
        # gather per phase — same cost the dense engine pays on iteration 1)
        init_rows = []
        for m in range(l):
            blk = jax.lax.dynamic_slice_in_dim(payload0, m * sub, sub, axis=0)
            init_rows.append(jax.lax.all_gather(blk, axis, axis=0, tiled=True))
        cache0 = jnp.stack(init_rows)  # (l, p*sub)

        def cond2(carry):
            _, _, it, changed, _, _ = carry
            return jnp.logical_and(changed, it < opts.max_iters)

        def body2(carry):
            labels, cache, it, _, ns, nf = carry

            def phase(m, pc):
                labels, cache, ns, nf = pc
                payload = problem.src_transform(labels)
                mine = jax.lax.dynamic_slice_in_dim(payload, m * sub, sub, axis=0)
                prev_mine = jax.lax.dynamic_slice(
                    cache,
                    (m, my_core * sub) + (0,) * (cache.ndim - 2),
                    (1, sub) + cache.shape[2:],
                )[0]
                row = jax.lax.dynamic_index_in_dim(cache, m, axis=0, keepdims=False)
                diff = mine != prev_mine  # changed since LAST broadcast
                # lane-batched payloads (sub, K): a vertex is exchanged iff
                # ANY lane changed — the union frontier, one (index, K-row)
                # pair on the wire per changed vertex.
                changed_src = diff.any(-1) if diff.ndim == 2 else diff
                new_row, overflow, count = _sparse_exchange(
                    changed_src, mine, row, sub, axis, budget
                )
                cache = jax.lax.dynamic_update_index_in_dim(cache, new_row, m, axis=0)
                active = None
                if dyn:
                    # the exchange's changed-mask IS the exact live frontier
                    # for phase m (changes since the tile could last have
                    # run), word-packed and ridden over the same crossbar as
                    # the label values. Iteration 0 must run dense: the
                    # initial cache rows were never reduced into any label.
                    local_fw = fwords.pack_bits(
                        jnp.pad(changed_src, (0, word_pad)) if word_pad
                        else changed_src
                    )  # (Ws,)
                    gfw = jax.lax.all_gather(local_fw, axis, axis=0, tiled=True)
                    pop = jax.lax.psum(count.astype(jnp.int32), axis)
                    use_dense = jnp.logical_or(it == 0, pop >= dense_thr)
                    cov_m = jax.lax.dynamic_index_in_dim(
                        coverage, m, axis=1, keepdims=False
                    )  # (1, R, T, Wc)
                    cnt_m = jax.lax.dynamic_index_in_dim(
                        cm_all["counts"], m, axis=1, keepdims=False
                    )  # (1, R)
                    active = fwords.frontier_active_tiles(
                        cov_m, gfw, cnt_m, use_dense
                    )
                if push_on:
                    # gfw is the exact union frontier for phase m, already on
                    # every device — the push active map reads it against the
                    # push stream's own coverage. The pop count is psum'd, so
                    # all devices take the same lax.cond branch and the
                    # all-gathers above stay aligned.
                    use_push = (
                        (it > 0) if forced_push
                        else jnp.logical_and(
                            jnp.logical_not(use_dense), pop < alpha_thr
                        )
                    )

                    def _pull(row):
                        return channel_phase_reduce_pallas(
                            problem, pg, row, phase_consts_at(cm_all, m), opts,
                            active,
                        )[0]

                    def _push(row):
                        pcov_m = jax.lax.dynamic_index_in_dim(
                            push_coverage, m, axis=1, keepdims=False
                        )  # (1, B, Tp, Wc)
                        pcnt_m = jax.lax.dynamic_index_in_dim(
                            push_cm["counts"], m, axis=1, keepdims=False
                        )  # (1, B)
                        pactive = fwords.frontier_active_tiles(
                            pcov_m, gfw, pcnt_m, None
                        )
                        return channel_phase_scatter_pallas(
                            problem, pg, row, phase_consts_at(push_cm, m),
                            opts, pactive,
                        )[0]

                    reduced = jax.lax.cond(use_push, _push, _pull, new_row)
                else:
                    reduced = channel_phase_reduce_pallas(
                        problem, pg, new_row, phase_consts_at(cm_all, m), opts,
                        active,
                    )[0]  # (Vl,)
                lab = labels[problem.merge_field]
                new = dict(labels)
                new[problem.merge_field] = jnp.minimum(lab, reduced.astype(lab.dtype))
                return (
                    new, cache,
                    ns + (1 - overflow.astype(jnp.int32)),
                    nf + overflow.astype(jnp.int32),
                )

            new, cache, ns, nf = jax.lax.fori_loop(
                0, l, phase, (labels, cache, ns, nf)
            )
            changed = jax.lax.psum(
                problem.not_converged(labels, new).astype(jnp.int32), axis
            ) > 0
            return new, cache, it + 1, changed, ns, nf

        labels, cache, iters, changed, nsparse, nfull = jax.lax.while_loop(
            cond2, body2,
            (labels, cache0, jnp.int32(0), jnp.bool_(True), jnp.int32(0), jnp.int32(0)),
        )
        labels = {k: (v[None] if getattr(v, "ndim", 0) >= 1 and v.shape[0] == vpc else v)
                  for k, v in labels.items()}
        return labels, iters, changed, nsparse, nfull

    label_spec = {k: (P(axis) if getattr(np.asarray(v), "ndim", 0) >= 1 else P())
                  for k, v in labels0.items()}
    fn = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(
            label_spec,
            *(P(axis, *([None] * (v.ndim - 1))) for v in const_vals),
        ),
        out_specs=(label_spec, P(), P(), P(), P()),
        check_vma=False,
    )
    out, iters, changed, nsparse, nfull = jax.jit(fn)(sharded, *const_vals)
    merge = np.asarray(out[problem.merge_field])
    # per-vertex payload bytes: lane-batched labels ship the whole lane row
    lane_w = merge.shape[-1] if problem.lanes > 0 else 1
    stats = frontier_wire_bytes(pg, int(nsparse), int(nfull), budget,
                                merge.dtype.itemsize * lane_w)
    res = EngineResult(
        labels=unpad_labels({k: np.asarray(v) for k, v in out.items()}, pg),
        iterations=int(iters),
        converged=not bool(changed),
    )
    return res, stats


def frontier_wire_bytes(pg, nsparse: int, nfull: int, budget: int, label_bytes: int):
    """Per-device wire bytes: sparse phase = p*K*(4+label); full = p*sub*label.
    Includes the one-time initial full gather of all l phases."""
    p, sub, l = pg.p, pg.sub_size, pg.l
    full_phase = p * sub * label_bytes
    sparse_phase = p * budget * (4 + label_bytes)
    dense_equivalent = (nsparse + nfull + l) * full_phase
    actual = l * full_phase + nsparse * sparse_phase + nfull * full_phase
    return {
        "sparse_phases": nsparse,
        "full_phases": nfull,
        "bytes_actual": actual,
        "bytes_dense_equivalent": dense_equivalent,
        "reduction": dense_equivalent / max(actual, 1),
    }
