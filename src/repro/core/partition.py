"""Two-dimensional graph partitioning (paper §III-C) + stride mapping.

Dimension 1: the (padded) vertex set is split into ``p`` equal intervals
``I_q`` — one per graph core / mesh device; core ``q`` owns all edges whose
*destination* lies in ``I_q`` (pull-based horizontal partitioning of the
inverse edge set).

Dimension 2: each interval is split into ``l`` equal sub-intervals ``J`` of
``sub_size`` vertices — sized so a sub-interval's labels fit the label scratch
pad (FPGA: BRAM; TPU: the per-phase gathered VMEM block). Sub-partition
``S[i, m]`` holds edges with dst ∈ I_i and src ∈ ∪_q J[q, m]; the ``p``
sub-intervals { J[q, m] : q } active at phase ``m`` form meta-partition M_m.

Neighbor indices are rewritten at partition time so that a source vertex id
becomes a direct offset into the phase's gathered label block:
``gathered_idx = src_core * sub_size + (src mod sub_size)`` — the TPU analogue
of the paper's "first log2(p) bits address the core" crossbar routing.

On top of the (p, l, E_pad) bucket layout, ``partition_2d`` also precomputes
the COMPRESSED Pallas edge stream the fused engine hot path consumes (paper
§III: "compressed graph representation"): every (core, phase) bucket is binned
into (R, T, Eb) row-block edge tiles (``prepare_tiles``) with degree-aware LPT
row packing, each edge slot's (src, dstb, valid) index triple is bit-packed
into a single int32 word (``pack_edge_words``), and the words are stacked into
one (p, l, R, T, Eb) array so a single ``pallas_call`` per phase runs all
cores. Packed word format (decoded in-kernel with shifts/masks):

  src_bits=16 (when p * sub_size <= 2^16 and vb <= 2^15 — the common case):
      tile_word    = valid<<31 | dstb<<16 | src           4 index B/edge
  src_bits=32 (fallback for larger gathered blocks):
      tile_word    = src
      tile_word_hi = valid<<31 | dstb                     8 index B/edge

vs 9 B/edge for the uncompressed (int32, int32, bool) triple. ``tile_counts``
holds the per-(core, phase, row-block) count of REAL edge tiles so the kernel
skips all-padding tiles outright (variable-T early-out) instead of streaming
them. ``tile_row_pos`` records the per-bucket row permutation degree-aware
packing introduced (the engine un-permutes kernel output with one static
gather).

Everything here is host-side numpy; outputs are static-shape arrays.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional, Union

import numpy as np

from repro.core.graph import COOGraph

__all__ = [
    "PartitionConfig",
    "PartitionedGraph",
    "EdgeCentricPartition",
    "DeltaFlushReport",
    "stride_permutation",
    "apply_permutation",
    "partition_2d",
    "partition_2d_streaming",
    "coo_edge_chunks",
    "partition_edge_centric",
    "bucket_coords",
    "apply_edge_deltas",
]


@dataclasses.dataclass(frozen=True)
class PartitionConfig:
    p: int  # graph cores == memory channels == mesh devices
    l: int  # sub-intervals per interval (scratch-pad phases)
    lane: int = 8  # sub_size alignment (TPU lane quantum; 128 on real HW)
    edge_pad: int = 8  # per-bucket edge-count alignment
    stride: Optional[int] = None  # stride mapping (paper uses 100); None = off
    scratch_size: Optional[int] = None  # if set, l is derived: labels per core phase
    # fused-kernel tile layout (consumed by EngineOptions(backend='pallas')):
    build_tiles: bool = True  # False skips the host-side binning (xla-only use)
    tile_vb: Optional[int] = None  # row-block height; None = sub_size (R = l)
    tile_eb: int = 128  # edge-tile width (lane quantum on real HW)
    degree_aware_tiles: bool = True  # LPT row packing (see prepare_tiles)
    pack_src_bits: Optional[int] = None  # force 16/32-bit regime; None = auto
    # hub-row splitting (two-level reduce): the max edge count of one kernel
    # row. 'auto' = per bucket max(tile_eb, ceil(E_bucket / R)) — no virtual
    # row exceeds the mean row-block load, floored at one tile width. An int
    # fixes the cap for every bucket. None disables splitting entirely (the
    # pre-split layout is preserved byte-for-byte). Requires
    # degree_aware_tiles: virtual rows only pay off when the LPT packer can
    # spread them across row blocks.
    split_threshold: Union[str, int, None] = "auto"  # 'auto' | int | None
    # push (scatter) direction: a second CSC-style stream of the SAME edges
    # binned by source block so a narrow frontier streams only its own
    # out-edges (Beamer direction-optimizing traversal, docs/tile_layout.md
    # §9). push_block must be a multiple of 32 (frontier-word alignment).
    # None auto-sizes a block to hold ~2 full edge tiles of the bucket's
    # average degree: fewer, denser blocks mean a smaller (B, Tp) scatter
    # grid and less cross-block T padding, while frontier selectivity is
    # preserved by the per-TILE coverage words (edges are source-sorted
    # within a block, so each tile covers a narrow source range).
    build_push: bool = True  # False skips the push stream (pull-only layout)
    push_block: Optional[int] = None  # gathered sources per push block
    # push edge-tile width; None = tile_eb. The scatter accumulator is the
    # whole per-core row (no row blocking), so wider push tiles shrink the
    # (B, Tp) grid without the load-balance concerns the pull layout's
    # row-blocked tiles have — on a narrow frontier the grid-step count,
    # not the per-tile edge work, is what the direction switch is buying.
    push_eb: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class PartitionedGraph:
    """Static-shape 2-D partitioned inverse-CSR-equivalent edge layout.

    Edge arrays are laid out (p, l, E_pad): bucket [i, m] is sub-partition
    S[i, m] sorted by local destination. ``src_gidx`` indexes the phase-m
    gathered block (size p * sub_size); ``dst_lidx`` indexes core i's local
    label shard (size l * sub_size).
    """

    p: int
    l: int
    sub_size: int
    num_vertices: int  # real V
    num_edges: int  # real E
    src_gidx: np.ndarray  # (p, l, E_pad) int32
    dst_lidx: np.ndarray  # (p, l, E_pad) int32
    valid: np.ndarray  # (p, l, E_pad) bool
    weights: Optional[np.ndarray]  # (p, l, E_pad) float32 or None
    perm: Optional[np.ndarray]  # old -> new vertex id (stride mapping), or None
    inv_perm: Optional[np.ndarray]
    bucket_sizes: np.ndarray  # (p, l) int64 — real edges per sub-partition
    # stacked fused-kernel COMPRESSED edge stream (one TileLayout per bucket,
    # bit-packed, uniform (R, T) so all p cores of a phase launch as one
    # pallas_call grid — see module docstring for the word format):
    tile_word: Optional[np.ndarray] = None  # (p, l, R, T, Eb) int32 packed
    tile_word_hi: Optional[np.ndarray] = None  # (p, l, R, T, Eb) int32 (32-bit regime)
    tile_counts: Optional[np.ndarray] = None  # (p, l, R) int32 real tiles per block
    tile_weights: Optional[np.ndarray] = None  # (p, l, R, T, Eb) f32 or None
    tile_row_pos: Optional[np.ndarray] = None  # (p, l, Vl) int32 or None
    # per-tile source-coverage bitmaps (frontier-aware dynamic skipping):
    # bit j of tile (i, m, r, t)'s word set iff the tile reads a source in
    # frontier word j of phase m's gathered block. Wc = ceil(p * Ws / 32)
    # with Ws = ceil(sub_size / 32) — see core/frontier_words.py and
    # docs/tile_layout.md §7 for the shared layout contract.
    tile_coverage: Optional[np.ndarray] = None  # (p, l, R, T, Wc) uint32
    tile_vb: int = 0  # row-block height (0 = tiles not built)
    src_bits: int = 0  # packed-word regime: 16 or 32 (0 = tiles not built)
    # hub-row splitting (two-level reduce). When any bucket split a row,
    # tile_row_pos is None and these take over; R may exceed Vl / vb:
    # packed kernel-output position -> natural row (-1 = spare, identity):
    tile_row_orig: Optional[np.ndarray] = None  # (p, l, R * vb) int32
    # gather form of the same map, what the engine's level-2 combine reads:
    tile_split_map: Optional[np.ndarray] = None  # (p, l, Vl, S_max) int32, -1 pad
    split_rows: int = 0  # natural (bucket, row) pairs split into > 1 virtual rows
    t_max_unsplit: int = 0  # T the stacked stream would need without splitting
    # push (scatter) stream: the SAME edge set, re-binned by SOURCE block
    # (B = ceil(gathered_size / push_block) blocks of push_block gathered
    # sources each) so a narrow frontier activates only the blocks that
    # contain frontier sources. Same bit-packed word format, but the dstb
    # field carries the FULL local destination row in [0, Vl) — the scatter
    # kernel's accumulator is the whole per-core label row. push_coverage is
    # tile_coverage_words over the push stream; ANDed against the frontier
    # it IS the push-mode tile scheduler (docs/tile_layout.md §9).
    push_word: Optional[np.ndarray] = None  # (p, l, B, Tp, Eb) int32 packed
    push_word_hi: Optional[np.ndarray] = None  # (p, l, B, Tp, Eb) | None
    push_counts: Optional[np.ndarray] = None  # (p, l, B) int32 real tiles
    push_weights: Optional[np.ndarray] = None  # (p, l, B, Tp, Eb) f32 | None
    push_coverage: Optional[np.ndarray] = None  # (p, l, B, Tp, Wc) uint32
    push_src_bits: int = 0  # push packed-word regime (0 = push not built)
    push_block: int = 0  # gathered sources per push block (0 = not built)
    # the config that built this layout — carried so delta ingestion
    # (``apply_edge_deltas``) can re-tile dirty buckets under the exact same
    # layout rules (thresholds, tile widths, push sizing) without the caller
    # re-supplying them. None on hand-built partitions: delta ingest refuses.
    config: Optional[PartitionConfig] = None

    @property
    def vertices_per_core(self) -> int:
        return self.l * self.sub_size

    @property
    def padded_vertices(self) -> int:
        return self.p * self.l * self.sub_size

    @property
    def gathered_size(self) -> int:
        return self.p * self.sub_size

    @property
    def edge_pad(self) -> int:
        return int(self.src_gidx.shape[-1])

    @property
    def padding_ratio(self) -> float:
        """Padded-slot fraction — the TPU cost of load imbalance (paper §IV-A:
        'imbalanced partitions lead to a lot of idle time')."""
        total_slots = self.p * self.l * self.edge_pad
        return 1.0 - float(self.bucket_sizes.sum()) / max(total_slots, 1)

    @property
    def imbalance(self) -> float:
        """max/mean real edges over buckets (1.0 = perfectly balanced)."""
        mean = self.bucket_sizes.mean()
        return float(self.bucket_sizes.max() / mean) if mean > 0 else 1.0

    @property
    def tile_padding_ratio(self) -> float:
        """Padded-slot fraction of the fused-kernel tile layout — what
        degree-aware row packing minimizes (hub rows no longer set T for
        every row block). Every real edge occupies exactly one tile slot, so
        this no longer needs a materialized valid array."""
        if self.tile_word is None:
            return 0.0
        return 1.0 - float(self.bucket_sizes.sum()) / max(self.tile_word.size, 1)

    @property
    def stream_bytes_per_edge(self) -> float:
        """Index-stream bytes per PULL edge slot of the compressed layout: 4
        in the 16-bit packed regime (8 in the 32-bit fallback) vs 9
        uncompressed (int32 src + int32 dstb + bool valid). When the push
        (scatter) stream is built it stores the same edges a second time, so
        its packed words are charged here too — amortized over the pull
        slots so records stay comparable across layouts. Payload weights,
        when present, add 4 more on both layouts and are excluded here."""
        if self.tile_word is None:
            return 0.0
        pull = 4.0 * (1 if self.tile_word_hi is None else 2)
        if self.push_word is None:
            return pull
        push = 4.0 * (1 if self.push_word_hi is None else 2)
        return pull + push * self.push_word.size / max(self.tile_word.size, 1)

    @property
    def skipped_tile_fraction(self) -> float:
        """Fraction of (core, phase, row-block) edge tiles the kernel's
        scalar-prefetched tile-count early-out never streams or decodes."""
        if self.tile_counts is None or self.tile_word is None:
            return 0.0
        t_max = self.tile_word.shape[3]
        total = self.tile_counts.size * t_max
        return 1.0 - float(self.tile_counts.sum()) / max(total, 1)

    @property
    def packed_rows_per_core(self) -> int:
        """Kernel-output rows per core: R * vb. Equals vertices_per_core
        unless hub-row splitting grew R to make room for virtual rows."""
        if self.tile_word is None:
            return self.vertices_per_core
        return int(self.tile_word.shape[2]) * self.tile_vb

    @property
    def split_row_fraction(self) -> float:
        """Fraction of natural (core, phase, row) slots hub-row splitting
        broke into > 1 virtual rows (0.0 when splitting is off or no row
        crossed the threshold)."""
        total = self.p * self.l * self.vertices_per_core
        return self.split_rows / max(total, 1)

    def channel_arrays(self, problem=None) -> dict:
        """The per-channel COMPRESSED edge stream, keyed for the engines.

        Every array's leading axis is the core axis — one graph core == one
        memory channel == one mesh device (docs/distributed.md) — and
        ``stack_packed_tiles`` already padded the per-bucket ragged (R, T)
        to the max over ALL (core, phase) buckets, so slice ``[q]`` is core
        q's complete, uniformly-shaped channel shard: the distributed engine
        ``NamedSharding``-places these over the ``graph`` mesh axis and each
        device streams exactly its own packed words + tile counts (never the
        flat (l, E_pad) src/dst/valid arrays). Keys match the engine's packed
        edge-constant dict (``word``/``word_hi``/``counts``/``w``/
        ``row_pos``/``split_map``; absent components are None).

        ``problem``: when given, the weight stream is dropped unless the
        problem's map UDF consumes it (``edge_op == 'add'``) — the kernel
        then adds unit weight in registers. This is THE weight-streaming
        rule; both engines get it from here so they cannot drift. The
        coverage bitmaps follow the same rule: they are dropped unless the
        problem's reduce is ``min`` — frontier skipping is only sound for
        monotone min problems (a skipped tile's sources re-contribute values
        already merged into the labels), while a sum reduce needs EVERY
        contribution every iteration, so PageRank streams dense.
        """
        if self.tile_word is None:
            raise ValueError(
                "packed edge stream not built; re-partition with "
                "PartitionConfig(build_tiles=True)"
            )
        arrs = {
            "word": self.tile_word,  # (p, l, R, T, Eb) int32 packed
            "word_hi": self.tile_word_hi,  # (p, l, R, T, Eb) | None
            "counts": self.tile_counts,  # (p, l, R)
            "w": self.tile_weights,  # (p, l, R, T, Eb) f32 | None
            "row_pos": self.tile_row_pos,  # (p, l, Vl) | None
            "split_map": self.tile_split_map,  # (p, l, Vl, S_max) | None
            "coverage": self.tile_coverage,  # (p, l, R, T, Wc) u32 | None
            "push_word": self.push_word,  # (p, l, B, Tp, Eb) | None
            "push_word_hi": self.push_word_hi,  # (p, l, B, Tp, Eb) | None
            "push_counts": self.push_counts,  # (p, l, B) | None
            "push_w": self.push_weights,  # (p, l, B, Tp, Eb) | None
            "push_coverage": self.push_coverage,  # (p, l, B, Tp, Wc) | None
        }
        if problem is not None and problem.edge_op != "add":
            arrs["w"] = None
            arrs["push_w"] = None
        # frontier coverage is only sound for monotone reduces: min and the
        # packed multi-source-BFS word OR. Sum problems must stay dense.
        # The entire push stream follows the same rule — scattering only the
        # frontier blocks' out-edges relies on skipped contributions being
        # already merged, which only holds for idempotent monotone reduces
        # (sum needs every contribution every iteration: push stays off).
        if problem is not None and problem.reduce_kind not in ("min", "or"):
            arrs["coverage"] = None
            for k in (
                "push_word", "push_word_hi", "push_counts",
                "push_w", "push_coverage",
            ):
                arrs[k] = None
        return arrs

    @property
    def coverage_bytes_per_edge(self) -> float:
        """Index-stream overhead of the coverage metadata, amortized per edge
        slot: Wc words per (Eb-slot) tile — e.g. 1/32 B/edge at Eb=128,
        Wc=1 — vs the 4-8 B/edge packed words it lets the engine skip. Push
        coverage words, when built, are counted too (same denominator)."""
        if self.tile_coverage is None or self.tile_word is None:
            return 0.0
        cov = self.tile_coverage.size
        if self.push_coverage is not None:
            cov += self.push_coverage.size
        return 4.0 * cov / max(self.tile_word.size, 1)

    @property
    def t_max_reduction(self) -> float:
        """Stacked-stream T_max as a fraction of what the UNSPLIT layout
        would need (the single fattest row block): 1.0 = splitting off or
        no effect; the acceptance target on star-like graphs is <= 0.5."""
        if self.tile_word is None or self.t_max_unsplit <= 0:
            return 1.0
        return float(self.tile_word.shape[3]) / float(self.t_max_unsplit)

    def in_neighbors(self, v: int) -> np.ndarray:
        """Decode vertex ``v``'s in-neighbors straight from the resident flat
        bucket layout (host-side, no engine run) — the serving router's
        "neighbors-of" path. All of v's in-edges live in core ``v // vpc``
        (dim-1 ownership), one slice per phase; the gathered index is
        inverted back to a global source id and the stride permutation is
        undone. Order is the bucket stream order (phase-major, then the
        bucket's dst-sorted order), which is deterministic for a given
        partition — and bit-identical between an incrementally flushed
        partition and a cold repartition of the same final edge list."""
        if not 0 <= int(v) < self.num_vertices:
            raise ValueError(f"vertex {v} out of range [0, {self.num_vertices})")
        vv = int(self.perm[int(v)]) if self.perm is not None else int(v)
        vpc, sub = self.vertices_per_core, self.sub_size
        i, lidx = vv // vpc, vv % vpc
        out = []
        for m in range(self.l):
            sel = self.valid[i, m] & (self.dst_lidx[i, m] == lidx)
            g = self.src_gidx[i, m][sel].astype(np.int64)
            out.append((g // sub) * vpc + m * sub + (g % sub))
        srcs = np.concatenate(out) if out else np.zeros(0, np.int64)
        if self.inv_perm is not None:
            srcs = self.inv_perm[srcs]
        return srcs.astype(np.int64)

    def memory_report(self) -> dict:
        """Byte accounting of the resident layout, field by field.

        ``device`` covers the arrays the engines ship to the accelerator (the
        packed edge/coverage streams plus counts and row maps); ``host_flat``
        covers the flat (p, l, E_pad) bucket arrays that stay host-side for
        delta ingestion and serving. ``device_bytes_per_edge`` is the
        footprint metric the bounded-memory acceptance checks compare peak
        build RSS against (the packed stream IS the final partition
        footprint; the flat arrays are reported separately because a
        memmap-backed build keeps them on disk)."""
        device_fields = (
            "tile_word", "tile_word_hi", "tile_counts", "tile_weights",
            "tile_coverage", "tile_row_pos", "tile_row_orig",
            "tile_split_map", "push_word", "push_word_hi", "push_counts",
            "push_weights", "push_coverage",
        )
        flat_fields = ("src_gidx", "dst_lidx", "valid", "weights")
        device = {
            name: int(getattr(self, name).nbytes)
            for name in device_fields
            if getattr(self, name) is not None
        }
        host_flat = {
            name: int(getattr(self, name).nbytes)
            for name in flat_fields
            if getattr(self, name) is not None
        }
        device_total = sum(device.values())
        flat_total = sum(host_flat.values())
        e = max(self.num_edges, 1)
        return {
            "device": device,
            "host_flat": host_flat,
            "device_total_bytes": device_total,
            "host_flat_total_bytes": flat_total,
            "total_bytes": device_total + flat_total,
            "device_bytes_per_edge": device_total / e,
            "bytes_per_edge": (device_total + flat_total) / e,
        }


def stride_permutation(num_vertices: int, stride: int = 100) -> np.ndarray:
    """Paper §III-C stride mapping: new order v0, v100, v200, ..., v1, v101, ...

    Returns ``perm`` with ``perm[old_id] = new_id``.
    """
    order = np.lexsort(
        (np.arange(num_vertices) // stride, np.arange(num_vertices) % stride)
    )
    # order[k] = old id at new position k  ->  invert
    perm = np.empty(num_vertices, dtype=np.int64)
    perm[order] = np.arange(num_vertices, dtype=np.int64)
    return perm


def apply_permutation(g: COOGraph, perm: np.ndarray) -> COOGraph:
    return COOGraph(
        src=perm[g.src].astype(np.uint32),
        dst=perm[g.dst].astype(np.uint32),
        num_vertices=g.num_vertices,
        weights=g.weights,
    )


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _resolve_dims(num_vertices: int, cfg: PartitionConfig) -> tuple[int, int, int, int]:
    """Resolve (p, l, sub_size, vpc) under cfg's scratch/lane rules.

    Shared by the in-memory and streaming builders so the two paths can never
    disagree on partition shapes (l derivation from scratch capacity, lane
    rounding of sub_size)."""
    p, l = cfg.p, cfg.l
    if cfg.scratch_size is not None:
        # derive l from scratch capacity (paper: sub-interval fits scratch pad)
        per_core = _round_up(-(-num_vertices // p), cfg.lane)
        l = max(1, -(-per_core // cfg.scratch_size))
    sub_size = _round_up(-(-num_vertices // (p * l)), cfg.lane)
    return p, l, sub_size, l * sub_size


def partition_2d(g: COOGraph, cfg: PartitionConfig) -> PartitionedGraph:
    """Partition the *processing* edge set (u -> v means "v pulls from u").

    ``g`` must already be the edge set in pull orientation (for BFS/WCC/SSSP/PR
    on directed input, pass the original COO: dst pulls from src along inverse
    edges, which is exactly iterating (src, dst) grouped by dst).
    """
    perm = inv = None
    if cfg.stride is not None and cfg.stride > 1:
        perm = stride_permutation(g.num_vertices, cfg.stride)
        inv = np.argsort(perm)
        g = apply_permutation(g, perm)

    p, l, sub_size, vpc = _resolve_dims(g.num_vertices, cfg)

    src = g.src.astype(np.int64)
    dst = g.dst.astype(np.int64)
    core = dst // vpc  # dim-1: destination interval owns the edge
    phase = (src % vpc) // sub_size  # dim-2: source sub-interval index
    src_core = src // vpc
    gidx = src_core * sub_size + (src % sub_size)  # crossbar routing rewrite
    lidx = dst % vpc

    # bucket sort by (core, phase), then by local dst inside each bucket
    key = (core * l + phase) * (vpc + 1) + lidx
    order = np.argsort(key, kind="stable")
    core, phase, gidx, lidx = core[order], phase[order], gidx[order], lidx[order]
    w = g.weights[order] if g.weights is not None else None

    bucket_id = core * l + phase
    sizes = np.bincount(bucket_id, minlength=p * l).reshape(p, l)
    e_pad = max(_round_up(int(sizes.max()), cfg.edge_pad), cfg.edge_pad)

    src_gidx = np.zeros((p, l, e_pad), dtype=np.int32)
    # padding edges point at the LAST local row so per-bucket dst stays sorted
    # (segment reduces use indices_are_sorted=True); they carry the reduce
    # identity so the row's value is unaffected.
    dst_lidx = np.full((p, l, e_pad), vpc - 1, dtype=np.int32)
    valid = np.zeros((p, l, e_pad), dtype=bool)
    weights = np.zeros((p, l, e_pad), dtype=np.float32) if w is not None else None

    starts = np.zeros(p * l + 1, dtype=np.int64)
    np.cumsum(sizes.ravel(), out=starts[1:])
    for i in range(p):
        for m in range(l):
            b = i * l + m
            s, e = starts[b], starts[b + 1]
            n = int(e - s)
            src_gidx[i, m, :n] = gidx[s:e]
            dst_lidx[i, m, :n] = lidx[s:e]
            valid[i, m, :n] = True
            if weights is not None:
                weights[i, m, :n] = w[s:e]

    tiles = (
        _build_tile_layouts(
            p, l, vpc, src_gidx, dst_lidx, valid, weights, cfg, sub_size
        )
        if cfg.build_tiles
        else {}
    )

    return PartitionedGraph(
        p=p,
        l=l,
        sub_size=sub_size,
        num_vertices=g.num_vertices,
        num_edges=g.num_edges,
        src_gidx=src_gidx,
        dst_lidx=dst_lidx,
        valid=valid,
        weights=weights,
        perm=perm,
        inv_perm=inv,
        bucket_sizes=sizes,
        config=cfg,
        **tiles,
    )


def _bucket_split_threshold(cfg: PartitionConfig, bucket_edges: int, r_blocks: int):
    """Resolve cfg.split_threshold for one bucket (None = splitting off)."""
    if cfg.split_threshold is None or not cfg.degree_aware_tiles:
        return None
    if cfg.split_threshold == "auto":
        # cap every kernel row at the bucket's MEAN row-block load (a row at
        # the mean cannot raise T above it) but never below one tile width —
        # sub-tile chunks cost R without shrinking T.
        return max(cfg.tile_eb, -(-int(bucket_edges) // max(r_blocks, 1)))
    return int(cfg.split_threshold)


def _build_tile_layouts(p, l, vpc, src_gidx, dst_lidx, valid, weights, cfg, sub_size):
    """Bin every (core, phase) bucket into (R, T, Eb) row-block tiles, bit-pack
    each slot's index triple into the compressed word stream, and stack to
    (p, l, R, T, Eb) with uniform (R, T) (max over buckets; padded tiles are
    recorded in ``tile_counts`` so the kernel skips them) so the engine
    launches all cores of a phase in one pallas_call. Hub rows above the
    split threshold become virtual rows (see prepare_tiles); when any bucket
    split, ``tile_row_orig``/``tile_split_map`` replace ``tile_row_pos`` and
    the engine runs the two-level reduce."""
    from repro.kernels.csr_gather_reduce.ops import (
        choose_src_bits,
        prepare_push_tiles,
        prepare_tiles,
        split_map_from_row_orig,
        stack_packed_tiles,
        stack_push_tiles,
        tile_coverage_words,
    )

    vb = cfg.tile_vb if cfg.tile_vb is not None else sub_size
    assert vpc % vb == 0, (vpc, vb)
    eb = cfg.tile_eb
    src_bits = (
        cfg.pack_src_bits
        if cfg.pack_src_bits is not None
        else choose_src_bits(p * sub_size, vb)
    )
    layouts = [
        [
            prepare_tiles(
                src_gidx[i, m], dst_lidx[i, m], valid[i, m],
                num_rows=vpc, vb=vb, eb=eb,
                weights=weights[i, m] if weights is not None else None,
                balance_rows=cfg.degree_aware_tiles,
                split_threshold=_bucket_split_threshold(
                    cfg, int(valid[i, m].sum()), vpc // vb
                ),
            )
            for m in range(l)
        ]
        for i in range(p)
    ]
    flat = [layouts[i][m] for i in range(p) for m in range(l)]
    word, word_hi, counts, wts = stack_packed_tiles(flat, src_bits=src_bits)
    r_blocks, t_max = word.shape[1], word.shape[2]
    tile_word = word.reshape(p, l, r_blocks, t_max, eb)
    tile_word_hi = (
        word_hi.reshape(p, l, r_blocks, t_max, eb) if word_hi is not None else None
    )
    tile_counts = counts.reshape(p, l, r_blocks)
    tile_weights = (
        wts.reshape(p, l, r_blocks, t_max, eb) if wts is not None else None
    )
    tile_coverage = tile_coverage_words(
        tile_word, tile_word_hi, src_bits=src_bits, p=p, sub_size=sub_size
    )
    any_split = any(t.row_orig is not None for row in layouts for t in row)
    tile_row_pos = tile_row_orig = tile_split_map = None
    split_rows = 0
    t_max_unsplit = max(t.t_tiles_unsplit for t in flat)
    if any_split:
        # every bucket needs a row_orig map (split or not) so one uniform
        # (p, l, Vl, S_max) gather drives the engine's level-2 combine.
        packed_rows = r_blocks * vb
        tile_row_orig = np.full((p, l, packed_rows), -1, dtype=np.int32)
        maps = []
        for i in range(p):
            for m in range(l):
                t = layouts[i][m]
                if t.row_orig is not None:
                    ro = t.row_orig
                elif t.row_pos is not None:
                    ro = np.full(vpc, -1, dtype=np.int32)
                    ro[t.row_pos] = np.arange(vpc, dtype=np.int32)
                else:
                    ro = np.arange(vpc, dtype=np.int32)
                tile_row_orig[i, m, : ro.shape[0]] = ro
                maps.append(split_map_from_row_orig(tile_row_orig[i, m], vpc))
                split_rows += t.num_split_rows
        s_max = max(sm.shape[1] for sm in maps)
        tile_split_map = np.full((p, l, vpc, s_max), -1, dtype=np.int32)
        for b, sm in enumerate(maps):
            tile_split_map[b // l, b % l, :, : sm.shape[1]] = sm
    else:
        any_packed = any(t.row_pos is not None for row in layouts for t in row)
        tile_row_pos = (
            np.tile(np.arange(vpc, dtype=np.int32), (p, l, 1)) if any_packed else None
        )
        if tile_row_pos is not None:
            for i in range(p):
                for m in range(l):
                    t = layouts[i][m]
                    if t.row_pos is not None:
                        tile_row_pos[i, m] = t.row_pos
    push = {}
    if cfg.build_push:
        # push (scatter) stream: same edges, binned by SOURCE block. The
        # packed dstb field holds the FULL local destination row [0, vpc),
        # so the 16-bit regime additionally needs vpc <= 2^15; an explicit
        # pack_src_bits=32 forces both streams into the wide regime.
        push_src_bits = (
            cfg.pack_src_bits
            if cfg.pack_src_bits is not None
            else choose_src_bits(p * sub_size, vpc)
        )
        gathered = p * sub_size
        peb = cfg.push_eb if cfg.push_eb is not None else eb
        push_block = cfg.push_block
        if push_block is None:
            # auto-size: ~2 full push-tile widths of the average bucket
            # degree per block, 32-aligned, clamped to one gathered block
            total_edges = int(np.asarray(valid).sum())
            avg_deg = total_edges / max(p * l, 1) / max(gathered, 1)
            want = 2.0 * peb / max(avg_deg, 1e-9)
            push_block = 32 * max(1, int(round(want / 32.0)))
            push_block = min(push_block, 32 * ((gathered + 31) // 32))
        push_layouts = [
            prepare_push_tiles(
                src_gidx[i, m], dst_lidx[i, m], valid[i, m],
                gathered_size=gathered,
                block_sources=push_block,
                num_rows=vpc, eb=peb,
                weights=weights[i, m] if weights is not None else None,
            )
            for i in range(p)
            for m in range(l)
        ]
        pw, pw_hi, pcnt, pwts = stack_push_tiles(
            push_layouts, src_bits=push_src_bits
        )
        b_blocks, tp_max = pw.shape[1], pw.shape[2]
        push_word = pw.reshape(p, l, b_blocks, tp_max, peb)
        push_word_hi = (
            pw_hi.reshape(p, l, b_blocks, tp_max, peb)
            if pw_hi is not None
            else None
        )
        push = dict(
            push_word=push_word,
            push_word_hi=push_word_hi,
            push_counts=pcnt.reshape(p, l, b_blocks),
            push_weights=(
                pwts.reshape(p, l, b_blocks, tp_max, peb)
                if pwts is not None
                else None
            ),
            push_coverage=tile_coverage_words(
                push_word, push_word_hi,
                src_bits=push_src_bits, p=p, sub_size=sub_size,
            ),
            push_src_bits=push_src_bits,
            push_block=push_block,
        )
    return dict(
        tile_word=tile_word,
        tile_word_hi=tile_word_hi,
        tile_counts=tile_counts,
        tile_weights=tile_weights,
        tile_row_pos=tile_row_pos,
        tile_coverage=tile_coverage,
        tile_vb=vb,
        src_bits=src_bits,
        tile_row_orig=tile_row_orig,
        tile_split_map=tile_split_map,
        split_rows=split_rows,
        t_max_unsplit=t_max_unsplit,
        **push,
    )


# ---------------------------------------------------------------------------
# Out-of-core streaming build: chunked COO ingestion, two passes, bounded RSS.
# ---------------------------------------------------------------------------


def coo_edge_chunks(g: COOGraph, chunk_edges: int = 1 << 18):
    """Re-iterable chunk factory over a resident COOGraph — the adapter that
    lets ``partition_2d_streaming`` consume a graph the in-memory path builds
    from, which is how the bit-identity tests compare the two. Each chunk is
    ``(src, dst)`` or ``(src, dst, weights)`` slices of ``chunk_edges`` edges
    (views, no copies). A zero-edge graph still yields one empty chunk so the
    weighted/unweighted signature survives the trip."""
    if chunk_edges <= 0:
        raise ValueError(f"chunk_edges must be positive, got {chunk_edges}")

    def factory():
        n = int(g.num_edges)
        for s in range(0, n, chunk_edges) or (0,):
            e = min(s + chunk_edges, n)
            if g.weights is not None:
                yield g.src[s:e], g.dst[s:e], g.weights[s:e]
            else:
                yield g.src[s:e], g.dst[s:e]

    return factory


def _chunk_iter(chunks):
    """Open one pass over the chunk stream. The builder reads the stream
    TWICE (count pass + placement pass), so a one-shot generator is rejected
    up front instead of silently producing an empty second pass."""
    if callable(chunks):
        return iter(chunks())
    if isinstance(chunks, (list, tuple)):
        return iter(chunks)
    raise TypeError(
        "chunks must be a callable chunk factory or a list/tuple of chunks; "
        "a bare generator cannot be replayed for the placement pass "
        "(wrap it: chunks=lambda: make_gen())"
    )


def _as_chunk(chunk):
    """Normalize one chunk to (src, dst, weights|None) int64/float32 1-D."""
    if not isinstance(chunk, (tuple, list)) or len(chunk) not in (2, 3):
        raise TypeError(
            "each chunk must be a (src, dst) or (src, dst, weights) tuple"
        )
    s = np.asarray(chunk[0]).astype(np.int64, copy=False)
    d = np.asarray(chunk[1]).astype(np.int64, copy=False)
    if s.ndim != 1 or s.shape != d.shape:
        raise ValueError(
            f"chunk src/dst must be equal-length 1-D: {s.shape} vs {d.shape}"
        )
    w = None
    if len(chunk) == 3:
        w = np.asarray(chunk[2], dtype=np.float32)
        if w.shape != s.shape:
            raise ValueError(
                f"chunk weights shape {w.shape} != edge shape {s.shape}"
            )
    return s, d, w


def partition_2d_streaming(
    chunks,
    num_vertices: int,
    cfg: PartitionConfig,
    *,
    memmap_dir: Optional[str] = None,
) -> PartitionedGraph:
    """Out-of-core ``partition_2d``: same output, bounded host memory.

    ``chunks`` is a callable returning an iterator of ``(src, dst[, weights])``
    edge chunks (or a re-iterable list/tuple of such chunks); the stream must
    replay DETERMINISTICALLY because the builder reads it twice:

      pass 1 (count): per-(core, phase) bucket sizes, per-row edge counts and
        per-source counts are accumulated chunk by chunk — O(p·l·Vl) state,
        independent of E. From the counts alone, ``plan_tiles`` /
        ``plan_push_tiles`` fix every layout decision (src_bits regime,
        per-bucket 'auto' split thresholds, hub-row chunking, LPT placement,
        stacked R/T/B/Tp, row-map mode) and the full output buffers are
        preallocated — optionally ``np.memmap``-backed under ``memmap_dir``.

      pass 2 (place): each chunk is binned straight into the preallocated
        flat bucket arrays at per-bucket cursors; buckets are then finalized
        one at a time (stable lidx sort, tile binning, word packing,
        coverage), so peak transient RAM is O(chunk + largest bucket), never
        O(E).

    Output is bit-identical to ``partition_2d`` on the same edge list: the
    global stable sort by (bucket, lidx) the in-memory path does decomposes
    into chunk-order bucket insertion (stream order within a bucket ==
    global input order) followed by a per-bucket stable sort on lidx, and
    every shape/placement decision comes from the same count-only planners
    (see docs/tile_layout.md §11 for the full invariants).

    ``memmap_dir``: when given, the large outputs (flat bucket arrays and
    packed word/weight/coverage streams) are ``np.memmap`` files under that
    directory (mode='w+'); small metadata (counts, row maps) stays in RAM.
    The returned arrays remain valid only while the files exist — the caller
    owns the directory's lifetime. Memmapped partitions feed the engines and
    ``apply_edge_deltas`` unchanged (a delta flush returns plain in-RAM
    arrays; the files are then garbage)."""
    p, l, sub_size, vpc = _resolve_dims(num_vertices, cfg)
    gathered = p * sub_size
    perm = inv = None
    if cfg.stride is not None and cfg.stride > 1:
        perm = stride_permutation(num_vertices, cfg.stride)
        inv = np.argsort(perm)

    # ---- pass 1: count. O(p*l*vpc + p*gathered) accumulators, no edge kept.
    sizes = np.zeros((p, l), dtype=np.int64)
    row_counts = np.zeros((p, l, vpc), dtype=np.int64)
    src_counts = (
        np.zeros((p, l, gathered), dtype=np.int64)
        if cfg.build_tiles and cfg.build_push
        else None
    )
    total = 0
    weighted = None
    for chunk in _chunk_iter(chunks):
        s, d, w = _as_chunk(chunk)
        if weighted is None:
            weighted = w is not None
        elif weighted != (w is not None):
            raise ValueError("all chunks must agree on carrying weights")
        if s.size == 0:
            continue
        lo = min(int(s.min()), int(d.min()))
        hi = max(int(s.max()), int(d.max()))
        if lo < 0 or hi >= num_vertices:
            raise ValueError(
                f"edge endpoints out of range [0, {num_vertices}): "
                f"chunk range [{lo}, {hi}]"
            )
        if perm is not None:
            s, d = perm[s], perm[d]
        b = (d // vpc) * l + (s % vpc) // sub_size
        sizes += np.bincount(b, minlength=p * l).reshape(p, l)
        row_counts += np.bincount(
            b * vpc + d % vpc, minlength=p * l * vpc
        ).reshape(p, l, vpc)
        if src_counts is not None:
            gx = (s // vpc) * sub_size + (s % sub_size)
            src_counts += np.bincount(
                b * gathered + gx, minlength=p * l * gathered
            ).reshape(p, l, gathered)
        total += int(s.size)
    weighted = bool(weighted)

    # ---- plan: every shape decision from counts alone (plan_tiles mirrors
    # prepare_tiles bit for bit — same thresholds, chunking, LPT placement).
    e_pad = max(_round_up(int(sizes.max()), cfg.edge_pad), cfg.edge_pad)

    if memmap_dir is not None:
        os.makedirs(memmap_dir, exist_ok=True)

    def _alloc(name, shape, dtype):
        if memmap_dir is None:
            return np.zeros(shape, dtype=dtype)
        path = os.path.join(memmap_dir, f"{name}.bin")
        return np.memmap(path, dtype=dtype, mode="w+", shape=shape)

    src_gidx = _alloc("src_gidx", (p, l, e_pad), np.int32)
    dst_lidx = _alloc("dst_lidx", (p, l, e_pad), np.int32)
    valid = _alloc("valid", (p, l, e_pad), bool)
    weights = _alloc("weights", (p, l, e_pad), np.float32) if weighted else None

    tiles: dict = {}
    plans = {}
    if cfg.build_tiles:
        from repro.kernels.csr_gather_reduce.ops import (
            choose_src_bits,
            plan_push_tiles,
            plan_tiles,
        )

        vb = cfg.tile_vb if cfg.tile_vb is not None else sub_size
        assert vpc % vb == 0, (vpc, vb)
        eb = cfg.tile_eb
        src_bits = (
            cfg.pack_src_bits
            if cfg.pack_src_bits is not None
            else choose_src_bits(gathered, vb)
        )
        for i in range(p):
            for m in range(l):
                plans[(i, m)] = plan_tiles(
                    row_counts[i, m], num_rows=vpc, vb=vb, eb=eb,
                    balance_rows=cfg.degree_aware_tiles,
                    split_threshold=_bucket_split_threshold(
                        cfg, int(sizes[i, m]), vpc // vb
                    ),
                )
        r_max = max(pl.r_blocks for pl in plans.values())
        t_max = max(pl.t_tiles for pl in plans.values())
        wc = -(-(p * (-(-sub_size // 32))) // 32)
        tile_word = _alloc("tile_word", (p, l, r_max, t_max, eb), np.int32)
        tile_word_hi = (
            _alloc("tile_word_hi", (p, l, r_max, t_max, eb), np.int32)
            if src_bits == 32
            else None
        )
        tile_counts = np.zeros((p, l, r_max), np.int32)
        tile_weights = (
            _alloc("tile_weights", (p, l, r_max, t_max, eb), np.float32)
            if weighted
            else None
        )
        tile_coverage = _alloc(
            "tile_coverage", (p, l, r_max, t_max, wc), np.uint32
        )
        # row-map mode is a GLOBAL property, decidable from the plans before
        # a single edge is placed (cold-path rule: any split bucket => every
        # bucket runs in row_orig/split-map mode).
        any_split = any(pl.row_orig is not None for pl in plans.values())
        tile_row_pos = tile_row_orig = tile_split_map = None
        if any_split:
            tile_row_orig = np.full((p, l, r_max * vb), -1, dtype=np.int32)
            s_max = max(pl.s_max for pl in plans.values())
            tile_split_map = np.full((p, l, vpc, s_max), -1, dtype=np.int32)
        else:
            any_packed = any(pl.row_pos is not None for pl in plans.values())
            if any_packed:
                tile_row_pos = np.tile(
                    np.arange(vpc, dtype=np.int32), (p, l, 1)
                )
        push_shapes = None
        if cfg.build_push:
            push_src_bits = (
                cfg.pack_src_bits
                if cfg.pack_src_bits is not None
                else choose_src_bits(gathered, vpc)
            )
            peb = cfg.push_eb if cfg.push_eb is not None else eb
            push_block = cfg.push_block
            if push_block is None:
                avg_deg = total / max(p * l, 1) / max(gathered, 1)
                want = 2.0 * peb / max(avg_deg, 1e-9)
                push_block = 32 * max(1, int(round(want / 32.0)))
                push_block = min(push_block, 32 * ((gathered + 31) // 32))
            push_shapes = [
                plan_push_tiles(
                    src_counts[i, m], gathered_size=gathered,
                    block_sources=push_block, eb=peb,
                )
                for i in range(p)
                for m in range(l)
            ]
            b_blocks = push_shapes[0][0]
            tp_max = max(t for _, t in push_shapes)
            push_word = _alloc(
                "push_word", (p, l, b_blocks, tp_max, peb), np.int32
            )
            push_word_hi = (
                _alloc("push_word_hi", (p, l, b_blocks, tp_max, peb), np.int32)
                if push_src_bits == 32
                else None
            )
            push_counts = np.zeros((p, l, b_blocks), np.int32)
            push_weights = (
                _alloc(
                    "push_weights", (p, l, b_blocks, tp_max, peb), np.float32
                )
                if weighted
                else None
            )
            push_coverage = _alloc(
                "push_coverage", (p, l, b_blocks, tp_max, wc), np.uint32
            )

    # ---- pass 2: place. Chunks are binned straight into the flat bucket
    # arrays at per-bucket cursors; within a bucket the arrival order is the
    # global input order (per-chunk bucket grouping is a stable sort).
    cursors = np.zeros(p * l, dtype=np.int64)
    seen = 0
    for chunk in _chunk_iter(chunks):
        s, d, w = _as_chunk(chunk)
        if s.size == 0:
            continue
        if perm is not None:
            s, d = perm[s], perm[d]
        b = (d // vpc) * l + (s % vpc) // sub_size
        gx = (s // vpc) * sub_size + (s % sub_size)
        lx = d % vpc
        order = np.argsort(b, kind="stable")
        b_s, g_s, l_s = b[order], gx[order], lx[order]
        w_s = w[order] if w is not None else None
        uniq, starts = np.unique(b_s, return_index=True)
        ends = np.append(starts[1:], b_s.size)
        for bk, ss, ee in zip(uniq, starts, ends):
            i, m = divmod(int(bk), l)
            n = int(ee - ss)
            c = int(cursors[bk])
            src_gidx[i, m, c : c + n] = g_s[ss:ee]
            dst_lidx[i, m, c : c + n] = l_s[ss:ee]
            if weights is not None:
                weights[i, m, c : c + n] = w_s[ss:ee]
            cursors[bk] += n
        seen += int(s.size)
    if seen != total or not np.array_equal(cursors.reshape(p, l), sizes):
        raise ValueError(
            "chunk stream did not replay identically between the count and "
            f"placement passes (counted {total} edges, placed {seen}); the "
            "chunk factory must be deterministic"
        )

    # ---- finalize one bucket at a time: stable lidx sort (reproducing the
    # in-memory path's global (bucket, lidx) stable sort), then tile binning
    # and word packing into the preallocated stacked buffers. Transient RAM
    # here is O(largest bucket).
    if cfg.build_tiles:
        from repro.kernels.csr_gather_reduce.ops import (
            pack_edge_words,
            prepare_push_tiles,
            prepare_tiles,
            split_map_from_row_orig,
            tile_coverage_words,
        )
    split_rows = 0
    for i in range(p):
        for m in range(l):
            n = int(sizes[i, m])
            ga = np.asarray(src_gidx[i, m, :n])
            la = np.asarray(dst_lidx[i, m, :n])
            oo = np.argsort(la, kind="stable")
            src_gidx[i, m, :n] = ga[oo]
            dst_lidx[i, m, :n] = la[oo]
            dst_lidx[i, m, n:] = vpc - 1  # padding keeps dst sorted
            valid[i, m, :n] = True
            if weights is not None:
                weights[i, m, :n] = np.asarray(weights[i, m, :n])[oo]
            if not cfg.build_tiles:
                continue
            plan = plans[(i, m)]
            t = prepare_tiles(
                src_gidx[i, m], dst_lidx[i, m], valid[i, m],
                num_rows=vpc, vb=vb, eb=eb,
                weights=weights[i, m] if weights is not None else None,
                balance_rows=cfg.degree_aware_tiles,
                split_threshold=_bucket_split_threshold(
                    cfg, n, vpc // vb
                ),
                plan=plan,
            )
            rr, tt = t.src.shape[:2]
            assert (rr, tt) == (plan.r_blocks, plan.t_tiles), (
                (rr, tt), (plan.r_blocks, plan.t_tiles)
            )
            w0, w1 = pack_edge_words(t.src, t.dstb, t.valid, src_bits=src_bits)
            tile_word[i, m, :rr, :tt] = w0
            if tile_word_hi is not None:
                tile_word_hi[i, m, :rr, :tt] = w1
            tile_counts[i, m, :rr] = t.tile_counts
            if tile_weights is not None and t.weights is not None:
                tile_weights[i, m, :rr, :tt] = t.weights
            tile_coverage[i, m] = tile_coverage_words(
                np.asarray(tile_word[i, m]),
                np.asarray(tile_word_hi[i, m])
                if tile_word_hi is not None
                else None,
                src_bits=src_bits, p=p, sub_size=sub_size,
            )
            if any_split:
                if t.row_orig is not None:
                    ro = t.row_orig
                elif t.row_pos is not None:
                    ro = np.full(vpc, -1, dtype=np.int32)
                    ro[t.row_pos] = np.arange(vpc, dtype=np.int32)
                else:
                    ro = np.arange(vpc, dtype=np.int32)
                tile_row_orig[i, m, : ro.shape[0]] = ro
                sm = split_map_from_row_orig(tile_row_orig[i, m], vpc)
                tile_split_map[i, m, :, : sm.shape[1]] = sm
                split_rows += t.num_split_rows
            elif tile_row_pos is not None and t.row_pos is not None:
                tile_row_pos[i, m] = t.row_pos
            if cfg.build_push:
                pt = prepare_push_tiles(
                    src_gidx[i, m], dst_lidx[i, m], valid[i, m],
                    gathered_size=gathered, block_sources=push_block,
                    num_rows=vpc, eb=peb,
                    weights=weights[i, m] if weights is not None else None,
                )
                bb, pt_t = pt.src.shape[:2]
                assert bb == b_blocks, (bb, b_blocks)
                pw0, pw1 = pack_edge_words(
                    pt.src, pt.dst, pt.valid, src_bits=push_src_bits
                )
                push_word[i, m, :, :pt_t] = pw0
                if push_word_hi is not None:
                    push_word_hi[i, m, :, :pt_t] = pw1
                push_counts[i, m] = pt.tile_counts
                if push_weights is not None and pt.weights is not None:
                    push_weights[i, m, :, :pt_t] = pt.weights
                push_coverage[i, m] = tile_coverage_words(
                    np.asarray(push_word[i, m]),
                    np.asarray(push_word_hi[i, m])
                    if push_word_hi is not None
                    else None,
                    src_bits=push_src_bits, p=p, sub_size=sub_size,
                )

    if cfg.build_tiles:
        tiles = dict(
            tile_word=tile_word,
            tile_word_hi=tile_word_hi,
            tile_counts=tile_counts,
            tile_weights=tile_weights,
            tile_row_pos=tile_row_pos,
            tile_coverage=tile_coverage,
            tile_vb=vb,
            src_bits=src_bits,
            tile_row_orig=tile_row_orig,
            tile_split_map=tile_split_map,
            split_rows=split_rows,
            t_max_unsplit=max(pl.t_tiles_unsplit for pl in plans.values()),
        )
        if cfg.build_push:
            tiles.update(
                push_word=push_word,
                push_word_hi=push_word_hi,
                push_counts=push_counts,
                push_weights=push_weights,
                push_coverage=push_coverage,
                push_src_bits=push_src_bits,
                push_block=push_block,
            )

    return PartitionedGraph(
        p=p,
        l=l,
        sub_size=sub_size,
        num_vertices=num_vertices,
        num_edges=total,
        src_gidx=src_gidx,
        dst_lidx=dst_lidx,
        valid=valid,
        weights=weights,
        perm=perm,
        inv_perm=inv,
        bucket_sizes=sizes,
        config=cfg,
        **tiles,
    )


# ---------------------------------------------------------------------------
# Delta ingestion: streaming edge insertions re-tile ONLY dirty buckets.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DeltaFlushReport:
    """What one incremental flush actually rebuilt — the O(B) contract.

    ``tile_bytes_repacked`` counts only the packed stream bytes that were
    regenerated from scratch (dirty buckets' edge words + coverage words +
    push words); ``tile_bytes_total`` is the whole partition's packed stream.
    A flush touching B of the p*l buckets must keep the repacked fraction
    ~B / (p*l) — asserted in tests/test_delta_ingest.py."""

    dirty: tuple  # ((core, phase), ...) buckets that received edges, sorted
    buckets_retiled: int
    total_buckets: int
    edges_added: int
    tile_bytes_repacked: int
    tile_bytes_total: int
    grew_edge_pad: bool  # per-bucket flat arrays grew past the old E_pad
    grew_tiles: bool  # stacked R/T/Tp grew (clean slices padded, not rebuilt)
    mode_changed: bool  # row-map mode flipped (row_pos -> split map)

    @property
    def repacked_fraction(self) -> float:
        return self.tile_bytes_repacked / max(self.tile_bytes_total, 1)


def bucket_coords(
    pg: PartitionedGraph, src: np.ndarray, dst: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Bin delta edges exactly the way ``partition_2d`` bins the full edge
    list: apply the stride permutation, then compute (core, phase, gidx,
    lidx) per edge. Endpoints must be existing vertex ids — vertex-set
    growth changes sub_size and with it every bucket, so it is a full
    repartition, not a delta."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if src.size:
        lo = min(int(src.min()), int(dst.min()))
        hi = max(int(src.max()), int(dst.max()))
        if lo < 0 or hi >= pg.num_vertices:
            raise ValueError(
                f"delta edge endpoints must be existing vertex ids in "
                f"[0, {pg.num_vertices}); got range [{lo}, {hi}]"
            )
    if pg.perm is not None:
        src = pg.perm[src]
        dst = pg.perm[dst]
    vpc, sub = pg.vertices_per_core, pg.sub_size
    core = dst // vpc
    phase = (src % vpc) // sub
    gidx = (src // vpc) * sub + (src % sub)
    lidx = dst % vpc
    return core, phase, gidx, lidx


def _tile_bytes_total(pg: PartitionedGraph) -> int:
    """Packed-stream bytes of a partition (edge words + weights + coverage,
    pull and push) — the denominator of the O(B) repack-fraction metric."""
    total = 0
    for a in (
        pg.tile_word, pg.tile_word_hi, pg.tile_weights, pg.tile_coverage,
        pg.push_word, pg.push_word_hi, pg.push_weights, pg.push_coverage,
    ):
        if a is not None:
            total += a.nbytes
    return total


def apply_edge_deltas(
    pg: PartitionedGraph,
    src: np.ndarray,
    dst: np.ndarray,
    weights: Optional[np.ndarray] = None,
) -> tuple[PartitionedGraph, DeltaFlushReport]:
    """Flush streamed edge insertions into a resident partition by re-tiling
    ONLY the dirty (core, phase) buckets.

    The incremental path reproduces ``partition_2d`` output bit-for-bit (see
    docs/serving.md §3 / docs/tile_layout.md §10): a bucket's flat slice is
    its old dst-sorted edges plus the delta edges in insertion order, stably
    re-sorted by local dst — exactly the tie order a cold repartition of
    (original edges ++ inserted edges) produces. Dirty buckets then re-run
    ``prepare_tiles`` / ``pack_edge_words`` / ``tile_coverage_words`` /
    ``prepare_push_tiles`` under the SAME config rules (per-bucket 'auto'
    split threshold recomputed with the new bucket size); clean buckets keep
    their packed arrays untouched — if the stacked R/T/Tp must grow, clean
    slices are only zero-padded (counts stay authoritative; padded tiles are
    dead under the kernel's early-out and carry all-zero coverage).

    Returns ``(new_pg, report)``. A NEW PartitionedGraph object is always
    returned — the engine's jit cache is keyed by object identity with edge
    constants baked into traces, so mutating the resident arrays in place
    would silently serve stale edges. The caller should drop the retired
    object from the cache (``engine.evict_from_cache``)."""
    cfg = pg.config
    if cfg is None:
        raise ValueError(
            "partition carries no PartitionConfig (hand-built?); "
            "delta ingest needs partition_2d provenance to re-tile"
        )
    if (pg.weights is not None) != (weights is not None):
        raise ValueError(
            "delta weights must match the partition: "
            f"partition weighted={pg.weights is not None}, "
            f"delta weighted={weights is not None}"
        )
    src = np.atleast_1d(np.asarray(src))
    dst = np.atleast_1d(np.asarray(dst))
    if src.shape != dst.shape or src.ndim != 1:
        raise ValueError(f"src/dst must be equal-length 1-D: {src.shape} vs {dst.shape}")
    p, l, vpc, sub = pg.p, pg.l, pg.vertices_per_core, pg.sub_size
    n_add = int(src.shape[0])
    if n_add == 0:
        return pg, DeltaFlushReport(
            dirty=(), buckets_retiled=0, total_buckets=p * l, edges_added=0,
            tile_bytes_repacked=0, tile_bytes_total=_tile_bytes_total(pg),
            grew_edge_pad=False, grew_tiles=False, mode_changed=False,
        )
    core, phase, gidx, lidx = bucket_coords(pg, src, dst)
    w = np.asarray(weights, dtype=np.float32) if weights is not None else None

    # group delta edges by bucket, preserving insertion order within a bucket
    # (the stable tie order a cold repartition of the appended edge list sees)
    b_id = core * l + phase
    order = np.argsort(b_id, kind="stable")
    b_s, g_s, l_s = b_id[order], gidx[order], lidx[order]
    w_s = w[order] if w is not None else None
    add = np.bincount(b_s, minlength=p * l).reshape(p, l)
    dirty = sorted((int(b) // l, int(b) % l) for b in np.unique(b_s))
    new_sizes = pg.bucket_sizes + add

    # -- flat (p, l, E_pad) bucket arrays: grow E_pad by the same rounding
    # rule partition_2d uses, then merge each dirty bucket's slice
    e_pad_old = pg.edge_pad
    e_pad = max(_round_up(int(new_sizes.max()), cfg.edge_pad), cfg.edge_pad)
    grew_epad = e_pad > e_pad_old

    def _grow_flat(a, fill):
        out = np.full((p, l, e_pad), fill, dtype=a.dtype)
        out[:, :, :e_pad_old] = a
        return out

    src_gidx = _grow_flat(pg.src_gidx, 0)
    dst_lidx = _grow_flat(pg.dst_lidx, vpc - 1)  # padding keeps dst sorted
    valid = _grow_flat(pg.valid, False)
    wts_flat = _grow_flat(pg.weights, 0.0) if pg.weights is not None else None

    starts = np.zeros(p * l + 1, dtype=np.int64)
    np.cumsum(add.ravel(), out=starts[1:])
    for (i, m) in dirty:
        b = i * l + m
        s, e = int(starts[b]), int(starts[b + 1])
        n_old, n = int(pg.bucket_sizes[i, m]), int(new_sizes[i, m])
        ga = np.concatenate([src_gidx[i, m, :n_old], g_s[s:e].astype(np.int32)])
        la = np.concatenate([dst_lidx[i, m, :n_old], l_s[s:e].astype(np.int32)])
        oo = np.argsort(la, kind="stable")  # old edges first on lidx ties
        src_gidx[i, m, :n] = ga[oo]
        dst_lidx[i, m, :n] = la[oo]
        valid[i, m, :n] = True
        if wts_flat is not None:
            wa = np.concatenate([wts_flat[i, m, :n_old], w_s[s:e]])
            wts_flat[i, m, :n] = wa[oo]

    updates = dict(
        num_edges=pg.num_edges + n_add,
        src_gidx=src_gidx,
        dst_lidx=dst_lidx,
        valid=valid,
        weights=wts_flat,
        bucket_sizes=new_sizes,
    )
    rep_bytes = 0
    grew_tiles = False
    mode_changed = False

    if pg.tile_word is not None:
        from repro.kernels.csr_gather_reduce.ops import (
            _lpt_max_load,
            pack_edge_words,
            prepare_push_tiles,
            prepare_tiles,
            split_map_from_row_orig,
            tile_coverage_words,
        )

        # -- pull stream: re-tile dirty buckets only
        vb = pg.tile_vb
        eb = int(pg.tile_word.shape[4])
        r_old, t_old = int(pg.tile_word.shape[2]), int(pg.tile_word.shape[3])
        r_base = vpc // vb
        layouts = {
            (i, m): prepare_tiles(
                src_gidx[i, m], dst_lidx[i, m], valid[i, m],
                num_rows=vpc, vb=vb, eb=eb,
                weights=wts_flat[i, m] if wts_flat is not None else None,
                balance_rows=cfg.degree_aware_tiles,
                split_threshold=_bucket_split_threshold(
                    cfg, int(new_sizes[i, m]), vpc // vb
                ),
            )
            for (i, m) in dirty
        }
        # Per-bucket layout shape + split metadata. The stacked shape is the
        # GLOBAL max over buckets — it can also SHRINK: a dirty bucket that
        # dictated the old R/T/S_max re-tiles under a larger 'auto' split
        # threshold (it grows with the bucket's edge count) and may need
        # less. Clean buckets' contributions are derived without touching
        # their packed bytes: T from the valid sign bits (a real tile always
        # holds >= 1 valid edge, and tiles fill a row block in order), R and
        # S from the row maps, and the unsplit-T metric from the flat dst
        # column — metadata reads, not stream rebuilds.
        vword = pg.tile_word_hi if pg.tile_word_hi is not None else pg.tile_word
        tile_has_edge = (vword < 0).any(axis=(2, 4))  # (p, l, T)
        r_b = np.full((p, l), r_base, dtype=np.int64)
        t_b = np.ones((p, l), dtype=np.int64)
        s_b = np.ones((p, l), dtype=np.int64)  # split-map width per bucket
        split_b = np.zeros((p, l), dtype=np.int64)  # split natural rows
        tu_b = np.ones((p, l), dtype=np.int64)  # per-bucket unsplit T
        for i in range(p):
            for m in range(l):
                if (i, m) in layouts:
                    continue
                nz = np.nonzero(tile_has_edge[i, m])[0]
                if nz.size:
                    t_b[i, m] = int(nz[-1]) + 1
                if pg.tile_split_map is not None:
                    width = (pg.tile_split_map[i, m] >= 0).sum(axis=1)
                    s_b[i, m] = max(int(width.max()), 1)
                    split_b[i, m] = int((width > 1).sum())
                    pos = np.nonzero(pg.tile_row_orig[i, m] >= 0)[0]
                    if pos.size:
                        r_b[i, m] = max(r_base, int(pos[-1]) // vb + 1)
                n_old = int(pg.bucket_sizes[i, m])
                rc = np.bincount(pg.dst_lidx[i, m, :n_old], minlength=vpc)
                if cfg.degree_aware_tiles:
                    load = _lpt_max_load(rc, r_base, vb)
                else:
                    load = int(rc.reshape(r_base, vb).sum(axis=1).max())
                tu_b[i, m] = max(1, -(-int(load) // eb))
        for (i, m), t in layouts.items():
            r_b[i, m], t_b[i, m] = t.src.shape[0], t.src.shape[1]
            tu_b[i, m] = t.t_tiles_unsplit
            split_b[i, m] = t.num_split_rows
        r_new, t_new = int(r_b.max()), int(t_b.max())
        grew_tiles = (r_new, t_new) != (r_old, t_old)
        ro_n, to_n = min(r_old, r_new), min(t_old, t_new)

        def _restack(a, fill=0):
            out = np.full((p, l, r_new, t_new) + a.shape[4:], fill, dtype=a.dtype)
            out[:, :, :ro_n, :to_n] = a[:, :, :ro_n, :to_n]
            return out

        tile_word = _restack(pg.tile_word)
        tile_word_hi = (
            _restack(pg.tile_word_hi)
            if pg.tile_word_hi is not None else None
        )
        tile_counts = np.zeros((p, l, r_new), np.int32)
        tile_counts[:, :, :ro_n] = pg.tile_counts[:, :, :ro_n]
        tile_weights = (
            _restack(pg.tile_weights)
            if pg.tile_weights is not None else None
        )
        tile_coverage = (
            _restack(pg.tile_coverage)
            if pg.tile_coverage is not None else None
        )
        for (i, m), t in layouts.items():
            rr, tt = t.src.shape[0], t.src.shape[1]
            w0, w1 = pack_edge_words(t.src, t.dstb, t.valid, src_bits=pg.src_bits)
            tile_word[i, m] = 0
            tile_word[i, m, :rr, :tt] = w0
            rep_bytes += w0.nbytes
            if tile_word_hi is not None:
                tile_word_hi[i, m] = 0
                tile_word_hi[i, m, :rr, :tt] = w1
                rep_bytes += w1.nbytes
            tile_counts[i, m] = 0
            tile_counts[i, m, :rr] = t.tile_counts
            if tile_weights is not None:
                tile_weights[i, m] = 0.0
                if t.weights is not None:
                    tile_weights[i, m, :rr, :tt] = t.weights
                    rep_bytes += t.weights.nbytes
            if tile_coverage is not None:
                cov = tile_coverage_words(
                    tile_word[i, m], tile_word_hi[i, m] if tile_word_hi is not None else None,
                    src_bits=pg.src_bits, p=p, sub_size=sub,
                )
                tile_coverage[i, m] = cov
                rep_bytes += cov.nbytes

        # -- row maps: dirty buckets bring fresh maps; clean buckets keep
        # (or mechanically re-derive — metadata, not packed stream) theirs.
        # The MODE is a global property: a partition is in split mode iff ANY
        # bucket still has a split row, so it can flip in either direction —
        # pos->split when a dirty bucket crosses its threshold, split->pos
        # when the only split bucket un-splits under its grown threshold.
        any_split_old = pg.tile_split_map is not None
        any_split_new = bool((split_b > 0).any())
        mode_changed = any_split_old != any_split_new
        tile_row_pos = tile_row_orig = tile_split_map = None
        split_rows = 0
        if not any_split_new:
            # no virtual rows anywhere: R stays Vl / vb in this mode, and the
            # pos map exists iff the LPT packer ran (cold-path rule)
            if cfg.degree_aware_tiles and r_base > 1:
                tile_row_pos = np.tile(np.arange(vpc, dtype=np.int32), (p, l, 1))
                for i in range(p):
                    for m in range(l):
                        if (i, m) in layouts:
                            t = layouts[(i, m)]
                            if t.row_pos is not None:
                                tile_row_pos[i, m] = t.row_pos
                        elif pg.tile_row_pos is not None:
                            tile_row_pos[i, m] = pg.tile_row_pos[i, m]
                        elif pg.tile_row_orig is not None:
                            # split->pos flip: invert the clean bucket's
                            # packed-position map (it has no split rows, so
                            # the inverse is exactly the row_pos the cold
                            # LPT pass reproduces on unchanged row counts)
                            pos = np.nonzero(pg.tile_row_orig[i, m] >= 0)[0]
                            tile_row_pos[
                                i, m, pg.tile_row_orig[i, m, pos]
                            ] = pos.astype(np.int32)
        else:
            packed_old, packed_new = r_old * vb, r_new * vb
            po_n = min(packed_old, packed_new)
            tile_row_orig = np.full((p, l, packed_new), -1, dtype=np.int32)
            if pg.tile_row_orig is not None:
                tile_row_orig[:, :, :po_n] = pg.tile_row_orig[:, :, :po_n]
            elif pg.tile_row_pos is not None:
                for i in range(p):
                    for m in range(l):
                        tile_row_orig[i, m, pg.tile_row_pos[i, m]] = np.arange(
                            vpc, dtype=np.int32
                        )
            else:
                tile_row_orig[:, :, :vpc] = np.arange(vpc, dtype=np.int32)
            for (i, m), t in layouts.items():
                ro = np.full(packed_new, -1, dtype=np.int32)
                if t.row_orig is not None:
                    ro[: t.row_orig.shape[0]] = t.row_orig
                elif t.row_pos is not None:
                    ro[t.row_pos] = np.arange(vpc, dtype=np.int32)
                else:
                    ro[:vpc] = np.arange(vpc, dtype=np.int32)
                tile_row_orig[i, m] = ro
            # gather-form split maps: rebuild dirty buckets (and every bucket
            # on a pos->split mode flip, where no old map exists)
            maps = {}
            for i in range(p):
                for m in range(l):
                    if (i, m) in layouts or not any_split_old:
                        maps[(i, m)] = split_map_from_row_orig(
                            tile_row_orig[i, m], vpc
                        )
                        s_b[i, m] = maps[(i, m)].shape[1]
            s_max = int(s_b.max())
            tile_split_map = np.full((p, l, vpc, s_max), -1, dtype=np.int32)
            if any_split_old:
                so_n = min(pg.tile_split_map.shape[3], s_max)
                tile_split_map[:, :, :, :so_n] = pg.tile_split_map[:, :, :, :so_n]
            for (i, m), sm in maps.items():
                tile_split_map[i, m] = -1
                tile_split_map[i, m, :, : sm.shape[1]] = sm
            split_rows = int(split_b.sum())
        updates.update(
            tile_word=tile_word,
            tile_word_hi=tile_word_hi,
            tile_counts=tile_counts,
            tile_weights=tile_weights,
            tile_coverage=tile_coverage,
            tile_row_pos=tile_row_pos,
            tile_row_orig=tile_row_orig,
            tile_split_map=tile_split_map,
            split_rows=split_rows,
            t_max_unsplit=int(tu_b.max()),
        )

        # -- push (scatter) stream: same dirty buckets, same block sizing
        if pg.push_word is not None:
            peb = int(pg.push_word.shape[4])
            tp_old = int(pg.push_word.shape[3])
            push_layouts = {
                (i, m): prepare_push_tiles(
                    src_gidx[i, m], dst_lidx[i, m], valid[i, m],
                    gathered_size=pg.gathered_size,
                    block_sources=pg.push_block,
                    num_rows=vpc, eb=peb,
                    weights=wts_flat[i, m] if wts_flat is not None else None,
                )
                for (i, m) in dirty
            }
            tp_new = max([tp_old] + [t.src.shape[1] for t in push_layouts.values()])
            grew_tiles = grew_tiles or tp_new > tp_old
            b_blocks = int(pg.push_word.shape[2])

            def _pad_push(a, fill=0):
                out = np.full(
                    (p, l, b_blocks, tp_new) + a.shape[4:], fill, dtype=a.dtype
                )
                out[:, :, :, :tp_old] = a
                return out

            push_word = _pad_push(pg.push_word)
            push_word_hi = (
                _pad_push(pg.push_word_hi) if pg.push_word_hi is not None else None
            )
            push_counts = pg.push_counts.copy()
            push_weights = (
                _pad_push(pg.push_weights) if pg.push_weights is not None else None
            )
            push_coverage = (
                _pad_push(pg.push_coverage) if pg.push_coverage is not None else None
            )
            for (i, m), t in push_layouts.items():
                bb, tt = t.src.shape[0], t.src.shape[1]
                assert bb == b_blocks, (bb, b_blocks)
                w0, w1 = pack_edge_words(
                    t.src, t.dst, t.valid, src_bits=pg.push_src_bits
                )
                push_word[i, m] = 0
                push_word[i, m, :, :tt] = w0
                rep_bytes += w0.nbytes
                if push_word_hi is not None:
                    push_word_hi[i, m] = 0
                    push_word_hi[i, m, :, :tt] = w1
                    rep_bytes += w1.nbytes
                push_counts[i, m] = t.tile_counts
                if push_weights is not None:
                    push_weights[i, m] = 0.0
                    if t.weights is not None:
                        push_weights[i, m, :, :tt] = t.weights
                        rep_bytes += t.weights.nbytes
                if push_coverage is not None:
                    cov = tile_coverage_words(
                        push_word[i, m],
                        push_word_hi[i, m] if push_word_hi is not None else None,
                        src_bits=pg.push_src_bits, p=p, sub_size=sub,
                    )
                    push_coverage[i, m] = cov
                    rep_bytes += cov.nbytes
            updates.update(
                push_word=push_word,
                push_word_hi=push_word_hi,
                push_counts=push_counts,
                push_weights=push_weights,
                push_coverage=push_coverage,
            )

    new_pg = dataclasses.replace(pg, **updates)
    report = DeltaFlushReport(
        dirty=tuple(dirty),
        buckets_retiled=len(dirty),
        total_buckets=p * l,
        edges_added=n_add,
        tile_bytes_repacked=rep_bytes,
        tile_bytes_total=_tile_bytes_total(new_pg),
        grew_edge_pad=grew_epad,
        grew_tiles=grew_tiles,
        mode_changed=mode_changed,
    )
    return new_pg, report


# ---------------------------------------------------------------------------
# Edge-centric (HitGraph/ThunderGP-style) partitioning for the baseline engine:
# horizontal partitioning of the *edge list* by destination interval, no
# sub-intervals, no compression (src kept as a global vertex id).
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class EdgeCentricPartition:
    p: int
    num_vertices: int
    num_edges: int
    vertices_per_core: int
    src_vid: np.ndarray  # (p, E_pad) int32 global (padded) src vertex id
    dst_lidx: np.ndarray  # (p, E_pad) int32 local dst id
    valid: np.ndarray  # (p, E_pad) bool
    weights: Optional[np.ndarray]
    bucket_sizes: np.ndarray  # (p,)


def partition_edge_centric(
    g: COOGraph, p: int, lane: int = 8, edge_pad: int = 8
) -> EdgeCentricPartition:
    vpc = _round_up(-(-g.num_vertices // p), lane)
    src = g.src.astype(np.int64)
    dst = g.dst.astype(np.int64)
    core = dst // vpc
    order = np.argsort(core * (g.num_vertices + 1) + dst, kind="stable")
    src, dst, core = src[order], dst[order], core[order]
    w = g.weights[order] if g.weights is not None else None
    sizes = np.bincount(core, minlength=p)
    e_pad = max(_round_up(int(sizes.max()), edge_pad), edge_pad)
    src_vid = np.zeros((p, e_pad), dtype=np.int32)
    dst_lidx = np.full((p, e_pad), vpc - 1, dtype=np.int32)  # keep sorted under padding
    valid = np.zeros((p, e_pad), dtype=bool)
    weights = np.zeros((p, e_pad), dtype=np.float32) if w is not None else None
    starts = np.zeros(p + 1, dtype=np.int64)
    np.cumsum(sizes, out=starts[1:])
    for i in range(p):
        s, e = starts[i], starts[i + 1]
        n = int(e - s)
        src_vid[i, :n] = src[s:e]
        dst_lidx[i, :n] = dst[s:e] - i * vpc
        valid[i, :n] = True
        if weights is not None:
            weights[i, :n] = w[s:e]
    return EdgeCentricPartition(
        p=p,
        num_vertices=g.num_vertices,
        num_edges=g.num_edges,
        vertices_per_core=vpc,
        src_vid=src_vid,
        dst_lidx=dst_lidx,
        valid=valid,
        weights=weights,
        bucket_sizes=sizes,
    )
