"""GraphScale core: compressed asynchronous multi-core graph processing."""
from repro.core import edge_centric, engine, graph, partition, problems, reference  # noqa: F401
