"""Graph problems as map/reduce user-defined functions (paper §III, §IV).

A ``Problem`` mirrors the paper's UDF plug-in surface for the graph-core
accumulator:

  * ``src_transform`` — the per-source part of the *map* UDF, evaluated on the
    label shard **before** the crossbar exchange (cheap elementwise work; the
    exchanged payload stays one word per vertex exactly like the paper's
    32-bit labels — PR exchanges rank/deg pre-divided, matching the paper's
    packed (degree, rank) 64-bit label semantics with half the traffic).
  * ``edge_map`` — the per-edge part of the map UDF (adds the edge weight for
    SSSP; identity otherwise).
  * ``reduce_kind`` — 'min' or 'sum': the reduce UDF of the accumulator
    (BFS/WCC/SSSP = min, PR = sum). Exactly the paper's switchable reduce PE.
  * ``apply`` semantics are implied by ``reduce_kind``: min-problems merge into
    the old label (idempotent → may be applied per phase = asynchronous);
    sum-problems replace via ``finalize`` at iteration end (the paper's PR
    double buffering over two vertex label arrays).

Labels are dicts of (…, Vl) arrays so problems may carry auxiliary per-vertex
state (e.g. PR's inverse out-degree) without the engine knowing.

Multi-query lane batching (docs/tile_layout.md §8): a problem with
``lanes = K > 0`` answers K point queries in one engine run by giving the
exchanged payload a trailing lane axis. Two layouts:

  * **packed** (``bfs_multi``) — the payload is a bitmap of "reached by query
    k", 32 lanes per uint32 word, and the reduce is bitwise OR
    (``reduce_kind='or'``). A K=64 batch widens the payload by just 2 words
    per vertex; the compressed 4 B/edge index stream is untouched.
  * **vector** (``sssp_multi``/``ppr_multi``) — the payload is a (…, K) label
    block; min/sum reduces vectorize over the lane axis.

``not_converged_lanes`` exposes the per-lane live mask; a converged lane's
labels stop changing, so it drops out of the (union) frontier words and the
dynamic tile schedule automatically — no per-lane control flow needed.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.graph import COOGraph, out_degrees

__all__ = [
    "Problem",
    "bfs",
    "wcc",
    "sssp",
    "pagerank",
    "bfs_multi",
    "sssp_multi",
    "ppr_multi",
    "lane_bits",
    "INF_U32",
]

INF_U32 = np.uint32(0xFFFFFFFF)

LabelTree = Dict[str, jnp.ndarray]


def lane_bits(words: jnp.ndarray, k: int) -> jnp.ndarray:
    """Unpack the trailing packed-word axis (…, W) uint32 into (…, k) bools
    (little-endian bit order, matching ``frontier_words.pack_bits``)."""
    lane = jnp.arange(k, dtype=jnp.uint32)
    w = jnp.take(words, lane // jnp.uint32(32), axis=-1)
    return (w >> (lane % jnp.uint32(32))) & jnp.uint32(1) != 0


@dataclasses.dataclass(frozen=True)
class Problem:
    name: str
    reduce_kind: str  # 'min' | 'sum' | 'or'
    # host-side: build initial (padded) label tree given padded size & graph
    init_labels: Callable[[COOGraph, int], Dict[str, np.ndarray]]
    # device-side map UDF, source half: label sub-tree -> exchanged payload
    src_transform: Callable[[LabelTree], jnp.ndarray]
    # device-side map UDF, edge half: (payload_at_src, edge_weight|None) -> contribution
    edge_map: Callable[[jnp.ndarray, Optional[jnp.ndarray]], jnp.ndarray]
    # declarative form of ``edge_map`` for the fused Pallas path: 'none' means
    # the contribution IS the payload (BFS/WCC/PR, whose per-source work lives
    # in ``src_transform``); 'add' is the saturating min-plus weight add (SSSP).
    # ``edge_map`` stays the oracle the XLA path executes.
    edge_op: str = "none"
    # identity element of the reduce UDF
    identity: float = 0.0
    # iteration finalize for sum problems: (labels, accumulated) -> new labels
    finalize: Optional[Callable[[LabelTree, jnp.ndarray], LabelTree]] = None
    # convergence: (old, new) -> bool scalar (True = keep iterating)
    not_converged: Optional[Callable[[LabelTree, LabelTree], jnp.ndarray]] = None
    # which label field is merged by min-problems
    merge_field: str = "label"
    tol: float = 1e-6
    # multi-query lane batching: number of concurrent queries (0 = laneless
    # single query). When > 0 the ``merge_field`` array carries a trailing
    # lane axis — K for 'vector' layout, ceil(K/32) packed words for 'packed'.
    lanes: int = 0
    lane_layout: str = ""  # '' | 'packed' | 'vector'
    # per-lane convergence: (old, new) -> (K,) bool mask (True = lane live).
    # Observability only — finished lanes already stop contributing because
    # their labels freeze and drop out of the union frontier words.
    not_converged_lanes: Optional[
        Callable[[LabelTree, LabelTree], jnp.ndarray]
    ] = None

    def payload_dtype(self, labels: Dict[str, np.ndarray]):
        return labels[self.merge_field].dtype


# ---------------------------------------------------------------------------
# BFS — label = hop distance from root; map = src+1; reduce = min.
# ---------------------------------------------------------------------------


def bfs(root: int) -> Problem:
    def init(g: COOGraph, padded: int):
        lab = np.full(padded, INF_U32, dtype=np.uint32)
        lab[root] = 0
        return {"label": lab}

    def src_transform(labels: LabelTree) -> jnp.ndarray:
        lab = labels["label"]
        # saturating +1 so INF stays INF
        return jnp.where(lab == INF_U32, lab, lab + jnp.uint32(1))

    def edge_map(z, w):
        return z

    def not_conv(old: LabelTree, new: LabelTree):
        return jnp.any(old["label"] != new["label"])

    return Problem(
        name="bfs",
        reduce_kind="min",
        init_labels=init,
        src_transform=src_transform,
        edge_map=edge_map,
        identity=float(INF_U32),
        not_converged=not_conv,
    )


# ---------------------------------------------------------------------------
# WCC — label = min vertex id in the weakly connected component. Requires the
# symmetrized edge set (undirected closure), as in the paper.
# ---------------------------------------------------------------------------


def wcc() -> Problem:
    def init(g: COOGraph, padded: int):
        lab = np.arange(padded, dtype=np.uint32)
        return {"label": lab}

    def not_conv(old: LabelTree, new: LabelTree):
        return jnp.any(old["label"] != new["label"])

    return Problem(
        name="wcc",
        reduce_kind="min",
        init_labels=init,
        src_transform=lambda labels: labels["label"],
        edge_map=lambda z, w: z,
        identity=float(INF_U32),
        not_converged=not_conv,
    )


# ---------------------------------------------------------------------------
# SSSP — min-plus with float32 edge weights (HitGraph's BFS comparison uses
# unit weights; we support general non-negative weights).
# ---------------------------------------------------------------------------

INF_F32 = np.float32(np.finfo(np.float32).max)


def sssp(root: int) -> Problem:
    def init(g: COOGraph, padded: int):
        lab = np.full(padded, INF_F32, dtype=np.float32)
        lab[root] = 0.0
        return {"label": lab}

    def edge_map(z, w):
        return jnp.where(z >= INF_F32, z, z + (w if w is not None else 1.0))

    def not_conv(old: LabelTree, new: LabelTree):
        return jnp.any(old["label"] != new["label"])

    return Problem(
        name="sssp",
        reduce_kind="min",
        init_labels=init,
        src_transform=lambda labels: labels["label"],
        edge_map=edge_map,
        edge_op="add",
        identity=float(INF_F32),
        not_converged=not_conv,
    )


# ---------------------------------------------------------------------------
# PageRank — pull-based power iteration:
#   p(i) <- (1-d)/|V| + d * sum_{j in N_in(i)} p(j) / outdeg(j)
# Exchanged payload is rank * inv_outdeg (the per-source map half), reduce=sum,
# finalize applies damping. Convergence on max |delta| < tol, or max_iters.
# ---------------------------------------------------------------------------


def pagerank(damping: float = 0.85, tol: float = 1e-6) -> Problem:
    def init(g: COOGraph, padded: int):
        deg = out_degrees(g).astype(np.float32)
        inv = np.zeros(padded, dtype=np.float32)
        nz = deg > 0
        inv[: g.num_vertices][nz] = 1.0 / deg[nz]
        rank = np.zeros(padded, dtype=np.float32)
        rank[: g.num_vertices] = 1.0 / g.num_vertices
        mask = np.zeros(padded, dtype=np.float32)
        mask[: g.num_vertices] = 1.0
        return {"label": rank, "inv_deg": inv, "mask": mask, "n": np.float32(g.num_vertices)}

    def src_transform(labels: LabelTree) -> jnp.ndarray:
        return labels["label"] * labels["inv_deg"]

    def finalize(labels: LabelTree, acc: jnp.ndarray) -> LabelTree:
        n = labels["n"]
        new_rank = ((1.0 - damping) / n + damping * acc) * labels["mask"]
        out = dict(labels)
        out["label"] = new_rank
        return out

    def not_conv(old: LabelTree, new: LabelTree):
        return jnp.max(jnp.abs(old["label"] - new["label"])) > tol

    return Problem(
        name="pagerank",
        reduce_kind="sum",
        init_labels=init,
        src_transform=src_transform,
        edge_map=lambda z, w: z,
        identity=0.0,
        finalize=finalize,
        not_converged=not_conv,
        tol=tol,
    )


# ---------------------------------------------------------------------------
# Multi-query lane-batched constructors (docs/tile_layout.md §8).
# ---------------------------------------------------------------------------


def bfs_multi(roots: Sequence[int]) -> Problem:
    """K-source BFS with bit-packed lanes: payload word w of vertex v has bit
    (k % 32) set iff query ``roots[k]`` has reached v (the classic multi-
    source BFS bitmap trick). The reduce is bitwise OR over the compressed
    edge stream; hop distances are recovered level-synchronously in
    ``finalize`` from the newly-set bits, so the final ``dist[:, k]`` is
    bit-identical to a single-query ``bfs(roots[k])`` run.

    'or' problems always execute on the level-synchronized (accumulate +
    finalize) schedule regardless of ``EngineOptions.immediate_updates`` —
    async multi-hop propagation within one iteration would record wrong
    levels. OR is monotone like min, so the frontier-word dynamic tile skip
    stays sound (the active map is the union of the live per-lane frontiers).
    """
    roots = np.asarray(roots, dtype=np.int64)
    k = int(roots.shape[0])
    if not 1 <= k <= 1024:
        raise ValueError(f"bfs_multi supports 1..1024 lanes, got {k}")
    w = (k + 31) // 32

    def init(g: COOGraph, padded: int):
        if (roots < 0).any() or (roots >= g.num_vertices).any():
            raise ValueError("bfs_multi root out of range")
        reach = np.zeros((padded, w), dtype=np.uint32)
        lane = np.arange(k)
        bits = (np.uint32(1) << (lane % 32).astype(np.uint32)).astype(np.uint32)
        # unbuffered |= : duplicate roots land in the same word and a plain
        # fancy-index |= would keep only one lane's bit
        np.bitwise_or.at(reach, (roots, lane // 32), bits)
        dist = np.full((padded, k), INF_U32, dtype=np.uint32)
        dist[roots, lane] = 0
        return {"reach": reach, "dist": dist, "level": np.uint32(0)}

    def finalize(labels: LabelTree, acc: jnp.ndarray) -> LabelTree:
        reach = labels["reach"]
        newly = acc & ~reach
        level = labels["level"] + jnp.uint32(1)
        hit = lane_bits(newly, k)
        dist = jnp.where(hit, level, labels["dist"])
        return {"reach": reach | newly, "dist": dist, "level": level}

    def not_conv(old: LabelTree, new: LabelTree):
        return jnp.any(old["reach"] != new["reach"])

    def lanes_live(old: LabelTree, new: LabelTree):
        diff = lane_bits(old["reach"] ^ new["reach"], k)
        return jnp.any(diff.reshape(-1, k), axis=0)

    return Problem(
        name=f"bfs_multi[{k}]",
        reduce_kind="or",
        init_labels=init,
        src_transform=lambda labels: labels["reach"],
        edge_map=lambda z, w_: z,
        identity=0.0,
        finalize=finalize,
        not_converged=not_conv,
        merge_field="reach",
        lanes=k,
        lane_layout="packed",
        not_converged_lanes=lanes_live,
    )


def sssp_multi(roots: Sequence[int]) -> Problem:
    """K-source SSSP with a (…, K) vector label block: one min-plus reduce
    over the edge stream updates all K distance columns per tile decode.
    Column k is bit-identical to a single-query ``sssp(roots[k])`` run (the
    min reduce broadcasts over lanes; no reassociation)."""
    roots = np.asarray(roots, dtype=np.int64)
    k = int(roots.shape[0])

    def init(g: COOGraph, padded: int):
        if (roots < 0).any() or (roots >= g.num_vertices).any():
            raise ValueError("sssp_multi root out of range")
        lab = np.full((padded, k), INF_F32, dtype=np.float32)
        lab[roots, np.arange(k)] = 0.0
        return {"label": lab}

    def edge_map(z, w):
        step = 1.0 if w is None else w[..., None]
        return jnp.where(z >= INF_F32, z, z + step)

    def not_conv(old: LabelTree, new: LabelTree):
        return jnp.any(old["label"] != new["label"])

    def lanes_live(old: LabelTree, new: LabelTree):
        diff = old["label"] != new["label"]
        return jnp.any(diff.reshape(-1, k), axis=0)

    return Problem(
        name=f"sssp_multi[{k}]",
        reduce_kind="min",
        init_labels=init,
        src_transform=lambda labels: labels["label"],
        edge_map=edge_map,
        edge_op="add",
        identity=float(INF_F32),
        not_converged=not_conv,
        lanes=k,
        lane_layout="vector",
        not_converged_lanes=lanes_live,
    )


def ppr_multi(
    seeds: Sequence[int], damping: float = 0.85, tol: float = 1e-6
) -> Problem:
    """K-seed personalized PageRank, one (…, K) rank column per seed:
        p_k <- (1-d) * e_k + d * A_pull p_k
    The sum reduce is the same one-hot MXU matmul as single-query PR — the
    lane axis just widens the payload operand of the dot."""
    seeds = np.asarray(seeds, dtype=np.int64)
    k = int(seeds.shape[0])

    def init(g: COOGraph, padded: int):
        if (seeds < 0).any() or (seeds >= g.num_vertices).any():
            raise ValueError("ppr_multi seed out of range")
        deg = out_degrees(g).astype(np.float32)
        inv = np.zeros(padded, dtype=np.float32)
        nz = deg > 0
        inv[: g.num_vertices][nz] = 1.0 / deg[nz]
        seed = np.zeros((padded, k), dtype=np.float32)
        seed[seeds, np.arange(k)] = 1.0
        mask = np.zeros(padded, dtype=np.float32)
        mask[: g.num_vertices] = 1.0
        return {"label": seed.copy(), "seed": seed, "inv_deg": inv, "mask": mask}

    def src_transform(labels: LabelTree) -> jnp.ndarray:
        return labels["label"] * labels["inv_deg"][..., None]

    def finalize(labels: LabelTree, acc: jnp.ndarray) -> LabelTree:
        new_rank = ((1.0 - damping) * labels["seed"] + damping * acc)
        new_rank = new_rank * labels["mask"][..., None]
        out = dict(labels)
        out["label"] = new_rank
        return out

    def not_conv(old: LabelTree, new: LabelTree):
        return jnp.max(jnp.abs(old["label"] - new["label"])) > tol

    def lanes_live(old: LabelTree, new: LabelTree):
        diff = jnp.abs(old["label"] - new["label"])
        return jnp.max(diff.reshape(-1, k), axis=0) > tol

    return Problem(
        name=f"ppr_multi[{k}]",
        reduce_kind="sum",
        init_labels=init,
        src_transform=src_transform,
        edge_map=lambda z, w: z,
        identity=0.0,
        finalize=finalize,
        not_converged=not_conv,
        tol=tol,
        lanes=k,
        lane_layout="vector",
        not_converged_lanes=lanes_live,
    )
