"""Graph problems as map/reduce user-defined functions (paper §III, §IV).

A ``Problem`` mirrors the paper's UDF plug-in surface for the graph-core
accumulator:

  * ``src_transform`` — the per-source part of the *map* UDF, evaluated on the
    label shard **before** the crossbar exchange (cheap elementwise work; the
    exchanged payload stays one word per vertex exactly like the paper's
    32-bit labels — PR exchanges rank/deg pre-divided, matching the paper's
    packed (degree, rank) 64-bit label semantics with half the traffic).
  * ``edge_map`` — the per-edge part of the map UDF (adds the edge weight for
    SSSP; identity otherwise).
  * ``reduce_kind`` — 'min' or 'sum': the reduce UDF of the accumulator
    (BFS/WCC/SSSP = min, PR = sum). Exactly the paper's switchable reduce PE.
  * ``apply`` semantics are implied by ``reduce_kind``: min-problems merge into
    the old label (idempotent → may be applied per phase = asynchronous);
    sum-problems replace via ``finalize`` at iteration end (the paper's PR
    double buffering over two vertex label arrays).

Labels are dicts of (…, Vl) arrays so problems may carry auxiliary per-vertex
state (e.g. PR's inverse out-degree) without the engine knowing.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.graph import COOGraph, out_degrees

__all__ = ["Problem", "bfs", "wcc", "sssp", "pagerank", "INF_U32"]

INF_U32 = np.uint32(0xFFFFFFFF)

LabelTree = Dict[str, jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Problem:
    name: str
    reduce_kind: str  # 'min' | 'sum'
    # host-side: build initial (padded) label tree given padded size & graph
    init_labels: Callable[[COOGraph, int], Dict[str, np.ndarray]]
    # device-side map UDF, source half: label sub-tree -> exchanged payload
    src_transform: Callable[[LabelTree], jnp.ndarray]
    # device-side map UDF, edge half: (payload_at_src, edge_weight|None) -> contribution
    edge_map: Callable[[jnp.ndarray, Optional[jnp.ndarray]], jnp.ndarray]
    # declarative form of ``edge_map`` for the fused Pallas path: 'none' means
    # the contribution IS the payload (BFS/WCC/PR, whose per-source work lives
    # in ``src_transform``); 'add' is the saturating min-plus weight add (SSSP).
    # ``edge_map`` stays the oracle the XLA path executes.
    edge_op: str = "none"
    # identity element of the reduce UDF
    identity: float = 0.0
    # iteration finalize for sum problems: (labels, accumulated) -> new labels
    finalize: Optional[Callable[[LabelTree, jnp.ndarray], LabelTree]] = None
    # convergence: (old, new) -> bool scalar (True = keep iterating)
    not_converged: Optional[Callable[[LabelTree, LabelTree], jnp.ndarray]] = None
    # which label field is merged by min-problems
    merge_field: str = "label"
    tol: float = 1e-6

    def payload_dtype(self, labels: Dict[str, np.ndarray]):
        return labels[self.merge_field].dtype


# ---------------------------------------------------------------------------
# BFS — label = hop distance from root; map = src+1; reduce = min.
# ---------------------------------------------------------------------------


def bfs(root: int) -> Problem:
    def init(g: COOGraph, padded: int):
        lab = np.full(padded, INF_U32, dtype=np.uint32)
        lab[root] = 0
        return {"label": lab}

    def src_transform(labels: LabelTree) -> jnp.ndarray:
        lab = labels["label"]
        # saturating +1 so INF stays INF
        return jnp.where(lab == INF_U32, lab, lab + jnp.uint32(1))

    def edge_map(z, w):
        return z

    def not_conv(old: LabelTree, new: LabelTree):
        return jnp.any(old["label"] != new["label"])

    return Problem(
        name="bfs",
        reduce_kind="min",
        init_labels=init,
        src_transform=src_transform,
        edge_map=edge_map,
        identity=float(INF_U32),
        not_converged=not_conv,
    )


# ---------------------------------------------------------------------------
# WCC — label = min vertex id in the weakly connected component. Requires the
# symmetrized edge set (undirected closure), as in the paper.
# ---------------------------------------------------------------------------


def wcc() -> Problem:
    def init(g: COOGraph, padded: int):
        lab = np.arange(padded, dtype=np.uint32)
        return {"label": lab}

    def not_conv(old: LabelTree, new: LabelTree):
        return jnp.any(old["label"] != new["label"])

    return Problem(
        name="wcc",
        reduce_kind="min",
        init_labels=init,
        src_transform=lambda labels: labels["label"],
        edge_map=lambda z, w: z,
        identity=float(INF_U32),
        not_converged=not_conv,
    )


# ---------------------------------------------------------------------------
# SSSP — min-plus with float32 edge weights (HitGraph's BFS comparison uses
# unit weights; we support general non-negative weights).
# ---------------------------------------------------------------------------

INF_F32 = np.float32(np.finfo(np.float32).max)


def sssp(root: int) -> Problem:
    def init(g: COOGraph, padded: int):
        lab = np.full(padded, INF_F32, dtype=np.float32)
        lab[root] = 0.0
        return {"label": lab}

    def edge_map(z, w):
        return jnp.where(z >= INF_F32, z, z + (w if w is not None else 1.0))

    def not_conv(old: LabelTree, new: LabelTree):
        return jnp.any(old["label"] != new["label"])

    return Problem(
        name="sssp",
        reduce_kind="min",
        init_labels=init,
        src_transform=lambda labels: labels["label"],
        edge_map=edge_map,
        edge_op="add",
        identity=float(INF_F32),
        not_converged=not_conv,
    )


# ---------------------------------------------------------------------------
# PageRank — pull-based power iteration:
#   p(i) <- (1-d)/|V| + d * sum_{j in N_in(i)} p(j) / outdeg(j)
# Exchanged payload is rank * inv_outdeg (the per-source map half), reduce=sum,
# finalize applies damping. Convergence on max |delta| < tol, or max_iters.
# ---------------------------------------------------------------------------


def pagerank(damping: float = 0.85, tol: float = 1e-6) -> Problem:
    def init(g: COOGraph, padded: int):
        deg = out_degrees(g).astype(np.float32)
        inv = np.zeros(padded, dtype=np.float32)
        nz = deg > 0
        inv[: g.num_vertices][nz] = 1.0 / deg[nz]
        rank = np.zeros(padded, dtype=np.float32)
        rank[: g.num_vertices] = 1.0 / g.num_vertices
        mask = np.zeros(padded, dtype=np.float32)
        mask[: g.num_vertices] = 1.0
        return {"label": rank, "inv_deg": inv, "mask": mask, "n": np.float32(g.num_vertices)}

    def src_transform(labels: LabelTree) -> jnp.ndarray:
        return labels["label"] * labels["inv_deg"]

    def finalize(labels: LabelTree, acc: jnp.ndarray) -> LabelTree:
        n = labels["n"]
        new_rank = ((1.0 - damping) / n + damping * acc) * labels["mask"]
        out = dict(labels)
        out["label"] = new_rank
        return out

    def not_conv(old: LabelTree, new: LabelTree):
        return jnp.max(jnp.abs(old["label"] - new["label"])) > tol

    return Problem(
        name="pagerank",
        reduce_kind="sum",
        init_labels=init,
        src_transform=src_transform,
        edge_map=lambda z, w: z,
        identity=0.0,
        finalize=finalize,
        not_converged=not_conv,
        tol=tol,
    )
