"""Frontier bitmaps as packed int words — THE shared frontier machinery.

GraphScale's second pillar is asynchronous processing for fast convergence
(paper §III-A); the missing half of it in this repo was *work-list-driven*
dispatch: late-stage BFS/WCC/SSSP touches a handful of vertices yet the
engine streamed every real tile every iteration. This module is the one
implementation of the frontier notion the repo previously kept twice (the
engine's ``not_converged`` label diff and ``core/frontier.py``'s
``_sparse_exchange`` changed-set):

  * a **frontier word** is one uint32 whose bit ``b`` says "source vertex
    ``w * 32 + b`` of this sub-interval changed" — the same 32-sources-per-
    word granularity as the partition-time coverage bitmaps
    (``PartitionedGraph.tile_coverage``), so activity testing is a bitwise
    AND, never a per-vertex gather;
  * frontier state is ``(..., l, Ws)`` uint32 with ``Ws =
    ceil(sub_size / 32)`` — per phase, per core (leading dims are the
    caller's channel axis: ``(p, l, Ws)`` in-process, ``(l, Ws)`` on a
    distributed device). Phase ``m``'s *gathered* frontier words are the
    cores' ``[:, m, :]`` slices concatenated in core order — exactly the
    layout of the phase's gathered crossbar block, so coverage word ``j``
    and frontier word ``j`` describe the same 32 sources.

Everything here is jnp and traceable; both engines (``core/engine.py``
in-process, ``core/distributed.py`` under shard_map) and the
frontier-compressed exchange (``core/frontier.py``) import from here. No
imports from any engine module — this sits below all of them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "WORD_BITS",
    "words_per_sub",
    "coverage_word_count",
    "pack_bits",
    "frontier_words_from_labels",
    "full_frontier_words",
    "frontier_popcount",
    "lane_popcounts",
    "frontier_active_tiles",
    "active_fetch_map",
]

WORD_BITS = 32


def words_per_sub(sub_size: int) -> int:
    """Frontier words per (core, phase) sub-interval: ceil(sub_size / 32)."""
    return -(-sub_size // WORD_BITS)


def coverage_word_count(p: int, sub_size: int) -> int:
    """int32 coverage words per tile: the phase's gathered block holds
    ``p * words_per_sub`` frontier-word slots, one coverage *bit* each."""
    return -(-(p * words_per_sub(sub_size)) // WORD_BITS)


def pack_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """(..., W*32) bool -> (..., W) uint32; bit ``b`` of word ``w`` is
    element ``w*32 + b`` (the little-endian convention every consumer —
    coverage builder, kernels, tests — shares)."""
    *lead, nb = bits.shape
    assert nb % WORD_BITS == 0, nb
    b = bits.reshape(*lead, nb // WORD_BITS, WORD_BITS).astype(jnp.uint32)
    weights = jnp.left_shift(
        jnp.uint32(1), jnp.arange(WORD_BITS, dtype=jnp.uint32)
    )
    return (b * weights).sum(axis=-1, dtype=jnp.uint32)


def frontier_words_from_labels(
    old: jnp.ndarray, new: jnp.ndarray, l: int, sub_size: int, *,
    lanes: bool = False,
) -> jnp.ndarray:
    """Label diff -> frontier words: (..., Vl) pair -> (..., l, Ws) uint32.

    This IS the convergence check: the run is converged iff every word is
    zero — for min problems it replaces ``problem.not_converged`` (the
    separate full label diff) for free.

    ``lanes=True`` (multi-query batching, docs/tile_layout.md §8): the label
    arrays carry a trailing lane axis (..., Vl, L) — K vector lanes or
    packed reach words — and a vertex is frontier-active iff ANY lane's
    value changed. The resulting words are the UNION of the per-lane
    frontiers: the dynamic tile schedule streams a tile if any live query
    still needs it, and a converged lane (no diffs) contributes nothing.
    """
    changed = old != new  # (..., Vl[, L])
    if lanes:
        changed = changed.any(axis=-1)  # union over lanes
    *lead, vl = changed.shape
    assert vl == l * sub_size, (vl, l, sub_size)
    changed = changed.reshape(*lead, l, sub_size)
    pad = words_per_sub(sub_size) * WORD_BITS - sub_size
    if pad:
        width = [(0, 0)] * (changed.ndim - 1) + [(0, pad)]
        changed = jnp.pad(changed, width)
    return pack_bits(changed)


def full_frontier_words(l: int, sub_size: int, lead=()) -> jnp.ndarray:
    """The all-active frontier (every real source set, tail bits clear) —
    the iteration-0 state: initial labels were never reduced, so the first
    iteration must stream every real tile."""
    ws = words_per_sub(sub_size)
    bits = np.zeros(ws * WORD_BITS, dtype=bool)
    bits[:sub_size] = True
    shifts = np.arange(WORD_BITS, dtype=np.uint64)
    words = (
        (bits.reshape(ws, WORD_BITS).astype(np.uint64) << shifts)
        .sum(axis=1)
        .astype(np.uint32)
    )
    return jnp.broadcast_to(jnp.asarray(words), tuple(lead) + (l, ws))


def frontier_popcount(frontier: jnp.ndarray) -> jnp.ndarray:
    """Total set bits (int32 scalar) — the density-switch statistic. Callers
    with a sharded frontier psum this over the channel axis."""
    return jax.lax.population_count(frontier).astype(jnp.int32).sum()


def lane_popcounts(changed_lanes: jnp.ndarray) -> jnp.ndarray:
    """Per-lane frontier sizes: (..., K) bool change mask -> (K,) int32
    changed-vertex counts (summed over all leading axes). Multi-query
    observability statistic (``problem.not_converged_lanes`` is its
    boolean projection); distributed callers psum it over the channel axis
    so every channel observes identical per-lane liveness."""
    k = changed_lanes.shape[-1]
    return changed_lanes.reshape(-1, k).sum(axis=0, dtype=jnp.int32)


def frontier_active_tiles(
    coverage_m: jnp.ndarray,  # (n, R, T, Wc) uint32 phase coverage bitmaps
    gathered_words: jnp.ndarray,  # (Wg,) uint32 phase frontier, gathered order
    counts_m: jnp.ndarray,  # (n, R) int32 static real-tile counts
    use_dense=None,  # scalar bool | None: wide-frontier fallback switch
) -> jnp.ndarray:
    """The dynamic tile scheduler: (n, R, T) bool active mask for one phase.

    A tile is active iff it is real (``t < counts``) AND its coverage bitmap
    intersects the set of nonzero frontier words — one vectorized AND over
    ``Wc`` words per tile, no per-edge or per-source work. ``use_dense``
    (the ``lax.cond`` density switch) short-circuits to the static all-real
    mask when the frontier is wide and the AND would save nothing; pass
    None to always compute the dynamic mask. Word granularity makes the
    test conservative (a tile sharing a 32-source word with the frontier is
    kept), never lossy — skipped tiles provably read no changed source.
    """
    n, r_blocks, t_tiles, wc = coverage_m.shape
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (n, r_blocks, t_tiles), 2)
    real = t_idx < counts_m[..., None]

    def dynamic(_):
        nz = gathered_words != jnp.uint32(0)  # (Wg,) word-activity bits
        pad = wc * WORD_BITS - nz.shape[0]
        nzp = jnp.pad(nz, (0, pad)) if pad else nz
        packed = pack_bits(nzp)  # (Wc,) uint32
        hit = jnp.any((coverage_m & packed) != jnp.uint32(0), axis=-1)
        return jnp.logical_and(real, hit)

    if use_dense is None:
        return dynamic(None)
    return jax.lax.cond(use_dense, lambda _: real, dynamic, None)


def active_fetch_map(active: jnp.ndarray) -> jnp.ndarray:
    """Active mask -> the scalar-prefetched fetch map the kernel consumes:
    ``fetch[..., t]`` is the index of the last active tile at or before
    ``t`` (-1 before the first). The kernel runs tile ``t`` iff
    ``fetch[t] == t``; skipped grid steps re-name the previous active block
    so the pipeline never re-DMAs for them (same elision trick as the
    static tile-count clamp)."""
    t_idx = jax.lax.broadcasted_iota(jnp.int32, active.shape, active.ndim - 1)
    marked = jnp.where(active, t_idx, jnp.int32(-1))
    return jax.lax.cummax(marked, axis=active.ndim - 1)
