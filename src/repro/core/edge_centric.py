"""Synchronous edge-centric baseline engine (HitGraph [8] / ThunderGP [9]).

The comparison target the paper measures against: iterate the *edge list*
(8 bytes/edge, uncompressed), produce one update per edge from the source
label, coalesce updates, and apply them only at the END of each iteration
(synchronous propagation). Per paper Fig. 1 this pays both more bytes/edge and
more iterations than GraphScale's asynchronous compressed design.

Implemented with the same UDF ``Problem`` interface so benchmark comparisons
hold the algorithm fixed and vary only the engine.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.partition import EdgeCentricPartition
from repro.core.problems import Problem

__all__ = ["EdgeCentricOptions", "run_edge_centric"]


@dataclasses.dataclass(frozen=True)
class EdgeCentricOptions:
    max_iters: int = 1000


@dataclasses.dataclass
class EdgeCentricResult:
    labels: Dict[str, np.ndarray]
    iterations: int
    converged: bool


def _prepare(problem: Problem, g, part: EdgeCentricPartition):
    padded = part.p * part.vertices_per_core
    labels = problem.init_labels(g, padded)
    out = {}
    for k, v in labels.items():
        v = np.asarray(v)
        if v.ndim == 1 and v.shape[0] == padded:
            v = v.reshape(part.p, part.vertices_per_core)
        out[k] = jnp.asarray(v)
    return out


@partial(jax.jit, static_argnames=("problem", "part", "opts"))
def _run_jit(problem, part, opts, labels):
    p = part.p
    vpc = part.vertices_per_core
    src_vid = jnp.asarray(part.src_vid)  # (p, E) global ids
    dst_lidx = jnp.asarray(part.dst_lidx)
    valid = jnp.asarray(part.valid)
    w = jnp.asarray(part.weights) if part.weights is not None else None

    def iteration(labels):
        # scatter phase: every core reads source labels from the full
        # (synchronously consistent) label array of the previous iteration.
        payload = problem.src_transform(labels).reshape(p * vpc)
        svals = jnp.take(payload, src_vid, axis=0)  # (p, E)
        contrib = problem.edge_map(svals, w)
        identity = jnp.asarray(problem.identity, dtype=contrib.dtype)
        contrib = jnp.where(valid, contrib, identity)

        def seg(c, d):
            if problem.reduce_kind == "min":
                return jax.ops.segment_min(c, d, num_segments=vpc, indices_are_sorted=True)
            return jax.ops.segment_sum(c, d, num_segments=vpc, indices_are_sorted=True)

        acc = jax.vmap(seg)(contrib, dst_lidx)  # (p, vpc)
        # gather/apply phase: updates applied only now (synchronous)
        if problem.reduce_kind == "min":
            lab = labels[problem.merge_field]
            new = dict(labels)
            new[problem.merge_field] = jnp.minimum(lab, acc.astype(lab.dtype))
            return new
        return problem.finalize(labels, acc)

    def cond(carry):
        _, it, changed = carry
        return jnp.logical_and(changed, it < opts.max_iters)

    def body(carry):
        labels, it, _ = carry
        new = iteration(labels)
        return new, it + 1, problem.not_converged(labels, new)

    return jax.lax.while_loop(cond, body, (labels, jnp.int32(0), jnp.bool_(True)))


def run_edge_centric(
    problem: Problem, g, part: EdgeCentricPartition, opts: EdgeCentricOptions = EdgeCentricOptions()
) -> EdgeCentricResult:
    from repro.core.engine import _wrap

    labels = _prepare(problem, g, part)
    labels, iters, changed = _run_jit(_wrap(problem), _wrap(part), opts, labels)
    out = {}
    for k, v in labels.items():
        v = np.asarray(v)
        if v.ndim == 2 and v.shape == (part.p, part.vertices_per_core):
            out[k] = v.reshape(-1)[: part.num_vertices]
        else:
            out[k] = v
    return EdgeCentricResult(labels=out, iterations=int(iters), converged=not bool(changed))
