"""repro: GraphScale (Dann et al., 2022) reproduced as a multi-pod JAX framework."""
__version__ = "0.1.0"
