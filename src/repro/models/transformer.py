"""Decoder-only LM (dense + MoE): scan-over-layers, GQA, qk-norm, RoPE,
SwiGLU, KV-cache decode. Covers qwen3-14b / smollm-135m / llama3-8b /
granite-moe / qwen3-moe configs.

Parameters are explicit pytrees with layer-stacked leaves (L, ...) consumed by
``jax.lax.scan`` — one compiled block regardless of depth (compile time and
HLO size stay O(1) in layers, which the multi-pod dry-run depends on).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import (
    MoEConfig,
    apply_rope,
    chunked_gqa_attention,
    decode_gqa_attention,
    init_attention,
    init_dense_ffn,
    init_moe_ffn,
    moe_ffn,
    moe_ffn_grouped,
    rms_norm,
    rope,
    swiglu,
)

__all__ = ["LMConfig", "init_params", "forward", "init_kv_cache", "decode_step", "count_params"]


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 10000.0
    moe: Optional[MoEConfig] = None
    dtype: Any = jnp.bfloat16
    attn_chunk: int = 1024
    remat: bool = True
    # explicit GSPMD activation constraints (NamedShardings; hashable), set by
    # the launcher per mesh — without them the FSDP param sharding conflicts
    # with batch sharding at the embedding gather and GSPMD drops the batch
    # axis (measured: 340 GiB/device temp on smollm train_4k).
    act_sharding: Any = None  # (B, S, d)
    logit_sharding: Any = None  # (B, S, V)
    expert_sharding: Any = None  # (E, C, d) MoE dispatch buffers
    attn_sharding: Any = None  # (B, Hq, S, hd) q/scores head sharding (if divisible)
    moe_groups: int = 1  # >1: grouped dispatch (capacity dim shards over fsdp)
    vocab_real: Any = None  # set when vocab is PADDED for shardability; loss masks the tail
    # Unroll layer/chunk scans so XLA cost_analysis counts every iteration
    # (while-loop bodies are costed ONCE regardless of trip count — measured).
    # The dry-run sets this; trainers keep rolled scans for compile speed.
    scan_unroll: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    def capacity(self, tokens_per_shard: int) -> int:
        assert self.moe is not None
        c = int(tokens_per_shard * self.moe.top_k / self.moe.num_experts
                * self.moe.capacity_factor)
        return max(8, ((c + 7) // 8) * 8)


def init_params(rng: jax.Array, cfg: LMConfig) -> Dict[str, Any]:
    k_emb, k_layers, k_norm, k_out = jax.random.split(rng, 4)

    def layer_init(k):
        ka, kf = jax.random.split(k)
        p = {
            "attn": init_attention(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd, cfg.dtype),
            "ln1": jnp.ones((cfg.d_model,), cfg.dtype),
            "ln2": jnp.ones((cfg.d_model,), cfg.dtype),
        }
        if cfg.qk_norm:
            p["q_norm"] = jnp.ones((cfg.hd,), cfg.dtype)
            p["k_norm"] = jnp.ones((cfg.hd,), cfg.dtype)
        if cfg.moe is None:
            p["ffn"] = init_dense_ffn(kf, cfg.d_model, cfg.d_ff, cfg.dtype)
        else:
            p["ffn"] = init_moe_ffn(kf, cfg.d_model, cfg.moe, cfg.dtype)
        return p

    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(layer_init)(layer_keys)  # stacked (L, ...) leaves
    emb_scale = cfg.d_model ** -0.5
    return {
        "embed": (jax.random.normal(k_emb, (cfg.vocab, cfg.d_model)) * emb_scale).astype(cfg.dtype),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), cfg.dtype),
        "unembed": (jax.random.normal(k_out, (cfg.d_model, cfg.vocab)) * emb_scale).astype(cfg.dtype),
    }


def _attention(lp, x, cfg: LMConfig, cos, sin, *, cache=None, length_mask=None):
    b, s, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ lp["attn"]["wq"]).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = (x @ lp["attn"]["wk"]).reshape(b, s, kvh, hd).transpose(0, 2, 1, 3)
    v = (x @ lp["attn"]["wv"]).reshape(b, s, kvh, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rms_norm(q, lp["q_norm"])
        k = rms_norm(k, lp["k_norm"])
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    q = _wsc(q, cfg.attn_sharding)
    if cache is None:
        o = chunked_gqa_attention(
            q, k, v, causal=True, chunk=min(cfg.attn_chunk, s),
            unroll=cfg.scan_unroll,
        )
        new_cache = None
    else:
        k_cache, v_cache, pos = cache
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, pos, axis=2)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, pos, axis=2)
        o = decode_gqa_attention(q, k_cache, v_cache, length_mask)
        new_cache = (k_cache, v_cache)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    return o @ lp["attn"]["wo"], new_cache


def _ffn(lp, x, cfg: LMConfig):
    b, s, d = x.shape
    if cfg.moe is None:
        return swiglu(x, lp["ffn"]["w1"], lp["ffn"]["w3"], lp["ffn"]["w2"]), 0.0
    flat = x.reshape(b * s, d)
    if cfg.moe_groups > 1:
        out, aux = moe_ffn_grouped(
            flat,
            lp["ffn"]["router"], lp["ffn"]["w1"], lp["ffn"]["w3"], lp["ffn"]["w2"],
            cfg.moe,
            capacity=cfg.capacity(b * s // cfg.moe_groups),
            groups=cfg.moe_groups,
            expert_sharding=cfg.expert_sharding,
        )
    else:
        out, aux = moe_ffn(
            flat,
            lp["ffn"]["router"],
            lp["ffn"]["w1"],
            lp["ffn"]["w3"],
            lp["ffn"]["w2"],
            cfg.moe,
            capacity=cfg.capacity(b * s),
            expert_sharding=cfg.expert_sharding,
        )
    return out.reshape(b, s, d), aux


def _wsc(x, sharding):
    return jax.lax.with_sharding_constraint(x, sharding) if sharding is not None else x


def _block(lp, x, cfg: LMConfig, cos, sin):
    a, _ = _attention(lp, rms_norm(x, lp["ln1"]), cfg, cos, sin)
    # constrain the row-parallel sublayer OUTPUTS (not just the residual sum):
    # under sequence parallelism GSPMD then emits reduce-scatter instead of
    # all-reduce for the wo/w2 partial sums (Megatron-SP; §Perf LM iteration)
    a = _wsc(a, cfg.act_sharding)
    x = _wsc(x + a, cfg.act_sharding)
    f, aux = _ffn(lp, rms_norm(x, lp["ln2"]), cfg)
    f = _wsc(f, cfg.act_sharding)
    return _wsc(x + f, cfg.act_sharding), aux


def forward(params, tokens: jnp.ndarray, cfg: LMConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens (B, S) -> logits (B, S, V), aux_loss (scalar)."""
    b, s = tokens.shape
    x = _wsc(jnp.take(params["embed"], tokens, axis=0), cfg.act_sharding)
    cos, sin = rope(jnp.arange(s), cfg.hd, cfg.rope_theta)
    block = _block
    if cfg.remat:
        block = jax.checkpoint(
            _block, policy=jax.checkpoint_policies.nothing_saveable, static_argnums=(2,)
        )

    def scan_body(carry, lp):
        x, aux = carry
        x, a = block(lp, x, cfg, cos, sin)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(
        scan_body, (x, jnp.float32(0.0)), params["layers"],
        unroll=cfg.n_layers if cfg.scan_unroll else 1,
    )
    x = rms_norm(x, params["final_norm"])
    logits = _wsc(x @ params["unembed"], cfg.logit_sharding)
    return logits, aux


# ---------------------------------------------------------------------------
# KV-cache decode
# ---------------------------------------------------------------------------


def init_kv_cache(cfg: LMConfig, batch: int, max_len: int, dtype=None):
    dtype = dtype or cfg.dtype
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, max_len, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_step(params, cache, tokens: jnp.ndarray, pos: jnp.ndarray, cfg: LMConfig):
    """One decode step. tokens (B, 1); pos scalar int32 (current position).

    Returns (logits (B, V), new cache). Cache layout (L, B, Hkv, S, D); the
    sequence axis may be sharded (sequence-parallel cache) — the softmax
    reductions inside decode attention then lower to all-reduces.
    """
    b = tokens.shape[0]
    max_len = cache["k"].shape[3]
    x = _wsc(jnp.take(params["embed"], tokens, axis=0), cfg.act_sharding)  # (B, 1, d)
    cos, sin = rope(pos[None], cfg.hd, cfg.rope_theta)  # (1, hd/2)
    length_mask = (jnp.arange(max_len, dtype=jnp.int32)[None, :] <= pos).astype(bool)
    length_mask = jnp.broadcast_to(length_mask, (b, max_len))

    def scan_body(x_aux, layer):
        x, _ = x_aux
        lp, kc, vc = layer
        a, new_kv = _attention(
            lp, rms_norm(x, lp["ln1"]), cfg, cos, sin,
            cache=(kc, vc, pos), length_mask=length_mask,
        )
        x = x + a
        f, _ = _ffn(lp, rms_norm(x, lp["ln2"]), cfg)
        return (x + f, 0.0), new_kv

    (x, _), new_kv = jax.lax.scan(
        scan_body, (x, 0.0), (params["layers"], cache["k"], cache["v"]),
        unroll=cfg.n_layers if cfg.scan_unroll else 1,
    )
    x = rms_norm(x, params["final_norm"])
    logits = (x @ params["unembed"])[:, 0, :]
    return logits, {"k": new_kv[0], "v": new_kv[1]}


def count_params(cfg: LMConfig) -> int:
    d, hd = cfg.d_model, cfg.hd
    attn = d * (cfg.n_heads * hd) * 2 + d * (cfg.n_kv_heads * hd) * 2
    if cfg.moe is None:
        ffn = 3 * d * cfg.d_ff
    else:
        ffn = cfg.moe.num_experts * 3 * d * cfg.moe.d_ff_expert + d * cfg.moe.num_experts
    per_layer = attn + ffn + 2 * d + (2 * hd if cfg.qk_norm else 0)
    return cfg.n_layers * per_layer + 2 * cfg.vocab * d + d


def active_params(cfg: LMConfig) -> int:
    """Active (per-token) parameters — MoE counts only top_k experts."""
    if cfg.moe is None:
        return count_params(cfg)
    d, hd = cfg.d_model, cfg.hd
    attn = d * (cfg.n_heads * hd) * 2 + d * (cfg.n_kv_heads * hd) * 2
    ffn = cfg.moe.top_k * 3 * d * cfg.moe.d_ff_expert + d * cfg.moe.num_experts
    per_layer = attn + ffn + 2 * d + (2 * hd if cfg.qk_norm else 0)
    return cfg.n_layers * per_layer + 2 * cfg.vocab * d + d
