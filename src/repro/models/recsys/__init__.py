from repro.models.recsys import din  # noqa: F401
