"""DIN — Deep Interest Network [arXiv:1706.06978].

embed_dim=18, seq_len=100, attention MLP 80-40, output MLP 200-80,
interaction = target attention over the user behavior sequence (unnormalized
attention weights, per the paper).

The embedding tables are the hot path (docs/distributed.md §4: sharded lookup == the
GraphScale vertex-label crossbar with rows as labels). The multi-hot user
profile feature routes through the EmbeddingBag kernel path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.ref import embedding_bag_reference
from repro.models.gnn.common import init_mlp, mlp

__all__ = ["DINConfig", "init", "score", "score_candidates"]


@dataclasses.dataclass(frozen=True)
class DINConfig:
    name: str = "din"
    embed_dim: int = 18
    seq_len: int = 100
    attn_mlp: tuple = (80, 40)
    out_mlp: tuple = (200, 80)
    item_vocab: int = 1_000_000
    cate_vocab: int = 1_000
    profile_bag_len: int = 32  # multi-hot profile feature (EmbeddingBag)
    dtype: Any = jnp.float32
    lookup: str = "take"  # 'take' (GSPMD) | 'crossbar' (GraphScale exchange)


def init(rng, cfg: DINConfig) -> Dict[str, Any]:
    k_i, k_c, k_a, k_o, k_p = jax.random.split(rng, 5)
    d = cfg.embed_dim
    elem = 2 * d  # item ++ cate
    return {
        "item_table": (jax.random.normal(k_i, (cfg.item_vocab, d)) * 0.01).astype(cfg.dtype),
        "cate_table": (jax.random.normal(k_c, (cfg.cate_vocab, d)) * 0.01).astype(cfg.dtype),
        "attn": init_mlp(k_a, [4 * elem, *cfg.attn_mlp, 1], cfg.dtype),
        # input: attention-pooled history (elem) ++ target (elem) ++ profile bag (d)
        "out": init_mlp(k_o, [2 * elem + d, *cfg.out_mlp, 1], cfg.dtype),
        "prelu": jnp.full((len(cfg.out_mlp),), 0.25, cfg.dtype),
    }


def _embed_elem(params, item_ids, cate_ids, lookup_fn=None):
    """Item rows come from the (sharded) item table; ``lookup_fn`` overrides
    the default XLA take with the GraphScale crossbar exchange
    (dist/embedding.make_crossbar_lookup) — GSPMD otherwise all-gathers the
    full table to every device (measured 717 MB/step on serve_bulk)."""
    if lookup_fn is not None:
        it = lookup_fn(params["item_table"], jnp.maximum(item_ids, 0))
    else:
        it = jnp.take(params["item_table"], jnp.maximum(item_ids, 0), axis=0)
    ct = jnp.take(params["cate_table"], jnp.maximum(cate_ids, 0), axis=0)
    return jnp.concatenate([it, ct], axis=-1)  # (..., 2d)


def _attention_pool(params, hist, target, hist_mask):
    """DIN local activation unit: a = MLP([h, t, h-t, h*t]); weighted sum.
    hist (B, L, e); target (B, e) -> (B, e)."""
    t = target[:, None, :].astype(hist.dtype)
    feats = jnp.concatenate([hist, jnp.broadcast_to(t, hist.shape), hist - t, hist * t], axis=-1)
    a = mlp(params["attn"], feats)[..., 0]  # (B, L) — NOT softmax-normalized (paper)
    a = jnp.where(hist_mask, a, 0.0)
    return jnp.einsum("bl,ble->be", a, hist)


def score(params, batch: Dict[str, jnp.ndarray], cfg: DINConfig, lookup_fn=None) -> jnp.ndarray:
    """batch: hist_items/hist_cates (B, L) [-1 pad], target_item/target_cate
    (B,), profile_bag (B, P) [-1 pad]. Returns logits (B,)."""
    hist = _embed_elem(params, batch["hist_items"], batch["hist_cates"], lookup_fn)  # (B, L, e)
    hist_mask = batch["hist_items"] >= 0
    hist = jnp.where(hist_mask[..., None], hist, 0.0)
    target = _embed_elem(params, batch["target_item"], batch["target_cate"], lookup_fn)  # (B, e)
    user = _attention_pool(params, hist, target, hist_mask)  # (B, e)
    prof = embedding_bag_reference(params["cate_table"], batch["profile_bag"], mode="sum")
    x = jnp.concatenate([user, target, prof], axis=-1)
    # output MLP with PReLU activations
    n = len(params["out"]["w"])
    for i, (w, b) in enumerate(zip(params["out"]["w"], params["out"]["b"])):
        x = x @ w + b
        if i < n - 1:
            alpha = params["prelu"][i]
            x = jnp.where(x >= 0, x, alpha * x)
    return x[..., 0]


def score_candidates(
    params,
    batch: Dict[str, jnp.ndarray],
    cfg: DINConfig,
    chunk: int | None = None,
    lookup_fn=None,
) -> jnp.ndarray:
    """Retrieval scoring: ONE user vs n_candidates items. batch:
    hist_items/hist_cates (1, L), profile_bag (1, P), cand_items/cand_cates
    (C,). Returns (C,) scores.

    ``chunk=None`` scores all candidates in one vectorized pass (the sharded
    production path: candidates sharded over the mesh); an integer chunk uses
    lax.map for memory-bounded single-host runs. ``lookup_fn`` routes BOTH
    the history and candidate item-table reads through the GraphScale
    crossbar exchange (see ``_embed_elem``) — the serving router's
    recommend-for path passes ``dist.embedding.make_crossbar_lookup``.
    """
    c = batch["cand_items"].shape[0]
    hist = _embed_elem(params, batch["hist_items"], batch["hist_cates"], lookup_fn)  # (1, L, e)
    hist_mask = batch["hist_items"] >= 0
    hist = jnp.where(hist_mask[..., None], hist, 0.0)
    prof = embedding_bag_reference(params["cate_table"], batch["profile_bag"], mode="sum")

    def score_block(items, cates):
        n = items.shape[0]
        target = _embed_elem(params, items, cates, lookup_fn)  # (n, e)
        h = jnp.broadcast_to(hist, (n,) + hist.shape[1:])
        m = jnp.broadcast_to(hist_mask, (n,) + hist_mask.shape[1:])
        user = _attention_pool(params, h, target, m)  # (n, e)
        pb = jnp.broadcast_to(prof, (n, prof.shape[-1]))
        x = jnp.concatenate([user, target, pb], axis=-1)
        layers = len(params["out"]["w"])
        for i, (w, b) in enumerate(zip(params["out"]["w"], params["out"]["b"])):
            x = x @ w + b
            if i < layers - 1:
                x = jnp.where(x >= 0, x, params["prelu"][i] * x)
        return x[..., 0]

    if chunk is None:
        return score_block(batch["cand_items"], batch["cand_cates"])
    assert c % chunk == 0, (c, chunk)
    cands = (
        batch["cand_items"].reshape(-1, chunk),
        batch["cand_cates"].reshape(-1, chunk),
    )
    return jax.lax.map(lambda xs: score_block(*xs), cands).reshape(-1)
