"""Transformer building blocks: RMSNorm, RoPE, chunked GQA attention, SwiGLU,
sort-based MoE. Pure functions over explicit parameter pytrees (no flax), so
every array's sharding is controlled by the caller's constraints.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "MoEConfig",
    "rms_norm",
    "rope",
    "apply_rope",
    "chunked_gqa_attention",
    "decode_gqa_attention",
    "swiglu",
    "moe_ffn",
    "moe_ffn_grouped",
    "init_dense_ffn",
    "init_moe_ffn",
    "init_attention",
]

_NEG = -1e30


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def rope(positions: jnp.ndarray, d: int, theta: float = 10000.0):
    """Returns (cos, sin) of shape (..., d//2)."""
    freqs = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (..., S, D); cos/sin broadcastable (S, D/2). LLaMA half-rotation."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(
        x.dtype
    )


def _attn_block(q, k, v, m, l, acc, qpos, kpos, scale, causal):
    """Online-softmax update for one KV chunk (the XLA twin of the Pallas
    flash kernel — identical recurrence, differentiable, remat-friendly).

    GQA is expressed with a grouped einsum over (B, Hkv, G, S, hd) — K/V are
    NEVER repeated to query heads. The earlier jnp.repeat version made XLA
    move group-x redundant K/V between sequence shards (measured 7 GiB/layer
    of f32[B,Hq,chunk,hd] all-gathers on llama3 train; §Perf LM iteration 2).
    q: (B, Hkv, G, S, hd); k/v: (B, Hkv, chunk, hd).
    """
    s = jnp.einsum("bkgqd,bkcd->bkgqc", q, k).astype(jnp.float32) * scale
    if causal:
        s = jnp.where(qpos[:, None] >= kpos[None, :], s, _NEG)
    m_new = jnp.maximum(m, s.max(axis=-1))
    alpha = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new[..., None])
    l_new = l * alpha + p.sum(axis=-1)
    acc_new = acc * alpha[..., None] + jnp.einsum(
        "bkgqc,bkcd->bkgqd", p.astype(v.dtype), v
    ).astype(jnp.float32)
    return m_new, l_new, acc_new


def chunked_gqa_attention(
    q: jnp.ndarray,  # (B, Hq, S, D)
    k: jnp.ndarray,  # (B, Hkv, S, D)
    v: jnp.ndarray,
    *,
    causal: bool = True,
    chunk: int = 1024,
    scale: Optional[float] = None,
    unroll: bool = False,
) -> jnp.ndarray:
    """Memory-O(S·chunk) attention: scan over KV chunks with online softmax.

    The per-chunk body is rematerialized so the backward pass recomputes
    chunk logits instead of storing them (flash-attention backward in XLA).
    """
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    n = s // chunk
    qg = q.reshape(b, hkv, group, s, d)  # grouped view: no K/V repeat
    k_chunks = k.reshape(b, hkv, n, chunk, d).transpose(2, 0, 1, 3, 4)
    v_chunks = v.reshape(b, hkv, n, chunk, d).transpose(2, 0, 1, 3, 4)
    qpos = jnp.arange(s, dtype=jnp.int32)

    @jax.checkpoint
    def body(carry, xs):
        m, l, acc = carry
        kc, vc, ci = xs
        kpos = ci * chunk + jnp.arange(chunk, dtype=jnp.int32)
        m, l, acc = _attn_block(qg, kc, vc, m, l, acc, qpos, kpos, scale, causal)
        return (m, l, acc), None

    m0 = jnp.full((b, hkv, group, s), _NEG, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, s), jnp.float32)
    a0 = jnp.zeros((b, hkv, group, s, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (k_chunks, v_chunks, jnp.arange(n, dtype=jnp.int32)),
        unroll=n if unroll else 1,
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, s, d).astype(q.dtype)


def decode_gqa_attention(
    q: jnp.ndarray,  # (B, Hq, 1, D) — one new token
    k_cache: jnp.ndarray,  # (B, Hkv, S, D)
    v_cache: jnp.ndarray,
    length_mask: jnp.ndarray,  # (B, S) bool — which cache slots are filled
    scale: Optional[float] = None,
) -> jnp.ndarray:
    """Single-step decode attention. With a sequence-sharded cache, the
    softmax reductions over S lower to all-reduces (GSPMD)."""
    b, hq, _, d = q.shape
    hkv = k_cache.shape[1]
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    qg = q.reshape(b, hkv, group, d)
    s = jnp.einsum("bkgd,bksd->bkgs", qg, k_cache).astype(jnp.float32) * scale
    s = jnp.where(length_mask[:, None, None, :], s, _NEG)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bksd->bkgd", w.astype(v_cache.dtype), v_cache)
    return o.reshape(b, hq, 1, d)


def swiglu(x: jnp.ndarray, w1, w3, w2) -> jnp.ndarray:
    return (jax.nn.silu(x @ w1) * (x @ w3)) @ w2


# ---------------------------------------------------------------------------
# Sort-based MoE (capacity-dropped): flatten (token, expert) assignments, sort
# by expert, pack each expert's tokens into (E, C) slots, grouped-GEMM, and
# combine weighted by router gates. Irregular gather/scatter — shares the
# segment-ops substrate with the GraphScale engine (docs/distributed.md §4).
# ---------------------------------------------------------------------------


def moe_ffn_grouped(
    x: jnp.ndarray,  # (T, d)
    router_w, w1, w3, w2,
    cfg: MoEConfig,
    capacity: int,  # PER-GROUP capacity
    groups: int,
    expert_sharding=None,  # NamedSharding for (G, E, C, d) dispatch buffers
):
    """Grouped MoE dispatch (GSPMD-style): tokens split into ``groups``
    independent dispatch groups (one per data shard) so the capacity dim of
    the (G, E, C, d) buffers shards over fsdp instead of replicating expert
    GEMMs on every data replica (hillclimb fix: 16x overcompute measured on
    granite-moe train_4k — EXPERIMENTS.md §Perf)."""
    t, d = x.shape
    g, e, k = groups, cfg.num_experts, cfg.top_k
    tg = t // g
    xg = x.reshape(g, tg, d)

    def route(xi):  # per-group index machinery (cheap; vmapped)
        logits = (xi @ router_w).astype(jnp.float32)
        gates = jax.nn.softmax(logits, axis=-1)  # (Tg, E)
        top_g, top_i = jax.lax.top_k(gates, k)
        top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)
        eids = top_i.reshape(-1)
        gvals = top_g.reshape(-1)
        order = jnp.argsort(eids)
        eids_s = eids[order]
        tok_s = order // k
        g_s = gvals[order]
        counts = jnp.bincount(eids_s, length=e)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(tg * k) - starts[eids_s]
        keep = pos < capacity
        slot = jnp.where(keep, eids_s * capacity + pos, e * capacity)
        tok_for_slot = jnp.full((e * capacity + 1,), tg, jnp.int32).at[slot].set(
            tok_s.astype(jnp.int32)
        )[:-1]
        g_for_slot = jnp.zeros((e * capacity + 1,), x.dtype).at[slot].set(
            g_s.astype(x.dtype)
        )[:-1]
        me = gates.mean(axis=0)
        ce = jnp.zeros((e,), jnp.float32).at[eids].add(1.0) / (tg * k)
        return tok_for_slot, g_for_slot, me, ce

    tok_slot, g_slot, me, ce = jax.vmap(route)(xg)  # (G, E*C) ...
    x_pad = jnp.concatenate([xg, jnp.zeros((g, 1, d), x.dtype)], axis=1)
    gathered = jnp.take_along_axis(x_pad, tok_slot[..., None], axis=1)
    gathered = gathered.reshape(g, e, capacity, d)
    if expert_sharding is not None:
        gathered = jax.lax.with_sharding_constraint(gathered, expert_sharding)
    h = jnp.einsum("gecd,edf->gecf", gathered, w1)
    h3 = jnp.einsum("gecd,edf->gecf", gathered, w3)
    out_slots = jnp.einsum("gecf,efd->gecd", jax.nn.silu(h) * h3, w2)
    if expert_sharding is not None:
        out_slots = jax.lax.with_sharding_constraint(out_slots, expert_sharding)
    out_slots = out_slots.reshape(g, e * capacity, d) * g_slot[..., None]

    def combine(ts, os):
        return jnp.zeros((tg + 1, d), x.dtype).at[ts].add(os)[:tg]

    out = jax.vmap(combine)(tok_slot, out_slots)  # (G, Tg, d)
    aux = cfg.router_aux_weight * e * jnp.mean(jnp.sum(me * ce, axis=-1))
    return out.reshape(t, d), aux


def moe_ffn(
    x: jnp.ndarray,  # (T, d)
    router_w: jnp.ndarray,  # (d, E)
    w1: jnp.ndarray,  # (E, d, f)
    w3: jnp.ndarray,  # (E, d, f)
    w2: jnp.ndarray,  # (E, f, d)
    cfg: MoEConfig,
    capacity: int,
    expert_sharding=None,  # NamedSharding for (E, C, d) dispatch buffers (EP)
):
    t, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    logits = (x @ router_w).astype(jnp.float32)  # (T, E)
    gates_all = jax.nn.softmax(logits, axis=-1)
    top_g, top_i = jax.lax.top_k(gates_all, k)  # (T, k)
    top_g = top_g / jnp.maximum(top_g.sum(-1, keepdims=True), 1e-9)

    eids = top_i.reshape(-1)  # (T*k,)
    gvals = top_g.reshape(-1)
    order = jnp.argsort(eids)
    eids_s = eids[order]
    tok_s = order // k
    g_s = gvals[order]
    counts = jnp.bincount(eids_s, length=e)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(t * k) - starts[eids_s]
    keep = pos < capacity
    slot = jnp.where(keep, eids_s * capacity + pos, e * capacity)  # dump slot

    tok_for_slot = jnp.full((e * capacity + 1,), t, jnp.int32)  # t = dummy token
    tok_for_slot = tok_for_slot.at[slot].set(tok_s.astype(jnp.int32))
    g_for_slot = jnp.zeros((e * capacity + 1,), x.dtype).at[slot].set(g_s.astype(x.dtype))
    tok_for_slot, g_for_slot = tok_for_slot[:-1], g_for_slot[:-1]

    x_pad = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    gathered = jnp.take(x_pad, tok_for_slot, axis=0).reshape(e, capacity, d)
    if expert_sharding is not None:  # expert-parallel dispatch (all-to-all)
        gathered = jax.lax.with_sharding_constraint(gathered, expert_sharding)
    h = jnp.einsum("ecd,edf->ecf", gathered, w1)
    h3 = jnp.einsum("ecd,edf->ecf", gathered, w3)
    out_slots = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * h3, w2)
    if expert_sharding is not None:
        out_slots = jax.lax.with_sharding_constraint(out_slots, expert_sharding)
    out_slots = out_slots.reshape(e * capacity, d) * g_for_slot[:, None]

    out = jnp.zeros((t + 1, d), x.dtype).at[tok_for_slot].add(out_slots)[:t]

    # Switch-style load-balance auxiliary loss
    me = gates_all.mean(axis=0)  # (E,)
    ce = jnp.zeros((e,), jnp.float32).at[eids].add(1.0) / (t * k)
    aux = cfg.router_aux_weight * e * jnp.sum(me * ce)
    return out, aux


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def init_attention(rng, d_model, n_heads, n_kv, head_dim, dtype):
    k1, k2, k3, k4 = jax.random.split(rng, 4)
    s = d_model ** -0.5
    return {
        "wq": (jax.random.normal(k1, (d_model, n_heads * head_dim)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (d_model, n_kv * head_dim)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (d_model, n_kv * head_dim)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (n_heads * head_dim, d_model)) * s).astype(dtype),
    }


def init_dense_ffn(rng, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(rng, 3)
    s = d_model ** -0.5
    return {
        "w1": (jax.random.normal(k1, (d_model, d_ff)) * s).astype(dtype),
        "w3": (jax.random.normal(k2, (d_model, d_ff)) * s).astype(dtype),
        "w2": (jax.random.normal(k3, (d_ff, d_model)) * (d_ff ** -0.5)).astype(dtype),
    }


def init_moe_ffn(rng, d_model, moe: MoEConfig, dtype):
    k0, k1, k2, k3 = jax.random.split(rng, 4)
    e, f = moe.num_experts, moe.d_ff_expert
    s = d_model ** -0.5
    return {
        "router": (jax.random.normal(k0, (d_model, e)) * s).astype(jnp.float32),
        "w1": (jax.random.normal(k1, (e, d_model, f)) * s).astype(dtype),
        "w3": (jax.random.normal(k2, (e, d_model, f)) * s).astype(dtype),
        "w2": (jax.random.normal(k3, (e, f, d_model)) * (f ** -0.5)).astype(dtype),
    }
