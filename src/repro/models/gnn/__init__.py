from repro.models.gnn import archs, common  # noqa: F401
