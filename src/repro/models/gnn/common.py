"""Shared GNN substrate: static-shape graph batches, segment message passing
(JAX has no sparse SpMM worth using here — message passing IS
``take`` + ``segment_sum`` over an edge index, the same gather/scatter
substrate as the GraphScale engine), MLP helpers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["GraphBatch", "aggregate", "init_mlp", "mlp", "segment_softmax_xla"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GraphBatch:
    """Padded static-shape (batched) graph — a registered pytree so jit
    shardings / donation apply leaf-wise (``n_graphs`` is static metadata).

    For batched small graphs (TU/molecule), ``graph_id`` maps nodes to their
    graph; single-graph tasks use graph_id == 0. Padding nodes/edges are
    masked. ``edge_dist`` carries precomputed pairwise distances (SchNet).
    """

    node_feat: jnp.ndarray  # (N, F)
    edge_src: jnp.ndarray  # (E,) int32
    edge_dst: jnp.ndarray  # (E,) int32
    node_mask: jnp.ndarray  # (N,) bool
    edge_mask: jnp.ndarray  # (E,) bool
    graph_id: jnp.ndarray  # (N,) int32
    n_graphs: int = dataclasses.field(metadata=dict(static=True))
    edge_feat: Optional[jnp.ndarray] = None  # (E, Fe)
    edge_dist: Optional[jnp.ndarray] = None  # (E,)

    @property
    def num_nodes(self) -> int:
        return int(self.node_feat.shape[0])

    @property
    def num_edges(self) -> int:
        return int(self.edge_src.shape[0])


def aggregate(
    messages: jnp.ndarray,  # (E, D)
    dst: jnp.ndarray,  # (E,)
    num_nodes: int,
    kind: str = "sum",
    mask: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Per-destination reduce — the GraphScale accumulator in XLA form."""
    if mask is not None:
        m = mask.reshape(mask.shape + (1,) * (messages.ndim - 1))
        if kind == "max":
            messages = jnp.where(m, messages, -jnp.inf)
        else:
            messages = jnp.where(m, messages, 0)
    if kind == "sum":
        return jax.ops.segment_sum(messages, dst, num_segments=num_nodes)
    if kind == "mean":
        s = jax.ops.segment_sum(messages, dst, num_segments=num_nodes)
        c = jax.ops.segment_sum(
            (mask if mask is not None else jnp.ones_like(dst, jnp.float32)).astype(jnp.float32),
            dst, num_segments=num_nodes,
        )
        return s / jnp.maximum(c, 1.0)[:, None]
    if kind == "max":
        out = jax.ops.segment_max(messages, dst, num_segments=num_nodes)
        return jnp.where(jnp.isfinite(out), out, 0.0)
    raise ValueError(kind)


def segment_softmax_xla(scores, dst, valid, num_rows):
    from repro.kernels.segment_softmax.ref import segment_softmax_reference

    return segment_softmax_reference(scores, dst, valid, num_rows)


def init_mlp(rng, sizes, dtype=jnp.float32, layer_norm=False) -> Dict[str, Any]:
    keys = jax.random.split(rng, len(sizes) - 1)
    p: Dict[str, Any] = {
        "w": [
            (jax.random.normal(k, (a, b)) * (a ** -0.5)).astype(dtype)
            for k, a, b in zip(keys, sizes[:-1], sizes[1:])
        ],
        "b": [jnp.zeros((b,), dtype) for b in sizes[1:]],
    }
    if layer_norm:
        p["ln_scale"] = jnp.ones((sizes[-1],), dtype)
        p["ln_bias"] = jnp.zeros((sizes[-1],), dtype)
    return p


def mlp(p, x, act=jax.nn.relu, final_act=False):
    n = len(p["w"])
    for i, (w, b) in enumerate(zip(p["w"], p["b"])):
        x = x @ w + b
        if i < n - 1 or final_act:
            x = act(x)
    if "ln_scale" in p:
        mu = x.mean(-1, keepdims=True)
        var = x.var(-1, keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(var + 1e-6) * p["ln_scale"] + p["ln_bias"]
    return x
