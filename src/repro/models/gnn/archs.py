"""GNN architectures on the segment-ops substrate.

Assigned four:
  gin-tu        [arXiv:1810.00826]  5 layers, hidden 64, sum agg, learnable eps
  gat-cora      [arXiv:1710.10903]  2 layers, hidden 8 x 8 heads, attn agg
  schnet        [arXiv:1706.08566]  3 interactions, hidden 64, rbf 300, cutoff 10
  meshgraphnet  [arXiv:2010.03409]  15 layers, hidden 128, sum agg, 2-layer MLPs
Extra pool archs (beyond assignment):
  gcn           [arXiv:1609.02907]  sym-normalized SpMM conv
  sage          [arXiv:1706.02216]  GraphSAGE mean aggregator

Uniform interface: ``init(rng, cfg, in_dim, out_dim)`` / ``apply(params,
batch, cfg)`` -> (N, out_dim) node outputs; graph-level tasks pool with
``graph_readout``. Homogeneous layer stacks are scanned (static HLO size).

All message passing routes through ``aggregate`` (take + segment reduce): the
same pull-based gather/reduce the GraphScale engine distributes; the
distributed variants live in dist/gnn_parallel.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.gnn.common import GraphBatch, aggregate, init_mlp, mlp, segment_softmax_xla

__all__ = ["GNNConfig", "init", "apply", "graph_readout"]


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str  # 'gin' | 'gat' | 'schnet' | 'meshgraphnet'
    n_layers: int
    d_hidden: int
    n_heads: int = 1
    aggregator: str = "sum"
    mlp_layers: int = 2
    rbf: int = 0  # schnet radial basis size
    cutoff: float = 10.0
    eps_learnable: bool = True
    dtype: Any = jnp.float32
    scan_unroll: bool = False  # dry-run: make cost_analysis count every layer
    remat: bool = False  # checkpoint each layer (bounds the bwd carry stack)


# ---------------------------------------------------------------------------


def init(rng, cfg: GNNConfig, in_dim: int, out_dim: int) -> Dict[str, Any]:
    k_in, k_layers, k_out = jax.random.split(rng, 3)
    h = cfg.d_hidden
    if cfg.name == "gin":
        def layer(k):
            return {
                "mlp": init_mlp(k, [h, h, h], cfg.dtype),
                "eps": jnp.zeros((), cfg.dtype),
            }
    elif cfg.name == "gat":
        # layer sizes differ (concat heads) -> explicit 2 layers, no scan
        hd = h  # per-head dim
        k1, k2 = jax.random.split(k_layers)
        params = {
            "encoder": init_mlp(k_in, [in_dim, hd * cfg.n_heads], cfg.dtype),
            "l1_w": (jax.random.normal(k1, (hd * cfg.n_heads, cfg.n_heads, hd)) * (h * cfg.n_heads) ** -0.5).astype(cfg.dtype),
            "l1_asrc": jnp.zeros((cfg.n_heads, hd), cfg.dtype),
            "l1_adst": jnp.zeros((cfg.n_heads, hd), cfg.dtype),
            "l2_w": (jax.random.normal(k2, (hd * cfg.n_heads, 1, out_dim)) * (hd * cfg.n_heads) ** -0.5).astype(cfg.dtype),
            "l2_asrc": jnp.zeros((1, out_dim), cfg.dtype),
            "l2_adst": jnp.zeros((1, out_dim), cfg.dtype),
        }
        return params
    elif cfg.name == "schnet":
        def layer(k):
            ka, kb, kc = jax.random.split(k, 3)
            return {
                "filter": init_mlp(ka, [cfg.rbf, h, h], cfg.dtype),
                "in_proj": init_mlp(kb, [h, h], cfg.dtype),
                "out_mlp": init_mlp(kc, [h, h, h], cfg.dtype),
            }
    elif cfg.name == "meshgraphnet":
        def layer(k):
            ke, kn = jax.random.split(k)
            sizes = [h] * cfg.mlp_layers
            return {
                "edge_mlp": init_mlp(ke, [3 * h] + sizes, cfg.dtype, layer_norm=True),
                "node_mlp": init_mlp(kn, [2 * h] + sizes, cfg.dtype, layer_norm=True),
            }
    elif cfg.name == "gcn":
        def layer(k):
            return {"w": init_mlp(k, [h, h], cfg.dtype)}
    elif cfg.name == "sage":
        def layer(k):
            ks, kn = jax.random.split(k)
            return {
                "w_self": init_mlp(ks, [h, h], cfg.dtype),
                "w_neigh": init_mlp(kn, [h, h], cfg.dtype),
            }
    else:
        raise ValueError(cfg.name)

    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(layer)(layer_keys)
    params = {
        "encoder": init_mlp(k_in, [in_dim, h, h], cfg.dtype, layer_norm=(cfg.name == "meshgraphnet")),
        "layers": stacked,
        "decoder": init_mlp(k_out, [h, h, out_dim], cfg.dtype),
    }
    if cfg.name == "meshgraphnet":
        k_eenc = jax.random.fold_in(k_in, 1)
        params["edge_encoder"] = init_mlp(k_eenc, [1, h, h], cfg.dtype, layer_norm=True)
    return params


# ---------------------------------------------------------------------------


def _gin_apply(params, b: GraphBatch, cfg: GNNConfig):
    h = mlp(params["encoder"], b.node_feat)

    def layer(h, lp):
        msgs = jnp.take(h, b.edge_src, axis=0)
        agg = aggregate(msgs, b.edge_dst, b.num_nodes, "sum", b.edge_mask)
        h = mlp(lp["mlp"], (1.0 + lp["eps"]) * h + agg, final_act=True)
        return h, None

    if cfg.remat:
        layer = jax.checkpoint(layer, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(layer, h, params["layers"], unroll=cfg.n_layers if cfg.scan_unroll else 1)
    return mlp(params["decoder"], h)


def _gat_layer(x, w, a_src, a_dst, b: GraphBatch, final: bool):
    # x (N, Din); w (Din, H, hd); scores via additive attention per head
    xp = jnp.einsum("nd,dhf->nhf", x, w)  # (N, H, hd)
    s_src = (xp * a_src[None]).sum(-1)  # (N, H)
    s_dst = (xp * a_dst[None]).sum(-1)
    e = jax.nn.leaky_relu(
        jnp.take(s_src, b.edge_src, axis=0) + jnp.take(s_dst, b.edge_dst, axis=0),
        negative_slope=0.2,
    )  # (E, H)
    att = jax.vmap(
        lambda sc: segment_softmax_xla(sc, b.edge_dst, b.edge_mask, b.num_nodes),
        in_axes=1, out_axes=1,
    )(e)  # (E, H)
    msgs = jnp.take(xp, b.edge_src, axis=0) * att[..., None]  # (E, H, hd)
    out = aggregate(msgs.reshape(msgs.shape[0], -1), b.edge_dst, b.num_nodes, "sum", b.edge_mask)
    out = out.reshape(x.shape[0], att.shape[1], -1)  # (N, H, hd)
    if final:
        return out.mean(axis=1)  # average heads (GAT output layer)
    return jax.nn.elu(out.reshape(x.shape[0], -1))  # concat heads


def _gat_apply(params, b: GraphBatch, cfg: GNNConfig):
    x = mlp(params["encoder"], b.node_feat)
    x = _gat_layer(x, params["l1_w"], params["l1_asrc"], params["l1_adst"], b, final=False)
    return _gat_layer(x, params["l2_w"], params["l2_asrc"], params["l2_adst"], b, final=True)


def _schnet_rbf(dist, n_rbf, cutoff):
    centers = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = 10.0
    return jnp.exp(-gamma * jnp.square(dist[:, None] - centers[None, :]))


def _schnet_apply(params, b: GraphBatch, cfg: GNNConfig):
    h = mlp(params["encoder"], b.node_feat)
    dist = b.edge_dist if b.edge_dist is not None else jnp.ones_like(b.edge_src, jnp.float32)
    rbf = _schnet_rbf(dist, cfg.rbf, cfg.cutoff)  # (E, rbf)

    def layer(h, lp):
        w = mlp(lp["filter"], rbf)  # (E, h) continuous-filter weights
        src_h = mlp(lp["in_proj"], h)
        msgs = jnp.take(src_h, b.edge_src, axis=0) * w
        agg = aggregate(msgs, b.edge_dst, b.num_nodes, "sum", b.edge_mask)
        h = h + mlp(lp["out_mlp"], agg)  # residual interaction block
        return h, None

    if cfg.remat:
        layer = jax.checkpoint(layer, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(layer, h, params["layers"], unroll=cfg.n_layers if cfg.scan_unroll else 1)
    return mlp(params["decoder"], h)


def _mgn_apply(params, b: GraphBatch, cfg: GNNConfig):
    h = mlp(params["encoder"], b.node_feat)
    ef = b.edge_dist[:, None] if b.edge_dist is not None else jnp.ones((b.num_edges, 1), cfg.dtype)
    e = mlp(params["edge_encoder"], ef)

    def layer(carry, lp):
        h, e = carry
        src = jnp.take(h, b.edge_src, axis=0)
        dst = jnp.take(h, b.edge_dst, axis=0)
        e = e + mlp(lp["edge_mlp"], jnp.concatenate([e, src, dst], axis=-1))
        agg = aggregate(e, b.edge_dst, b.num_nodes, cfg.aggregator, b.edge_mask)
        h = h + mlp(lp["node_mlp"], jnp.concatenate([h, agg], axis=-1))
        return (h, e), None

    if cfg.remat:
        layer = jax.checkpoint(layer, policy=jax.checkpoint_policies.nothing_saveable)
    (h, e), _ = jax.lax.scan(layer, (h, e), params["layers"], unroll=cfg.n_layers if cfg.scan_unroll else 1)
    return mlp(params["decoder"], h)


def _gcn_apply(params, b: GraphBatch, cfg: GNNConfig):
    h = mlp(params["encoder"], b.node_feat)
    ones = b.edge_mask.astype(jnp.float32)
    deg = jax.ops.segment_sum(ones, b.edge_dst, num_segments=b.num_nodes) + 1.0
    deg_src = jax.ops.segment_sum(ones, b.edge_src, num_segments=b.num_nodes) + 1.0
    # symmetric normalization 1/sqrt(d_i d_j) with implicit self loop
    norm = jax.lax.rsqrt(jnp.take(deg_src, b.edge_src) * jnp.take(deg, b.edge_dst))

    def layer(h, lp):
        msgs = jnp.take(h, b.edge_src, axis=0) * norm[:, None]
        agg = aggregate(msgs, b.edge_dst, b.num_nodes, "sum", b.edge_mask)
        agg = agg + h * jax.lax.rsqrt(deg)[:, None]  # self loop
        return jax.nn.relu(mlp(lp["w"], agg)), None

    if cfg.remat:
        layer = jax.checkpoint(layer, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(layer, h, params["layers"], unroll=cfg.n_layers if cfg.scan_unroll else 1)
    return mlp(params["decoder"], h)


def _sage_apply(params, b: GraphBatch, cfg: GNNConfig):
    h = mlp(params["encoder"], b.node_feat)

    def layer(h, lp):
        msgs = jnp.take(h, b.edge_src, axis=0)
        agg = aggregate(msgs, b.edge_dst, b.num_nodes, "mean", b.edge_mask)
        h = jax.nn.relu(mlp(lp["w_self"], h) + mlp(lp["w_neigh"], agg))
        # L2 normalize (GraphSAGE 3.1)
        return h / jnp.maximum(jnp.linalg.norm(h, axis=-1, keepdims=True), 1e-6), None

    if cfg.remat:
        layer = jax.checkpoint(layer, policy=jax.checkpoint_policies.nothing_saveable)
    h, _ = jax.lax.scan(layer, h, params["layers"], unroll=cfg.n_layers if cfg.scan_unroll else 1)
    return mlp(params["decoder"], h)


_APPLY = {
    "gin": _gin_apply,
    "gat": _gat_apply,
    "schnet": _schnet_apply,
    "meshgraphnet": _mgn_apply,
    "gcn": _gcn_apply,
    "sage": _sage_apply,
}


def apply(params, batch: GraphBatch, cfg: GNNConfig) -> jnp.ndarray:
    out = _APPLY[cfg.name](params, batch, cfg)
    return jnp.where(batch.node_mask[:, None], out, 0.0)


def graph_readout(node_out: jnp.ndarray, batch: GraphBatch, kind: str = "sum"):
    return aggregate(node_out, batch.graph_id, batch.n_graphs, kind, batch.node_mask)
