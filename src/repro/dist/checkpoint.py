"""Atomic, checksummed, mesh-elastic checkpoints (docs/distributed.md §7).

Layout: ``<dir>/step_<08d>/`` holding one ``leaf_<05d>.npy`` per pytree leaf
(jax.tree flatten order) plus ``manifest.json`` (leaf CRC32s, step, user
meta). Writes go to ``<final>.tmp`` and are renamed into place, so a killed
writer never leaves a half checkpoint that ``latest_step`` could resume from;
restores verify every leaf's checksum and raise ``IOError`` on corruption.

Elasticity: arrays are stored as LOGICAL (unsharded) values, so a restore may
bring ANY mesh — pass ``shardings`` (a pytree of NamedShardings matching
``like``) and each leaf is device_put onto the new mesh's layout. A job
checkpointed on 4 devices continues on 8 (tests/test_elastic.py).
"""
from __future__ import annotations

import json
import os
import shutil
import zlib
from typing import Any, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "save_checkpoint",
    "restore_checkpoint",
    "latest_step",
    "list_steps",
]

_STEP_PREFIX = "step_"


def _step_dir(directory: str, step: int) -> str:
    return os.path.join(directory, f"{_STEP_PREFIX}{step:08d}")


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes())


def _json_default(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(f"not JSON-serializable: {type(o)}")


def list_steps(directory: str) -> List[int]:
    """Completed checkpoint steps, ascending (.tmp half-writes excluded)."""
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        if name.startswith(_STEP_PREFIX) and not name.endswith(".tmp"):
            try:
                steps.append(int(name[len(_STEP_PREFIX) :]))
            except ValueError:
                continue
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def save_checkpoint(
    directory: str,
    step: int,
    state: Any,
    meta: Optional[dict] = None,
    keep: Optional[int] = None,
) -> str:
    """Write ``state`` atomically as step ``step``; returns the final path.
    ``keep``: garbage-collect all but the newest ``keep`` checkpoints."""
    os.makedirs(directory, exist_ok=True)
    final = _step_dir(directory, step)
    tmp = final + ".tmp"
    shutil.rmtree(tmp, ignore_errors=True)
    os.makedirs(tmp)
    leaves = jax.tree.leaves(state)
    checksums = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(leaf)
        np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), arr)
        checksums.append(_crc(arr))
    manifest = {
        "step": int(step),
        "n_leaves": len(leaves),
        "checksums": checksums,
        "meta": meta or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, default=_json_default)
    shutil.rmtree(final, ignore_errors=True)
    os.rename(tmp, final)  # the atomic commit point
    if keep is not None:
        for old in list_steps(directory)[:-keep]:
            shutil.rmtree(_step_dir(directory, old), ignore_errors=True)
    return final


def restore_checkpoint(
    directory: str,
    like: Any,
    step: Optional[int] = None,
    shardings: Optional[Any] = None,
) -> Tuple[Any, dict]:
    """Restore the checkpoint at ``step`` (default: latest) into ``like``'s
    tree structure. ``shardings``: optional pytree of (Named)Shardings
    matching ``like`` — each leaf is device_put onto it (elastic restore onto
    a different mesh than the save used). Returns ``(state, meta)``; raises
    ``IOError`` on a checksum mismatch."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory!r}")
    path = _step_dir(directory, step)
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat, treedef = jax.tree.flatten(like)
    if manifest["n_leaves"] != len(flat):
        raise IOError(
            f"checkpoint {path} has {manifest['n_leaves']} leaves, "
            f"restore template has {len(flat)}"
        )
    sh_flat = jax.tree.leaves(shardings) if shardings is not None else None
    if sh_flat is not None and len(sh_flat) != len(flat):
        raise IOError("shardings tree does not match the restore template")
    out = []
    for i in range(len(flat)):
        arr = np.load(os.path.join(path, f"leaf_{i:05d}.npy"))
        if _crc(arr) != manifest["checksums"][i]:
            raise IOError(f"checksum mismatch on leaf {i} of {path}")
        if sh_flat is not None:
            out.append(jax.device_put(arr, sh_flat[i]))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree.unflatten(treedef, out), manifest["meta"]
