"""``repro.dist`` — the multi-device production subsystem.

The GraphScale engines (``core.engine`` / ``core.distributed``) are the graph
substrate; this package is everything around them that turns a kernel demo
into a servable system (docs/distributed.md):

  * ``sharding``        — mesh axis roles + parameter/batch/cache
                          PartitionSpec trees for the LM / GNN / RecSys
                          families (consumed by ``launch.cells``).
  * ``embedding``       — the vertex-label crossbar generalized to embedding
                          rows: capacity-bounded all_to_all request/response
                          lookup across table shards.
  * ``compression``     — int8 / top-k gradient compression with error
                          feedback for slow-axis data parallelism.
  * ``gnn_parallel``    — feature-row aggregation over the 2-D-partitioned
                          crossbar engine (GNN message passing).
  * ``gat_parallel``    — a full GAT loss lowered onto the dst-partitioned
                          layout (one payload all-gather per layer).
  * ``checkpoint``      — atomic, checksummed, mesh-elastic checkpoints.
  * ``fault_tolerance`` — checkpoint policy + retry/recovery loop + straggler
                          monitor.

Importing the package installs the jax >= 0.6 API adapters
(``repro.core.jax_compat``): ``jax.shard_map``, ``jax.make_mesh(axis_types)``,
and ``jax.sharding.AxisType`` all work on the container's jax 0.4.x.
"""
from repro.core import jax_compat

jax_compat.install()

__all__ = [
    "sharding",
    "embedding",
    "compression",
    "gnn_parallel",
    "gat_parallel",
    "checkpoint",
    "fault_tolerance",
]
