"""Gradient compression for slow-axis data parallelism (docs/distributed.md
§6): int8 linear quantization and top-k sparsification, with the error-
feedback accumulator that makes lossy sync converge (the residual every round
re-enters the next gradient, so nothing is permanently lost).

All functions are shard_map-friendly pure jax; state is a pytree mirroring
the gradients.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "int8_compress",
    "int8_decompress",
    "topk_sparsify",
    "compressed_psum",
    "make_error_feedback",
]


def int8_compress(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric linear quantization to int8: returns ``(q, scale)`` with
    ``x ~= q * scale`` and |error| <= scale / 2 (round-to-nearest)."""
    scale = jnp.max(jnp.abs(x)) / 127.0
    scale = jnp.maximum(scale, jnp.asarray(1e-20, jnp.float32)).astype(jnp.float32)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def topk_sparsify(x: jnp.ndarray, frac: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Keep the ``ceil(frac * n)`` largest-magnitude entries (ties keep
    everything at the threshold, so the mask can exceed k). Returns
    ``(sparse, mask)`` with ``sparse[mask] == x[mask]`` and zeros elsewhere."""
    flat = jnp.abs(x.reshape(-1))
    k = max(1, math.ceil(frac * flat.shape[0]))
    thresh = jnp.sort(flat)[flat.shape[0] - k]
    mask = jnp.abs(x) >= thresh
    return jnp.where(mask, x, 0), mask


def compressed_psum(x: jnp.ndarray, axis, mode: str = "int8") -> jnp.ndarray:
    """Stateless compressed all-reduce: quantize locally, mean across ``axis``.
    For converging training prefer ``make_error_feedback`` (the residual
    matters); this is the one-shot form for metrics/eval reductions."""
    if mode == "int8":
        q, s = int8_compress(x)
        x = int8_decompress(q, s)
    elif mode == "topk":
        x, _ = topk_sparsify(x, 0.1)
    else:
        raise ValueError(f"unknown compression mode {mode!r}")
    return jax.lax.pmean(x, axis)


def make_error_feedback(mode: str = "int8", frac: float = 0.1):
    """Error-feedback compressed gradient sync (EF-SGD).

    Returns ``(init, apply)``:
      * ``init(params) -> ef``   zero residuals mirroring the grads
      * ``apply(grads, ef, axis) -> (synced, ef')``  inside shard_map:
        compress ``grads + ef``, pmean the lossy payload across ``axis``,
        carry the per-device quantization residual into the next step.
    """
    if mode not in ("int8", "topk"):
        raise ValueError(f"unknown compression mode {mode!r}")

    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def compress_one(x):
        if mode == "int8":
            q, s = int8_compress(x)
            return int8_decompress(q, s)
        return topk_sparsify(x, frac)[0]

    def apply(grads, ef, axis):
        def one(g, e):
            corrected = g.astype(jnp.float32) + e
            sent = compress_one(corrected)
            return jax.lax.pmean(sent, axis), corrected - sent

        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = jax.tree.leaves(ef)
        pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        synced = jax.tree.unflatten(tdef, [p[0] for p in pairs])
        new_ef = jax.tree.unflatten(tdef, [p[1] for p in pairs])
        return synced, new_ef

    return init, apply
