"""Preemption-safe training loop: checkpoint policy + retry + straggler
monitor (docs/distributed.md §7).

``run_with_recovery`` is the production driver contract: a deterministic
``step_fn(state, i)`` (the data cursor is a pure function of ``i``, as the
synthetic pipelines guarantee) resumed from the newest checkpoint produces
EXACTLY the state an uninterrupted run would (tests/test_fault_tolerance.py
asserts this bitwise, including a full PageRank engine run).
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Dict, Optional, Tuple

from repro.dist.checkpoint import latest_step, restore_checkpoint, save_checkpoint

__all__ = ["CheckpointPolicy", "StepMonitor", "run_with_recovery"]


@dataclasses.dataclass(frozen=True)
class CheckpointPolicy:
    directory: str
    every_steps: int = 100  # save after steps i with (i+1) % every == 0
    keep: int = 3  # newest checkpoints retained
    max_retries: int = 3  # per-step retries on a raised (transient) failure
    retry_backoff_s: float = 0.0


class StepMonitor:
    """Flags straggler steps: duration > deadline_factor * running median.
    The first ``min_history`` steps are never flagged (no baseline yet)."""

    def __init__(self, deadline_factor: float = 3.0, min_history: int = 3):
        self.deadline_factor = deadline_factor
        self.min_history = min_history
        self._durations: list = []
        self._stragglers = 0

    def record(self, step: int, duration_s: float) -> bool:
        flagged = False
        if len(self._durations) >= self.min_history:
            med = statistics.median(self._durations)
            flagged = duration_s > self.deadline_factor * med
        self._durations.append(duration_s)
        self._stragglers += int(flagged)
        return flagged

    def summary(self) -> Dict[str, Any]:
        return {
            "steps": len(self._durations),
            "stragglers": self._stragglers,
            "median_s": statistics.median(self._durations) if self._durations else 0.0,
        }


def run_with_recovery(
    step_fn: Callable[[Any, int], Tuple[Any, dict]],
    init_state: Callable[[], Any],
    total_steps: int,
    policy: CheckpointPolicy,
    monitor: Optional[StepMonitor] = None,
) -> Tuple[Any, dict]:
    """Run ``step_fn`` for steps [resume_point, total_steps).

    Resume: if ``policy.directory`` holds a checkpoint, restore it (template
    from ``init_state()``) and continue from its ``next_step``. Transient
    step failures retry up to ``policy.max_retries`` times with the SAME
    (state, i) — safe because a failed step never committed its state.
    Returns ``(final_state, last_metrics)``.
    """
    last = latest_step(policy.directory)
    if last is not None:
        state, meta = restore_checkpoint(policy.directory, init_state(), step=last)
        start = int(meta.get("next_step", last))
    else:
        state = init_state()
        start = 0
    metrics: dict = {}
    for i in range(start, total_steps):
        t0 = time.perf_counter()
        for attempt in range(policy.max_retries + 1):
            try:
                state, metrics = step_fn(state, i)
                break
            except Exception:
                if attempt >= policy.max_retries:
                    raise
                if policy.retry_backoff_s:
                    time.sleep(policy.retry_backoff_s * (attempt + 1))
        if monitor is not None:
            monitor.record(i, time.perf_counter() - t0)
        if policy.every_steps and (i + 1) % policy.every_steps == 0:
            save_checkpoint(
                policy.directory,
                i + 1,
                state,
                meta={"next_step": i + 1},
                keep=policy.keep,
            )
    return state, metrics
