"""GAT on the GraphScale dst-partitioned layout (docs/distributed.md §4;
hillclimb cell C).

The dense baseline replicates full (V, H*hd) node tensors and lets GSPMD
all-reduce them everywhere. This variant lowers the SAME training math onto
the paper's layout: vertices dst-partitioned over the mesh (l = 1 — the
whole interval fits the scratch pad), ONE all-gather of the projected
payload (xp ++ per-head src attention scores) per layer, and everything
downstream — attention softmax, message aggregation, loss — is local to the
destination's device because every in-edge of a vertex lives in its core's
bucket.

Numerics match the dense single-device GAT to f32 tolerance (tested in
tests/test_distributed.py); ``wire_dtype`` optionally narrows the exchanged
payload (bf16 wires, f32 math).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import jax_compat

jax_compat.install()

from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.models.gnn.common import mlp, segment_softmax_xla  # noqa: E402

__all__ = ["make_gat_graphscale_loss"]


def _gat_layer_dist(w, a_src, a_dst, x, e_src, e_dst, e_val, axis, final, wire_dtype):
    """One distributed GAT layer on this device's (Vl, ...) shard. ``e_src``
    indexes the gathered payload (crossbar-routed gathered ids), ``e_dst``
    the local interval."""
    vl = x.shape[0]
    xp = jnp.einsum("nd,dhf->nhf", x, w)  # (Vl, H, hd)
    s_src = (xp * a_src[None]).sum(-1)  # (Vl, H)
    s_dst = (xp * a_dst[None]).sum(-1)
    h, hd = xp.shape[1], xp.shape[2]

    # the layer's ONE exchange: projected rows ++ src attention scores
    payload = jnp.concatenate([xp.reshape(vl, h * hd), s_src], axis=-1)
    if wire_dtype is not None:
        payload = payload.astype(wire_dtype)
    gathered = jax.lax.all_gather(payload, axis, axis=0, tiled=True)
    gathered = gathered.astype(x.dtype)
    xp_g = gathered[:, : h * hd].reshape(-1, h, hd)  # (V, H, hd) scratch pad
    ssrc_g = gathered[:, h * hd :]  # (V, H)

    e = jax.nn.leaky_relu(
        jnp.take(ssrc_g, e_src, axis=0) + jnp.take(s_dst, e_dst, axis=0),
        negative_slope=0.2,
    )  # (E, H)
    # every in-edge of a dst is local -> the softmax needs no second exchange
    att = jax.vmap(
        lambda sc: segment_softmax_xla(sc, e_dst, e_val, vl), in_axes=1, out_axes=1
    )(e)
    msgs = jnp.take(xp_g, e_src, axis=0) * att[..., None]  # (E, H, hd)
    flat = jnp.where(e_val[:, None], msgs.reshape(msgs.shape[0], -1), 0)
    out = jax.ops.segment_sum(
        flat, e_dst, num_segments=vl, indices_are_sorted=True
    ).reshape(vl, h, hd)
    if final:
        return out.mean(axis=1)  # average heads (GAT output layer)
    return jax.nn.elu(out.reshape(vl, -1))  # concat heads


def make_gat_graphscale_loss(
    mesh,
    axes: Sequence[str],
    vpc: int,
    n_heads: int,
    head_dim: int,
    wire_dtype: Optional[jnp.dtype] = None,
):
    """Build ``loss(params, feat, sg, dl, vm, labels, lmask) -> scalar``.

    ``params`` is ``gnn.init(..., GNNConfig(name='gat'), ...)`` (replicated);
    ``feat`` is (p, Vl, F) (``gnn_parallel.shard_features``) or (V_pad, F)
    sharded over ``axes``; ``sg``/``dl``/``vm`` are the partition's
    (p, l=1, E_pad) edge arrays; ``labels``/``lmask`` (V_pad,). The masked
    softmax cross-entropy is psum-reduced to the global mean. Differentiable
    in ``params`` (hillclimb trains through it)."""
    axes = tuple(axes)
    ax = axes if len(axes) > 1 else axes[0]

    def loss_fn(params, feat, sg, dl, vm, labels, lmask):
        feat3 = feat.ndim == 3

        def body(params, feat, sg, dl, vm, labels, lmask):
            x0 = feat[0] if feat3 else feat  # (Vl, F)
            assert x0.shape[0] == vpc, (x0.shape, vpc)
            sg_l, dl_l, vm_l = sg[0], dl[0], vm[0]  # (l, E_pad)
            assert sg_l.shape[0] == 1, "GAT layout uses l == 1 (interval fits scratch)"
            e_src, e_dst, e_val = sg_l[0], dl_l[0], vm_l[0]

            x = mlp(params["encoder"], x0)  # (Vl, H*hd)
            x = _gat_layer_dist(
                params["l1_w"], params["l1_asrc"], params["l1_adst"],
                x, e_src, e_dst, e_val, ax, final=False, wire_dtype=wire_dtype,
            )
            out = _gat_layer_dist(
                params["l2_w"], params["l2_asrc"], params["l2_adst"],
                x, e_src, e_dst, e_val, ax, final=True, wire_dtype=wire_dtype,
            )  # (Vl, OUT)

            lg = out.astype(jnp.float32)
            lse = jax.nn.logsumexp(lg, axis=-1)
            gold = jnp.take_along_axis(lg, labels[:, None], axis=-1)[:, 0]
            num = jax.lax.psum(((lse - gold) * lmask).sum(), ax)
            den = jax.lax.psum(lmask.sum(), ax)
            return num / jnp.maximum(den, 1.0)

        edge_spec = P(ax, None, None)
        feat_spec = P(ax, None, None) if feat3 else P(ax, None)
        fn = jax.shard_map(
            body,
            mesh=mesh,
            in_specs=(P(), feat_spec, edge_spec, edge_spec, edge_spec, P(ax), P(ax)),
            out_specs=P(),
            check_vma=False,
        )
        return fn(params, feat, sg, dl, vm, labels, lmask)

    return loss_fn
