"""Distributed embedding lookup = the GraphScale vertex-label crossbar with
table rows as labels (docs/distributed.md §4).

GSPMD's default lowering of ``take`` against a row-sharded table all-gathers
the FULL table to every device (measured 717 MB/step on DIN serve_bulk).
The crossbar instead moves (id, row) pairs: every device sends each of its
ids to the shard that owns the row (all_to_all #1, the request wires), each
shard gathers locally, and the rows travel back (all_to_all #2, the response
wires) — per-device wire cost ``2 * n * capacity_bound`` rows instead of the
whole table, exactly the paper's two-level exchange with a static per-link
budget.

The budget is the FPGA-honest part: request queues are static
``capacity``-deep (like the paper's crossbar FIFOs), so a pathological id
distribution that hammers one shard cannot blow up the wire cost — over-
capacity ids are DROPPED (zero rows, counted) rather than serialized.
``capacity_factor`` scales the bound relative to a uniform distribution.

Padding ids (< 0) return zero rows, matching the models' masked-embedding
convention.
"""
from __future__ import annotations

import math
from typing import Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import jax_compat

jax_compat.install()

from jax.sharding import PartitionSpec as P  # noqa: E402

__all__ = ["crossbar_lookup_local", "make_crossbar_lookup"]


def crossbar_lookup_local(
    table: jnp.ndarray,  # (rows_local, d) THIS shard's table rows
    ids: jnp.ndarray,  # (n,) int32 global row ids; -1 = padding
    axis: Union[str, Tuple[str, ...]],  # mesh axis (or axes) the table shards
    num_shards: int,
    capacity: int,  # static request-queue depth per destination shard
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One device's side of the two-level crossbar (call inside shard_map).

    Returns ``(rows (n, d), dropped)``: row i is the table row for ids[i],
    or zeros when ids[i] is padding or overflowed its shard's request queue;
    ``dropped`` is the int32 count of overflowed (real) ids.
    """
    n = ids.shape[0]
    rows_local = table.shape[0]
    valid = ids >= 0
    shard = jnp.where(valid, ids // rows_local, 0)  # owning shard
    local_row = jnp.where(valid, ids % rows_local, 0)

    # rank of each id within its destination shard's request queue
    onehot = (shard[:, None] == jnp.arange(num_shards)[None, :]) & valid[:, None]
    rank = (
        jnp.take_along_axis(jnp.cumsum(onehot, axis=0), shard[:, None], axis=1)[:, 0]
        - 1
    )
    served = valid & (rank < capacity)
    dropped = jnp.sum(valid & ~served).astype(jnp.int32)

    # request wires: (num_shards, capacity) local row ids, -1 = empty slot.
    # Unserved ids scatter out of bounds and are dropped by the scatter mode.
    req = jnp.full((num_shards, capacity), -1, jnp.int32)
    slot = jnp.where(served, rank, capacity)
    req = req.at[shard, slot].set(local_row.astype(jnp.int32), mode="drop")
    recv = jax.lax.all_to_all(req, axis, split_axis=0, concat_axis=0, tiled=True)

    # local gather + response wires
    rows = jnp.take(table, jnp.maximum(recv, 0).reshape(-1), axis=0)
    rows = rows.reshape(num_shards, capacity, -1)
    rows = jnp.where((recv >= 0)[..., None], rows, 0)
    resp = jax.lax.all_to_all(rows, axis, split_axis=0, concat_axis=0, tiled=True)

    # resp[s, k] = row for MY k-th request to shard s
    out = resp[shard, jnp.minimum(rank, capacity - 1)]
    out = jnp.where(served[:, None], out, 0)
    return out, dropped


def _as_tuple(axes) -> Tuple[str, ...]:
    return (axes,) if isinstance(axes, str) else tuple(axes)


def make_crossbar_lookup(
    mesh,
    table_axis: Union[str, Sequence[str]],
    batch_axes: Union[str, Sequence[str]],
    capacity_factor: float = 2.0,
):
    """Build ``lookup(table, ids) -> rows`` running the crossbar exchange.

    ``table_axis``: mesh axis (or axes — the 'full' two-level crossbar) the
    table rows shard over. ``batch_axes``: axes the id batch shards over.
    Axes in neither set see replicated ids and compute redundantly (free).
    ``capacity_factor``: request-queue depth as a multiple of the uniform
    per-shard load; ids landing beyond it return zero rows.

    Differentiable in ``table`` (the response all_to_all transposes back into
    the row-gradient scatter), so the same exchange serves training.
    """
    taxes = _as_tuple(table_axis)
    baxes = _as_tuple(batch_axes)
    num_shards = math.prod(int(mesh.shape[a]) for a in taxes)
    coll_axis = taxes if len(taxes) > 1 else taxes[0]
    t_entry = taxes if len(taxes) > 1 else taxes[0]
    b_entry = baxes if len(baxes) > 1 else baxes[0]

    def lookup(table, ids):
        batch_rank = ids.ndim
        d = table.shape[-1]

        def body(tbl, idl):
            flat = idl.reshape(-1)
            capacity = max(
                1, math.ceil(flat.shape[0] * capacity_factor / num_shards)
            )
            out, _ = crossbar_lookup_local(
                tbl, flat, coll_axis, num_shards, capacity
            )
            return out.reshape(idl.shape + (d,))

        fn = jax.shard_map(
            body,
            mesh=mesh,
            in_specs=(
                P(t_entry, None),
                P(b_entry, *([None] * (batch_rank - 1))),
            ),
            out_specs=P(b_entry, *([None] * batch_rank)),
            check_vma=False,
        )
        return fn(table, ids)

    return lookup
