"""GNN message passing over the 2-D-partitioned crossbar engine
(docs/distributed.md §4): the engine's gather->reduce with (Vl, D) feature
ROWS as the exchanged payload instead of scalar labels.

Payloads are multi-word per vertex, so this path keeps the flat per-phase
edge arrays (the packed scalar stream cannot carry a feature row); the
crossbar exchange and dst-partitioned segment reduce are the same contract
as ``core.distributed``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import jax_compat

jax_compat.install()

from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core.distributed import crossbar_exchange  # noqa: E402
from repro.core.partition import PartitionedGraph  # noqa: E402

__all__ = ["shard_features", "make_graphscale_aggregate"]


def shard_features(
    feat: np.ndarray, pg: PartitionedGraph, mesh, axis: str = "graph"
) -> jnp.ndarray:
    """Node features -> engine vertex order (stride permutation + padding),
    reshaped (p, Vl, D) and NamedSharding-placed over the graph axis (one
    core's interval per device)."""
    feat = np.asarray(feat)
    d = feat.shape[1]
    padded = np.zeros((pg.padded_vertices, d), feat.dtype)
    if pg.perm is not None:
        padded[pg.perm[: pg.num_vertices]] = feat[: pg.num_vertices]
    else:
        padded[: pg.num_vertices] = feat
    arr = padded.reshape(pg.p, pg.vertices_per_core, d)
    return jax.device_put(
        jnp.asarray(arr), NamedSharding(mesh, P(axis, None, None))
    )


def make_graphscale_aggregate(pg: PartitionedGraph, mesh, axis: str = "graph"):
    """Build ``agg(feat) -> (p, Vl, D)``: for every vertex v, the sum of
    feat[u] over processing edges (u -> v) — distributed feature aggregation
    through the phased crossbar (one sub-interval all-gather per phase, all
    label reads local afterwards)."""
    assert pg.p == mesh.shape[axis], (pg.p, dict(mesh.shape))
    sub, l, vpc = pg.sub_size, pg.l, pg.vertices_per_core
    sg = jnp.asarray(pg.src_gidx)
    dl = jnp.asarray(pg.dst_lidx)
    vm = jnp.asarray(pg.valid)

    def body(feat, sg, dl, vm):
        feat, sg, dl, vm = feat[0], sg[0], dl[0], vm[0]  # this device's shard

        def phase(m, acc):
            blk = jax.lax.dynamic_slice_in_dim(feat, m * sub, sub, axis=0)
            gathered = crossbar_exchange(blk, axis)  # (p*sub, D) scratch pad
            sg_m = jax.lax.dynamic_index_in_dim(sg, m, 0, keepdims=False)
            dl_m = jax.lax.dynamic_index_in_dim(dl, m, 0, keepdims=False)
            vm_m = jax.lax.dynamic_index_in_dim(vm, m, 0, keepdims=False)
            msgs = jnp.take(gathered, sg_m, axis=0)  # (E, D) label reads
            msgs = jnp.where(vm_m[:, None], msgs, 0)
            return acc + jax.ops.segment_sum(
                msgs, dl_m, num_segments=vpc, indices_are_sorted=True
            )

        acc0 = jnp.zeros((vpc, feat.shape[1]), feat.dtype)
        return jax.lax.fori_loop(0, l, phase, acc0)[None]

    espec = P(axis, None, None)

    def agg(feat):
        fn = jax.shard_map(
            body,
            mesh=mesh,
            in_specs=(P(axis, None, None), espec, espec, espec),
            out_specs=P(axis, None, None),
            check_vma=False,
        )
        return fn(feat, sg, dl, vm)

    return agg
