"""Mesh axis roles + PartitionSpec trees for every architecture family.

``rules_for_mesh`` classifies a mesh's axes into the two roles the launchers
reason about — ``fsdp`` (batch / parameter-shard axes: ``pod`` + ``data``)
and ``tp`` (the tensor-parallel ``model`` axis) — and exposes the one
primitive every spec builder uses: ``axis_if(axis, dim)``, which returns the
axis only when ``dim`` divides evenly over it (GSPMD rejects ragged shards;
an indivisible dim stays replicated rather than failing the lowering).

Spec builders return P-trees that MATCH the parameter / batch pytrees
structurally (``jax.tree.map``-zippable with eval_shape structs — what
``launch.cells`` does), built by walking the actual struct with
``tree_map_with_path`` so optional leaves (qk-norm, MoE, edge encoders) never
desynchronize the trees.

These shardings are placement choices, not numerics: any spec tree here
yields bit-identical results under GSPMD; the builders encode the measured
preferences (Megatron-style tp on head/ff/vocab dims, fsdp on d_model,
sequence-sharded KV caches).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

from repro.train.optim import AdamWState

__all__ = [
    "MeshRules",
    "rules_for_mesh",
    "lm_param_specs",
    "lm_batch_specs",
    "lm_cache_specs",
    "state_specs",
    "replicated_specs",
    "gnn_batch_specs",
    "din_param_specs",
    "din_batch_specs",
    "din_retrieval_specs",
]

Axis = Union[str, Tuple[str, ...], None]


@dataclasses.dataclass(frozen=True)
class MeshRules:
    """Axis roles of one mesh. ``fsdp``/``tp`` are P-ready (str, tuple of
    strs, or None) so callers can embed them in PartitionSpecs directly."""

    axis_sizes: Tuple[Tuple[str, int], ...]  # mesh axes in order
    fsdp: Axis  # batch + parameter-shard axes ('pod','data')
    tp: Axis  # tensor-parallel axis ('model')

    @property
    def all_axes(self) -> Tuple[str, ...]:
        return tuple(n for n, _ in self.axis_sizes)

    def size(self, axis: Axis) -> int:
        """Total device count across ``axis`` (1 for None)."""
        if axis is None:
            return 1
        names = (axis,) if isinstance(axis, str) else tuple(axis)
        sizes = dict(self.axis_sizes)
        return math.prod(sizes[n] for n in names)

    def axis_if(self, axis: Axis, dim: int) -> Axis:
        """``axis`` when ``dim`` shards evenly over it, else None (replicate).
        Tuple axes collapse to themselves; a 1-sized axis still counts (it
        divides everything), which keeps mini-mesh cell building trivial."""
        if axis is None:
            return None
        n = self.size(axis)
        return axis if n > 0 and dim % n == 0 else None


def rules_for_mesh(mesh) -> MeshRules:
    names = tuple(mesh.axis_names)
    sizes = tuple((n, int(mesh.shape[n])) for n in names)
    tp: Axis = "model" if "model" in names else None
    data_axes = tuple(n for n in names if n != "model")
    fsdp: Axis
    if len(data_axes) == 0:
        fsdp = None
    elif len(data_axes) == 1:
        fsdp = data_axes[0]
    else:
        fsdp = data_axes  # ('pod', 'data'): pod is data-parallel only
    return MeshRules(axis_sizes=sizes, fsdp=fsdp, tp=tp)


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------


def _leaf_name(path) -> str:
    for entry in reversed(path):
        key = getattr(entry, "key", None)
        if isinstance(key, str):
            return key
    return ""


def _lm_leaf_spec(r: MeshRules, name: str, shape: Tuple[int, ...]) -> P:
    """One LM parameter leaf -> spec. Layer-stacked leaves carry a leading L
    dim (always replicated); matmul weights shard tp on the 'wide' dim
    (heads / d_ff / experts / vocab) and fsdp on d_model."""
    if name == "embed":  # (V, d)
        return P(r.axis_if(r.tp, shape[0]), r.axis_if(r.fsdp, shape[1]))
    if name == "unembed":  # (d, V)
        return P(r.axis_if(r.fsdp, shape[0]), r.axis_if(r.tp, shape[1]))
    if name in ("wq", "wk", "wv"):  # (L, d, H*hd)
        return P(None, r.axis_if(r.fsdp, shape[1]), r.axis_if(r.tp, shape[2]))
    if name == "wo":  # (L, H*hd, d)
        return P(None, r.axis_if(r.tp, shape[1]), r.axis_if(r.fsdp, shape[2]))
    if name == "router":  # (L, d, E)
        return P(None, None, r.axis_if(r.tp, shape[2]))
    if name in ("w1", "w3"):
        if len(shape) == 4:  # MoE (L, E, d, f): experts over tp, d over fsdp
            return P(None, r.axis_if(r.tp, shape[1]), r.axis_if(r.fsdp, shape[2]), None)
        return P(None, r.axis_if(r.fsdp, shape[1]), r.axis_if(r.tp, shape[2]))
    if name == "w2":
        if len(shape) == 4:  # MoE (L, E, f, d)
            return P(None, r.axis_if(r.tp, shape[1]), None, r.axis_if(r.fsdp, shape[3]))
        return P(None, r.axis_if(r.tp, shape[1]), r.axis_if(r.fsdp, shape[2]))
    # norms / scales / anything small: replicate
    return P(*([None] * len(shape)))


def lm_param_specs(r: MeshRules, cfg) -> Any:
    """P-tree matching ``transformer.init_params(key, cfg)``."""
    from repro.models.transformer import init_params

    struct = jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _lm_leaf_spec(r, _leaf_name(path), tuple(leaf.shape)),
        struct,
    )


def lm_batch_specs(r: MeshRules, batch: int) -> dict:
    b = r.axis_if(r.fsdp, batch)
    return {"tokens": P(b, None), "labels": P(b, None)}


def lm_cache_specs(r: MeshRules, cfg, batch: int, max_len: int) -> dict:
    """KV cache (L, B, Hkv, S, hd): batch over fsdp, SEQUENCE over tp (the
    kv-head count rarely divides a 16-way model axis; sequence always can be
    padded to)."""
    b = r.axis_if(r.fsdp, batch)
    s = r.axis_if(r.tp, max_len)
    spec = P(None, b, None, s, None)
    return {"k": spec, "v": spec}


# ---------------------------------------------------------------------------
# generic state / replicated helpers
# ---------------------------------------------------------------------------


def state_specs(param_specs) -> dict:
    """Extend parameter specs to the full TrainState: Adam moments mirror the
    parameter layout leaf-for-leaf, the step counter is replicated."""
    return {
        "params": param_specs,
        "opt": AdamWState(step=P(), mu=param_specs, nu=param_specs),
    }


def replicated_specs(struct) -> Any:
    """Fully-replicated P-tree matching ``struct`` (GNN params are small)."""
    return jax.tree.map(lambda _: P(), struct)


# ---------------------------------------------------------------------------
# GNN family
# ---------------------------------------------------------------------------


def gnn_batch_specs(r: MeshRules, n_nodes: int, n_edges: int, n_graphs: int):
    """GraphBatch specs: nodes and edges shard over the WHOLE mesh when
    divisible (graph tensors dwarf the replicated params)."""
    from repro.models.gnn.common import GraphBatch

    an = r.axis_if(r.all_axes, n_nodes)
    ae = r.axis_if(r.all_axes, n_edges)
    return GraphBatch(
        node_feat=P(an, None),
        edge_src=P(ae),
        edge_dst=P(ae),
        node_mask=P(an),
        edge_mask=P(ae),
        graph_id=P(an),
        n_graphs=n_graphs,
        edge_feat=None,
        edge_dist=P(ae),
    )


# ---------------------------------------------------------------------------
# RecSys (DIN)
# ---------------------------------------------------------------------------


def din_param_specs(r: MeshRules, cfg) -> Any:
    """DIN params: the (huge) item table is row-sharded — over tp for the
    'take'/'crossbar' lookups, over the WHOLE mesh for 'crossbar_full' (table
    grads + Adam moments then shard everywhere, no fsdp all-reduce). The
    cate table and MLPs are small and replicate."""
    from repro.models.recsys.din import init

    struct = jax.eval_shape(lambda: init(jax.random.key(0), cfg))
    rows_axis = r.all_axes if cfg.lookup == "crossbar_full" else r.tp

    def spec(path, leaf):
        if _leaf_name(path) == "item_table":
            return P(r.axis_if(rows_axis, leaf.shape[0]), None)
        return P(*([None] * len(leaf.shape)))

    return jax.tree_util.tree_map_with_path(spec, struct)


def din_batch_specs(r: MeshRules, batch: int) -> dict:
    b = r.axis_if(r.all_axes, batch) or r.axis_if(r.fsdp, batch)
    return {
        "hist_items": P(b, None),
        "hist_cates": P(b, None),
        "target_item": P(b),
        "target_cate": P(b),
        "profile_bag": P(b, None),
        "labels": P(b),
    }


def din_retrieval_specs(r: MeshRules, n_candidates: int) -> dict:
    c = r.axis_if(r.all_axes, n_candidates)
    return {
        "hist_items": P(None, None),
        "hist_cates": P(None, None),
        "profile_bag": P(None, None),
        "cand_items": P(c),
        "cand_cates": P(c),
    }
