"""Serving observability: per-query latency percentiles, QPS, amortized
MTEPS, and delta-flush accounting.

Latency is measured per QUERY (completion wall time minus arrival at the
admission queue), so it includes queueing delay — a query that waits for its
batch to fill or for the deadline pays that wait here. Batch records carry
the engine-side view (wall per lane-batched run, iterations, resident edge
count); the first batch of a (kind, partition generation) is flagged
``cold`` — it pays trace+compile — and excluded from the steady-state stats
``bench_engine --serve-smoke`` asserts on.

Amortized MTEPS follows the PR 7 serving metric: a K-lane traversal batch
streams the whole edge set once per iteration for all its queries, so
``edges * served / wall`` is the per-query-amortized edge throughput; here
it is aggregated over steady (warm) traversal batches only.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

__all__ = [
    "BatchRecord",
    "FlushRecord",
    "ServingMetrics",
    "latency_summary",
]


def latency_summary(lat_ms) -> dict:
    """p50/p95/p99 + mean/max over a latency sample (ms). Empty-safe."""
    a = np.asarray(list(lat_ms), dtype=np.float64)
    if a.size == 0:
        return {"n": 0, "mean_ms": None, "p50_ms": None, "p95_ms": None,
                "p99_ms": None, "max_ms": None}
    return {
        "n": int(a.size),
        "mean_ms": float(a.mean()),
        "p50_ms": float(np.percentile(a, 50)),
        "p95_ms": float(np.percentile(a, 95)),
        "p99_ms": float(np.percentile(a, 99)),
        "max_ms": float(a.max()),
    }


@dataclasses.dataclass(frozen=True)
class BatchRecord:
    """One executed admission batch (or one host-answered query group)."""

    kind: str
    served: int  # real (non-padding) queries answered
    lanes: int  # batch width K (1 for host-answered kinds)
    wall_s: float
    iterations: int  # engine iterations (0 for non-traversal kinds)
    edges: int  # resident edge count at execution time
    cold: bool  # first batch of its (kind, partition generation): compile


@dataclasses.dataclass(frozen=True)
class FlushRecord:
    """One delta flush (DeltaFlushReport + wall time)."""

    edges_added: int
    wall_s: float
    buckets_retiled: int
    total_buckets: int
    repacked_fraction: float


class ServingMetrics:
    """Accumulates completions, batch records, and flush records for one
    serving run; ``summary()`` emits the BENCH_engine.json ``serving``
    record."""

    def __init__(self):
        self.latencies_ms: dict = {}  # kind -> [per-query latency ms]
        self.batches: list = []
        self.flushes: list = []
        self.rejected = 0
        self._t0: Optional[float] = None
        self._t1: Optional[float] = None

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self):
        self._t1 = time.perf_counter()

    @property
    def wall_s(self) -> float:
        if self._t0 is None:
            return 0.0
        return (self._t1 or time.perf_counter()) - self._t0

    def record_query(self, kind: str, latency_ms: float):
        self.latencies_ms.setdefault(kind, []).append(float(latency_ms))

    def record_batch(self, rec: BatchRecord):
        self.batches.append(rec)

    def record_flush(self, rec: FlushRecord):
        self.flushes.append(rec)

    def record_rejected(self, n: int = 1):
        self.rejected += n

    def steady_batches(self, kind: Optional[str] = None) -> list:
        """Warm batches (compile excluded), optionally for one kind."""
        return [
            b for b in self.batches
            if not b.cold and (kind is None or b.kind == kind)
        ]

    def summary(self) -> dict:
        all_lat = [x for v in self.latencies_ms.values() for x in v]
        served = sum(b.served for b in self.batches)
        wall = self.wall_s
        steady = self.steady_batches()
        steady_walls = [b.wall_s for b in steady]
        per_kind = {
            k: dict(
                latency=latency_summary(v),
                steady_batch_ms=(
                    float(np.median([b.wall_s for b in self.steady_batches(k)]))
                    * 1e3
                    if self.steady_batches(k) else None
                ),
            )
            for k, v in sorted(self.latencies_ms.items())
        }
        # amortized MTEPS over steady traversal batches (iterations > 0):
        # one edge-stream pass per iteration answers `served` queries at once
        trav = [b for b in steady if b.iterations > 0]
        trav_wall = sum(b.wall_s for b in trav)
        amortized_mteps = (
            sum(b.edges * b.served for b in trav) / trav_wall / 1e6
            if trav_wall > 0 else None
        )
        return {
            "queries": served,
            "rejected": self.rejected,
            "wall_s": wall,
            "qps": served / wall if wall > 0 else None,
            "latency": latency_summary(all_lat),
            "per_kind": per_kind,
            "batches": len(self.batches),
            "cold_batches": sum(1 for b in self.batches if b.cold),
            "steady_batch_ms": (
                float(np.median(steady_walls)) * 1e3 if steady_walls else None
            ),
            "amortized_mteps": amortized_mteps,
            "flushes": [dataclasses.asdict(f) for f in self.flushes],
        }
