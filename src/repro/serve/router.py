"""Mixed-op routing over ONE resident ``PartitionedGraph``.

Three traffic classes share the same resident partition (ISSUE 9 / ROADMAP
"always-on graph serving"):

  neighbors-of   host-side decode of the flat bucket layout
                 (``PartitionedGraph.in_neighbors`` — no engine run)
  distance-to    BFS / SSSP lane batches: K same-kind queries answered by one
                 warm-jit engine run (PR 7 template-problem trick — the trace
                 depends only on K; each batch's roots enter via the label
                 init), then ``dist[target, lane]`` is extracted per query.
                 PPR rides the same path, answering top-k vertices per seed.
  recommend-for  DIN retrieval scoring over a candidate pool of hub vertices,
                 with the user's history read from the SAME partition
                 (in-neighbors) and the item-table reads routed through the
                 ``dist.embedding`` crossbar exchange.

``GraphService`` owns the resident state: the COO view, the partition, the
per-kind warm-jit templates, the recommend scorer, and the delta buffer.
Ingest + flush swap in a NEW partition (``apply_edge_deltas``), bump the
generation (so the next batch per kind is marked cold — it retraces against
the new edge constants), and evict the retired partition from the engine's
identity-keyed jit cache.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import numpy as np

from repro.core.engine import EngineOptions, evict_from_cache, prepare_labels, run
from repro.core.graph import COOGraph, in_degrees
from repro.core.partition import PartitionConfig, PartitionedGraph, partition_2d
from repro.core.problems import INF_U32, bfs_multi, ppr_multi, sssp_multi
from repro.serve.delta import DeltaBuffer
from repro.serve.metrics import FlushRecord

__all__ = ["Query", "BatchResult", "RecommendScorer", "GraphService",
           "TRAVERSAL_KINDS", "KINDS"]

TRAVERSAL_KINDS = ("bfs", "sssp", "ppr")
KINDS = ("neighbors",) + TRAVERSAL_KINDS + ("recommend",)


@dataclasses.dataclass(frozen=True)
class Query:
    """One request. ``target`` is the distance-to endpoint (bfs/sssp only);
    ``qid`` is the caller's correlation id."""

    kind: str
    root: int
    target: int = 0
    qid: int = -1


@dataclasses.dataclass
class BatchResult:
    """One executed same-kind batch: ``answers[i]`` answers ``queries[i]``."""

    kind: str
    answers: list
    served: int
    lanes: int
    wall_s: float
    iterations: int
    cold: bool


class RecommendScorer:
    """recommend-for: DIN retrieval scoring over a fixed-size candidate pool.

    The pool is the ``pool_size`` highest in-degree vertices of the resident
    graph (recomputed on every flush — newly hot vertices enter the pool),
    mapped onto the DIN item/category vocab by id. The user's behavior
    history is their in-neighbor list decoded from the resident partition —
    the same array the neighbors-of path serves — so recommendations follow
    the graph through delta ingest. Shapes are static (pool size, seq_len),
    so the jitted scorer stays warm across queries AND flushes.

    ``lookup='crossbar'`` routes item-table reads through the GraphScale
    crossbar exchange (``dist.embedding.make_crossbar_lookup``) on a graph
    mesh over the local devices; ``'take'`` is the plain XLA gather.
    """

    def __init__(
        self,
        cfg=None,
        *,
        pool_size: int = 64,
        topk: int = 8,
        lookup: str = "crossbar",
        seed: int = 0,
    ):
        from repro.configs.registry import get
        from repro.models.recsys import din

        self.cfg = cfg if cfg is not None else get("din").smoke()
        self.pool_size = int(pool_size)
        self.topk = int(topk)
        self._params = din.init(jax.random.key(seed), self.cfg)
        lookup_fn = None
        if lookup == "crossbar":
            from repro.dist.embedding import make_crossbar_lookup
            from repro.launch.mesh import make_graph_mesh

            # one table shard per local device (1 on CPU CI — the exchange
            # still runs, degenerating to a local gather + all_to_all of 1)
            n_dev = len(jax.devices())
            shards = n_dev if self.cfg.item_vocab % n_dev == 0 else 1
            mesh = make_graph_mesh(shards, axis="table")
            lookup_fn = make_crossbar_lookup(mesh, "table", "table")
        elif lookup != "take":
            raise ValueError(f"lookup must be 'crossbar' or 'take', got {lookup!r}")
        self._score = jax.jit(
            lambda params, batch: din.score_candidates(
                params, batch, self.cfg, lookup_fn=lookup_fn
            )
        )
        self._pool_items = None
        self._pool_vertices = None

    def refresh_pool(self, g: COOGraph):
        """(Re)build the candidate pool from the current graph's in-degrees.
        Called at service construction and after every flush."""
        deg = in_degrees(g)
        order = np.argsort(-deg, kind="stable")[: self.pool_size]
        if order.shape[0] < self.pool_size:  # tiny graph: pad by repetition
            order = np.resize(order, self.pool_size)
        self._pool_vertices = order.astype(np.int64)
        self._pool_items = (order % self.cfg.item_vocab).astype(np.int32)

    def recommend_for(self, pg: PartitionedGraph, root: int) -> dict:
        """Score the pool for one user (= vertex ``root``); returns the topk
        pool vertices with their DIN scores."""
        if self._pool_items is None:
            raise RuntimeError("refresh_pool was never called")
        cfg = self.cfg
        L = cfg.seq_len
        hist_v = pg.in_neighbors(root)[:L]
        hist_items = np.full((1, L), -1, dtype=np.int32)
        hist_items[0, : hist_v.shape[0]] = hist_v % cfg.item_vocab
        hist_cates = np.where(hist_items >= 0, hist_items % cfg.cate_vocab, -1)
        # deterministic per-user profile bag (stand-in for profile features)
        prof = (
            (int(root) + np.arange(cfg.profile_bag_len)) % cfg.cate_vocab
        ).astype(np.int32)[None, :]
        batch = {
            "hist_items": hist_items,
            "hist_cates": hist_cates.astype(np.int32),
            "profile_bag": prof,
            "cand_items": self._pool_items,
            "cand_cates": (self._pool_items % cfg.cate_vocab).astype(np.int32),
        }
        scores = np.asarray(self._score(self._params, batch))
        top = np.argsort(-scores, kind="stable")[: self.topk]
        return {
            "vertices": self._pool_vertices[top].copy(),
            "items": self._pool_items[top].copy(),
            "scores": scores[top].copy(),
        }


class GraphService:
    """The always-on resident graph service: answers all KINDS from one
    ``PartitionedGraph``, accepts streamed edge insertions, and re-tiles
    dirty buckets on flush."""

    def __init__(
        self,
        g: COOGraph,
        partition,  # PartitionConfig (partitions here) or a built PartitionedGraph
        *,
        lanes: int = 16,
        opts: Optional[EngineOptions] = None,
        scorer: Optional[RecommendScorer] = None,
        ppr_tol: float = 1e-4,
        ppr_topk: int = 8,
        auto_flush_edges: Optional[int] = None,
    ):
        if isinstance(partition, PartitionConfig):
            pg = partition_2d(g, partition)
        elif isinstance(partition, PartitionedGraph):
            pg = partition
        else:
            raise TypeError(f"partition must be PartitionConfig or PartitionedGraph, got {type(partition)}")
        self.g = g
        self.pg = pg
        self.lanes = int(lanes)
        self.opts = opts if opts is not None else EngineOptions(lanes=lanes)
        if self.opts.lanes != self.lanes:
            raise ValueError(
                f"opts.lanes={self.opts.lanes} must match service lanes={lanes}"
            )
        self.ppr_tol = ppr_tol
        self.ppr_topk = ppr_topk
        self.generation = 0
        self.delta = DeltaBuffer(pg, auto_flush_edges=auto_flush_edges)
        self.scorer = scorer
        if self.scorer is not None:
            self.scorer.refresh_pool(g)
        # warm-jit template problems, one per traversal kind: the engine
        # trace depends only on K, so any K-rooted instance is the jit key
        zeros = [0] * self.lanes
        self._templates = {
            "bfs": bfs_multi(zeros),
            "sssp": sssp_multi(zeros),
            "ppr": ppr_multi(zeros, tol=ppr_tol),
        }
        self._makers = {
            "bfs": bfs_multi,
            "sssp": sssp_multi,
            "ppr": lambda roots: ppr_multi(roots, tol=ppr_tol),
        }
        self._warm: set = set()  # (kind, generation) pairs that already compiled

    # -- delta ingest ------------------------------------------------------
    def ingest(self, src, dst, weights=None) -> int:
        """Stage streamed edge insertions; visible to queries after flush()."""
        return self.delta.stage(src, dst, weights)

    def flush(self) -> FlushRecord:
        """Re-tile the dirty buckets, swap in the new partition, sync the COO
        view, refresh the recommend pool, and invalidate the retired
        partition's jit-cache entry (its traces baked the old edge stream,
        labels, and coverage words in as constants)."""
        src, dst, w = self.delta.pending()
        t0 = time.perf_counter()
        new_pg, report = self.delta.flush(self.pg)
        wall = time.perf_counter() - t0
        if report.edges_added:
            old_pg = self.pg
            self.pg = new_pg
            self.g = COOGraph(
                src=np.concatenate([self.g.src, src.astype(self.g.src.dtype)]),
                dst=np.concatenate([self.g.dst, dst.astype(self.g.dst.dtype)]),
                num_vertices=self.g.num_vertices,
                weights=(
                    np.concatenate([self.g.weights, w])
                    if self.g.weights is not None else None
                ),
            )
            self.generation += 1  # next batch per kind re-traces (cold)
            evict_from_cache(old_pg)
            if self.scorer is not None:
                self.scorer.refresh_pool(self.g)
        return FlushRecord(
            edges_added=report.edges_added,
            wall_s=wall,
            buckets_retiled=report.buckets_retiled,
            total_buckets=report.total_buckets,
            repacked_fraction=report.repacked_fraction,
        )

    # -- query answering ---------------------------------------------------
    def answer_batch(self, queries: list) -> BatchResult:
        """Answer one SAME-KIND batch of up to ``lanes`` queries (the request
        loop's admission coalescing guarantees both)."""
        if not queries:
            raise ValueError("empty batch")
        kind = queries[0].kind
        if any(q.kind != kind for q in queries):
            raise ValueError("mixed-kind batch; admission must coalesce by kind")
        if kind not in KINDS:
            raise ValueError(f"unknown kind {kind!r}; supported: {KINDS}")
        if kind in TRAVERSAL_KINDS and len(queries) > self.lanes:
            raise ValueError(f"batch of {len(queries)} exceeds K={self.lanes}")
        t0 = time.perf_counter()
        if kind == "neighbors":
            answers = [self.pg.in_neighbors(q.root) for q in queries]
            iters, lanes_used, cold = 0, 1, False
        elif kind == "recommend":
            if self.scorer is None:
                raise ValueError("service built without a RecommendScorer")
            key = ("recommend", self.generation)
            cold = key not in self._warm
            self._warm.add(key)
            answers = [self.scorer.recommend_for(self.pg, q.root) for q in queries]
            iters, lanes_used = 0, 1
        else:
            answers, iters, cold = self._answer_traversal(kind, queries)
            lanes_used = self.lanes
        wall = time.perf_counter() - t0
        return BatchResult(
            kind=kind, answers=answers, served=len(queries),
            lanes=lanes_used, wall_s=wall, iterations=iters, cold=cold,
        )

    def _answer_traversal(self, kind: str, queries: list):
        roots = np.asarray([q.root for q in queries], dtype=np.int64)
        served = roots.shape[0]
        if served < self.lanes:  # pad the partial batch (admission_batches rule)
            roots = np.concatenate([roots, np.repeat(roots[-1:], self.lanes - served)])
        labels = prepare_labels(self._makers[kind](roots), self.g, self.pg)
        key = (kind, self.generation)
        cold = key not in self._warm
        self._warm.add(key)
        res = run(self._templates[kind], self.g, self.pg, self.opts, labels=labels)
        if kind == "bfs":
            dist = res.labels["dist"]  # (V, K) uint32, INF_U32 = unreachable
            answers = [
                {"distance": int(dist[q.target, j]),
                 "reachable": bool(dist[q.target, j] != INF_U32)}
                for j, q in enumerate(queries)
            ]
        elif kind == "sssp":
            lab = res.labels["label"]  # (V, K) float32, +inf = unreachable
            answers = [
                {"distance": float(lab[q.target, j]),
                 "reachable": bool(np.isfinite(lab[q.target, j]))}
                for j, q in enumerate(queries)
            ]
        else:  # ppr: top-k vertices per seed lane
            lab = res.labels["label"]  # (V, K) float32 rank columns
            answers = []
            for j in range(served):
                top = np.argsort(-lab[:, j], kind="stable")[: self.ppr_topk]
                answers.append({
                    "vertices": top.astype(np.int64),
                    "scores": lab[top, j].copy(),
                })
        return answers, res.iterations, cold
