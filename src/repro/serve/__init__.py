"""repro.serve — the always-on graph service (docs/serving.md).

One resident ``PartitionedGraph`` stays hot while mixed-op queries stream in
and the graph itself mutates:

  loop.RequestLoop      bounded admission + same-kind K-lane coalescing,
                        deadline-or-full draining, per-query latency
  delta.DeltaBuffer     streamed edge insertions binned to (core, phase)
                        buckets; flush re-tiles ONLY dirty row blocks
                        (core.partition.apply_edge_deltas)
  router.GraphService   neighbors-of / distance-to / recommend-for routing
                        over the same resident partition
  metrics               p50/p95/p99 latency, QPS, amortized MTEPS
"""
from repro.serve.delta import DeltaBuffer
from repro.serve.loop import Completion, LoopConfig, RequestLoop
from repro.serve.metrics import (
    BatchRecord,
    FlushRecord,
    ServingMetrics,
    latency_summary,
)
from repro.serve.router import (
    KINDS,
    TRAVERSAL_KINDS,
    BatchResult,
    GraphService,
    Query,
    RecommendScorer,
)

__all__ = [
    "BatchRecord",
    "BatchResult",
    "Completion",
    "DeltaBuffer",
    "FlushRecord",
    "GraphService",
    "KINDS",
    "LoopConfig",
    "Query",
    "RecommendScorer",
    "RequestLoop",
    "ServingMetrics",
    "TRAVERSAL_KINDS",
    "latency_summary",
]
