"""Streaming edge-insertion staging for the resident partitioned graph.

The buffer is the serving side of ``core.partition.apply_edge_deltas``:
insertions arrive one edge (or one small batch) at a time, are binned to
their (core, phase) destination bucket immediately — the same arithmetic
``partition_2d`` uses, so the dirty-bucket set is known before the flush —
and buffered until a flush re-tiles ONLY those dirty buckets. The resident
``PartitionedGraph`` is immutable between flushes: queries racing an ingest
see a consistent snapshot, and the engine's identity-keyed jit cache stays
valid (a flush yields a NEW partition object; the retired one is evicted by
the service via ``engine.evict_from_cache``).

Binning is layout-stable across flushes: ``apply_edge_deltas`` never changes
p, l, sub_size, or the stride permutation, so the buffer's coordinates stay
valid no matter how many flushes happen while it fills.

Memmap-backed partitions (``partition_2d_streaming(..., memmap_dir=...)``,
docs/tile_layout.md §11) flush like any other: ``np.memmap`` is an ndarray
subclass, so the re-tile reads dirty bucket slices straight off disk, and the
NEW partition's arrays come out of ``apply_edge_deltas`` as plain RAM arrays
(clean-bucket data is copied, never aliased), leaving the on-disk build
artifacts untouched — safe to delete once the first flush retires them.
Covered by tests/test_streaming_partition.py.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.partition import (
    PartitionedGraph,
    apply_edge_deltas,
    bucket_coords,
)

__all__ = ["DeltaBuffer"]


class DeltaBuffer:
    """Bounded staging area for streamed edge insertions.

    ``auto_flush_edges``: when set, ``should_flush()`` turns True once that
    many edges are pending — the request loop's flush trigger. The buffer
    never flushes on its own; the owner decides when (and pairs the flush
    with jit-cache eviction and COO bookkeeping).
    """

    def __init__(
        self,
        pg: PartitionedGraph,
        *,
        auto_flush_edges: Optional[int] = None,
    ):
        if pg.config is None:
            raise ValueError(
                "partition carries no PartitionConfig; delta ingest needs "
                "partition_2d provenance"
            )
        self._pg = pg  # layout reference: p/l/sub_size/perm are flush-invariant
        self.auto_flush_edges = auto_flush_edges
        self._src: list = []
        self._dst: list = []
        self._w: list = []
        self._dirty: set = set()

    def stage(self, src, dst, weights=None) -> int:
        """Stage insertions; returns the number of edges staged. Validates
        endpoints and bins to buckets now, so bad edges fail at ingest time
        (not mid-flush) and ``dirty_buckets`` is always current."""
        src = np.atleast_1d(np.asarray(src, dtype=np.int64))
        dst = np.atleast_1d(np.asarray(dst, dtype=np.int64))
        if src.shape != dst.shape or src.ndim != 1:
            raise ValueError(f"src/dst must be equal-length 1-D: {src.shape} vs {dst.shape}")
        if (self._pg.weights is not None) != (weights is not None):
            raise ValueError(
                "delta weights must match the partition: "
                f"partition weighted={self._pg.weights is not None}, "
                f"delta weighted={weights is not None}"
            )
        if src.size == 0:
            return 0
        core, phase, _, _ = bucket_coords(self._pg, src, dst)
        self._dirty.update(zip(core.tolist(), phase.tolist()))
        self._src.append(src)
        self._dst.append(dst)
        if weights is not None:
            w = np.atleast_1d(np.asarray(weights, dtype=np.float32))
            if w.shape != src.shape:
                raise ValueError(f"weights shape {w.shape} != src shape {src.shape}")
            self._w.append(w)
        return int(src.size)

    @property
    def pending_edges(self) -> int:
        return sum(int(a.size) for a in self._src)

    @property
    def dirty_buckets(self) -> frozenset:
        """(core, phase) buckets the next flush will re-tile."""
        return frozenset(self._dirty)

    def should_flush(self) -> bool:
        return (
            self.auto_flush_edges is not None
            and self.pending_edges >= self.auto_flush_edges
        )

    def pending(self):
        """The staged (src, dst, weights-or-None) arrays, without clearing —
        the service reads these before ``flush`` to keep its COO view of the
        graph in sync with the new partition."""
        if not self._src:
            z = np.zeros(0, dtype=np.int64)
            return z, z, (np.zeros(0, np.float32) if self._w or self._pg.weights is not None else None)
        src = np.concatenate(self._src)
        dst = np.concatenate(self._dst)
        w = np.concatenate(self._w) if self._w else None
        return src, dst, w

    def flush(self, pg: PartitionedGraph):
        """Apply all pending insertions to ``pg`` (must be the resident
        partition this buffer was staged against — same layout lineage);
        returns ``(new_pg, DeltaFlushReport)`` and clears the buffer."""
        src, dst, w = self.pending()
        new_pg, report = apply_edge_deltas(pg, src, dst, w)
        self._src, self._dst, self._w = [], [], []
        self._dirty = set()
        self._pg = new_pg
        return new_pg, report
