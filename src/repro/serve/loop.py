"""The always-on request loop: bounded admission, same-kind K-lane
coalescing, deadline-or-batch-full draining.

Requests enter a bounded admission queue (reject — don't buffer unboundedly —
when the service is behind; the caller sees backpressure) and are coalesced
per kind: traversal kinds drain as soon as K same-kind queries are waiting
(one warm-jit lane batch answers all of them in a single edge-stream pass
per iteration), or when the OLDEST waiting query has aged past the deadline
(``max_wait_ms``) — a partial batch is then padded to K by repeating its
last root (``admission_batches`` rule: duplicate lanes are cheap and keep
the jit cache warm at one batch width). Host-answered kinds (neighbors,
recommend) use the same queue/deadline machinery with their own batch caps.

Delta events ride the same stream: ``ingest`` stages insertions and the loop
flushes when the buffer crosses its auto-flush threshold (or on an explicit
flush event), re-tiling only dirty buckets and swapping the resident
partition between batches — never mid-batch, so every query is answered
against one consistent snapshot.

The loop is synchronous and replay-driven (``run(events)``): real wall-clock
timestamps, deterministic order. Per-query latency = completion time minus
arrival at ``submit`` — it includes time spent waiting for the batch to fill,
which is what a caller actually experiences.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

from repro.serve.metrics import BatchRecord, FlushRecord, ServingMetrics
from repro.serve.router import GraphService, Query, TRAVERSAL_KINDS

__all__ = ["LoopConfig", "Completion", "RequestLoop"]


@dataclasses.dataclass(frozen=True)
class LoopConfig:
    queue_capacity: int = 256  # total waiting queries before rejects
    max_wait_ms: float = 20.0  # deadline: oldest waiting query age to drain
    host_batch: int = 16  # batch cap for host-answered kinds (neighbors/recommend)


@dataclasses.dataclass(frozen=True)
class Completion:
    qid: int
    kind: str
    answer: object
    latency_ms: float


class RequestLoop:
    """Drives a ``GraphService`` from a request/ingest event stream."""

    def __init__(self, service: GraphService, cfg: LoopConfig = LoopConfig()):
        self.service = service
        self.cfg = cfg
        self._queues: dict = {}  # kind -> deque[(Query, arrival_s)]
        self.metrics = ServingMetrics()

    # -- admission ---------------------------------------------------------
    @property
    def queued(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def submit(self, query: Query, now: Optional[float] = None) -> bool:
        """Admit a query; False = rejected (queue full — backpressure)."""
        if self.queued >= self.cfg.queue_capacity:
            self.metrics.record_rejected()
            return False
        self._queues.setdefault(query.kind, deque()).append(
            (query, now if now is not None else time.perf_counter())
        )
        return True

    def ingest(self, src, dst, weights=None):
        """Stage edge insertions; flush if the buffer crossed its threshold."""
        self.service.ingest(src, dst, weights)
        if self.service.delta.should_flush():
            self.flush()

    def flush(self):
        rec = self.service.flush()
        if rec.edges_added:
            self.metrics.record_flush(rec)
        return rec

    # -- draining ----------------------------------------------------------
    def _batch_width(self, kind: str) -> int:
        return self.service.lanes if kind in TRAVERSAL_KINDS else self.cfg.host_batch

    def pump(self, now: Optional[float] = None, force: bool = False) -> list:
        """Drain every due batch: full batches always; aged (or ``force``d)
        partial batches too. Returns the completions."""
        completions = []
        deadline_s = self.cfg.max_wait_ms / 1e3
        for kind in list(self._queues):
            dq = self._queues[kind]
            width = self._batch_width(kind)
            while dq:
                if len(dq) < width:
                    t = now if now is not None else time.perf_counter()
                    if not force and (t - dq[0][1]) < deadline_s:
                        break  # young partial batch: keep waiting
                entries = [dq.popleft() for _ in range(min(width, len(dq)))]
                completions.extend(self._execute(kind, entries))
        return completions

    def _execute(self, kind: str, entries: list) -> list:
        res = self.service.answer_batch([q for q, _ in entries])
        done = time.perf_counter()
        self.metrics.record_batch(BatchRecord(
            kind=kind, served=res.served, lanes=res.lanes, wall_s=res.wall_s,
            iterations=res.iterations, edges=self.service.g.num_edges,
            cold=res.cold,
        ))
        out = []
        for (q, arrival), ans in zip(entries, res.answers):
            lat_ms = (done - arrival) * 1e3
            self.metrics.record_query(kind, lat_ms)
            out.append(Completion(qid=q.qid, kind=kind, answer=ans, latency_ms=lat_ms))
        return out

    # -- replay ------------------------------------------------------------
    def run(self, events: list) -> list:
        """Replay an event stream and return all completions in completion
        order. Events:

          ("query", Query)                   submit + drain due batches
          ("delta", (src, dst[, weights]))   stage insertions (may auto-flush)
          ("flush", None)                    explicit flush

        A final forced pump drains the trailing partial batches, and a final
        flush applies any staged-but-unflushed insertions."""
        self.metrics.start()
        completions = []
        for ev, payload in events:
            if ev == "query":
                if self.submit(payload):
                    completions.extend(self.pump())
            elif ev == "delta":
                self.ingest(*payload)
            elif ev == "flush":
                self.flush()
            else:
                raise ValueError(f"unknown event {ev!r}")
        completions.extend(self.pump(force=True))
        if self.service.delta.pending_edges:
            self.flush()
        self.metrics.stop()
        return completions
