"""Synthetic data generators for every architecture family (offline container:
no external datasets; statistics matched to the assigned shapes).

All generators are deterministic in (seed, step) so a restarted trainer can
skip ahead and reproduce the exact stream — the checkpoint/restart integration
test relies on this (dist/fault_tolerance.py).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.models.gnn.common import GraphBatch

__all__ = [
    "lm_batch",
    "recsys_batch",
    "retrieval_batch",
    "graph_batch_from_coo",
    "batched_molecules",
    "random_positions_distances",
    "skewed_graph",
    "path_grid_graph",
    "query_workload",
    "mixed_query_workload",
    "edge_insertion_stream",
    "admission_batches",
]

QUERY_KINDS = ("bfs", "sssp", "ppr", "recommend", "neighbors")
DEFAULT_QUERY_MIX = {"bfs": 0.35, "sssp": 0.2, "ppr": 0.2, "recommend": 0.25}


def query_workload(
    num_queries: int,
    num_vertices: int,
    *,
    zipf_a: float = 1.2,
    hot_fraction: float = 0.1,
    seed: int = 0,
) -> np.ndarray:
    """Multi-root query stream for the lane-batched traversal path: root ids
    for ``num_queries`` point queries (BFS roots / SSSP sources / PPR seeds)
    with SKEWED root popularity — real query traffic concentrates on hub
    entities, so admission batches contain duplicate roots and the packed
    lane layout must stay correct under them (the bit-OR init regression).

    A random ``hot_fraction`` of the vertex set forms the popularity-ranked
    head; each query picks rank ``r ~ Zipf(zipf_a)`` (clamped into the head)
    with probability ~rank^-a, so a handful of hot roots dominate while the
    tail keeps full-vertex-range coverage. Deterministic in ``seed``;
    returns (num_queries,) int64.
    """
    if num_vertices < 1 or num_queries < 1:
        raise ValueError((num_queries, num_vertices))
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, num_queries, num_vertices])
    )
    head = max(1, int(num_vertices * hot_fraction))
    # popularity rank -> vertex id: a seeded permutation, so hot roots are
    # scattered over the id space (and over graph cores / phases)
    by_rank = rng.permutation(num_vertices)
    ranks = np.minimum(rng.zipf(zipf_a, size=num_queries) - 1, head - 1)
    return by_rank[ranks].astype(np.int64)


def mixed_query_workload(
    num_queries: int,
    num_vertices: int,
    *,
    mix: dict | None = None,
    zipf_a: float = 1.2,
    hot_fraction: float = 0.1,
    seed: int = 0,
) -> list:
    """Mixed-op query stream for the always-on serving loop (repro.serve):
    each query is a dict ``{"kind", "root", "target"}`` with ``kind`` drawn
    from ``mix`` (default ``DEFAULT_QUERY_MIX`` over bfs/sssp/ppr/recommend;
    weights are normalized) and zipf-skewed roots shared across kinds — hot
    entities are hot for EVERY traffic class, so same-kind admission
    coalescing sees duplicate roots inside one batch. ``target`` (the
    distance-to endpoint for bfs/sssp; ignored by other kinds) is drawn from
    the same skewed popularity head. Deterministic in ``seed``."""
    mix = dict(DEFAULT_QUERY_MIX) if mix is None else dict(mix)
    bad = sorted(set(mix) - set(QUERY_KINDS))
    if bad:
        raise ValueError(f"unknown query kinds {bad}; supported: {QUERY_KINDS}")
    total = float(sum(mix.values()))
    if total <= 0:
        raise ValueError(f"mix weights must sum > 0: {mix}")
    kinds = sorted(mix)
    probs = np.asarray([mix[k] / total for k in kinds], dtype=np.float64)
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, 11, num_queries, num_vertices])
    )
    roots = query_workload(
        num_queries, num_vertices, zipf_a=zipf_a,
        hot_fraction=hot_fraction, seed=seed,
    )
    targets = query_workload(
        num_queries, num_vertices, zipf_a=zipf_a,
        hot_fraction=hot_fraction, seed=seed + 1,
    )
    picks = rng.choice(len(kinds), size=num_queries, p=probs)
    return [
        {"kind": kinds[picks[i]], "root": int(roots[i]), "target": int(targets[i])}
        for i in range(num_queries)
    ]


def edge_insertion_stream(
    num_edges: int,
    num_vertices: int,
    *,
    num_batches: int = 1,
    hub_fraction: float = 0.05,
    hub_bias: float = 0.5,
    weighted: bool = False,
    seed: int = 0,
) -> list:
    """Streaming edge-insertion batches for delta ingest (repro.serve.delta):
    returns ``num_batches`` tuples ``(src, dst, weights-or-None)`` covering
    ``num_edges`` total insertions. Destinations are biased so ``hub_bias``
    of the edges land on a ``hub_fraction`` head of the vertex set —
    sustained ingest concentrates on few (core, phase) buckets (the dirty-
    row-block regime) and keeps growing heavy rows, eventually driving them
    over the hub-split threshold. Deterministic in ``seed``."""
    if num_edges < 0 or num_batches < 1 or num_vertices < 1:
        raise ValueError((num_edges, num_batches, num_vertices))
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, 13, num_edges, num_vertices])
    )
    head = max(1, int(num_vertices * hub_fraction))
    hubs = rng.permutation(num_vertices)[:head]
    src = rng.integers(0, num_vertices, num_edges).astype(np.int64)
    dst = rng.integers(0, num_vertices, num_edges).astype(np.int64)
    to_hub = rng.random(num_edges) < hub_bias
    dst[to_hub] = hubs[rng.integers(0, head, int(to_hub.sum()))]
    w = (rng.random(num_edges) + 0.1).astype(np.float32) if weighted else None
    bounds = np.linspace(0, num_edges, num_batches + 1).astype(np.int64)
    return [
        (src[a:b], dst[a:b], w[a:b] if w is not None else None)
        for a, b in zip(bounds[:-1], bounds[1:])
    ]


def admission_batches(roots: np.ndarray, lanes: int) -> list:
    """Chunk a query stream into K-lane admission batches for the serving
    loop; the final partial batch is padded by repeating its last root
    (duplicate lanes are cheap — same packed word — and keep the jit cache
    warm at one batch width)."""
    if lanes < 1:
        raise ValueError(f"lanes must be >= 1, got {lanes}")
    roots = np.asarray(roots)
    out = []
    for i in range(0, len(roots), lanes):
        chunk = roots[i : i + lanes]
        served = len(chunk)
        if served < lanes:
            chunk = np.concatenate(
                [chunk, np.repeat(chunk[-1:], lanes - served)]
            )
        out.append((chunk, served))
    return out


def skewed_graph(
    n: int,
    *,
    kind: str = "star",
    hub_in_degree: int | None = None,
    num_hubs: int = 1,
    avg_degree: int = 2,
    zipf_a: float = 1.6,
    seed: int = 0,
):
    """Skew-heavy COOGraph generator for the hub-row-splitting perf path.

    The engine pulls along IN-edges, so the load of a kernel row is a
    vertex's in-degree — skew is therefore injected on the DESTINATION side
    (unlike ``graph.star``, whose hub has out-degree n-1 but in-degree 0).

      kind='star':     ``num_hubs`` hub vertices (ids 0..num_hubs-1) each
                       receive ``hub_in_degree`` edges from uniform sources
                       (duplicates kept: a multigraph, so hub in-degree can
                       exceed n), plus a uniform background of n*avg_degree
                       edges. wiki-talk-like: one row dwarfs the rest.
      kind='powerlaw': in-degrees follow a Zipf(``zipf_a``) rank profile
                       capped at ``hub_in_degree`` — RMAT-like heavy tail
                       with tunable hub mass.

    Deterministic in ``seed``. Returns a ``repro.core.graph.COOGraph``.
    """
    from repro.core.graph import COOGraph

    rng = np.random.default_rng(np.random.SeedSequence([seed, n, num_hubs]))
    if hub_in_degree is None:
        hub_in_degree = n // 2
    if kind == "star":
        hub_dst = np.repeat(
            np.arange(num_hubs, dtype=np.uint32), hub_in_degree
        )
        hub_src = rng.integers(0, n, hub_dst.shape[0]).astype(np.uint32)
        bg_src = rng.integers(0, n, n * avg_degree).astype(np.uint32)
        bg_dst = rng.integers(0, n, n * avg_degree).astype(np.uint32)
        src = np.concatenate([hub_src, bg_src])
        dst = np.concatenate([hub_dst, bg_dst])
    elif kind == "powerlaw":
        ranks = np.arange(1, n + 1, dtype=np.float64)
        deg = np.minimum(
            np.maximum((hub_in_degree / ranks**zipf_a), 1.0).astype(np.int64),
            hub_in_degree,
        )
        dst = np.repeat(np.arange(n, dtype=np.uint32), deg)
        src = rng.integers(0, n, dst.shape[0]).astype(np.uint32)
    else:
        raise ValueError(f"kind must be 'star' or 'powerlaw', got {kind!r}")
    order = rng.permutation(src.shape[0])
    return COOGraph(src=src[order], dst=dst[order], num_vertices=n)


def path_grid_graph(
    width: int,
    height: int = 1,
    *,
    shuffle: bool = False,
    seed: int = 0,
):
    """High-diameter COOGraph for the frontier-aware dynamic-skip perf path.

    A ``width`` x ``height`` grid with bidirectional nearest-neighbour edges
    (``height=1`` degenerates to a simple path). BFS/SSSP from a corner takes
    ~``width + height`` iterations with a frontier that is a thin wavefront —
    the regime where per-iteration dead-tile skipping dwarfs the static
    padding-tile skip (most tiles hold only vertices far from the wave).

    ``shuffle=True`` applies a random permutation to the vertex ids. On the
    id-ordered grid the wavefront is contiguous, so it occupies few source
    sub-intervals and label-propagation problems (WCC) converge along the id
    order; shuffling scatters the frontier across tiles, exercising the
    coverage-bitmap test rather than the easy contiguous case.

    Deterministic in ``seed``. Returns a ``repro.core.graph.COOGraph``.
    """
    from repro.core.graph import COOGraph

    n = width * height
    vid = np.arange(n, dtype=np.uint32).reshape(height, width)
    right = np.stack([vid[:, :-1].ravel(), vid[:, 1:].ravel()])
    down = np.stack([vid[:-1, :].ravel(), vid[1:, :].ravel()])
    a = np.concatenate([right[0], down[0]])
    b = np.concatenate([right[1], down[1]])
    src = np.concatenate([a, b])
    dst = np.concatenate([b, a])
    if shuffle:
        perm = np.random.default_rng(
            np.random.SeedSequence([seed, width, height])
        ).permutation(n).astype(np.uint32)
        src, dst = perm[src], perm[dst]
    return COOGraph(src=src, dst=dst, num_vertices=n)


def lm_batch(seed: int, step: int, batch: int, seq: int, vocab: int) -> Dict[str, np.ndarray]:
    """Zipf-distributed token stream with next-token labels."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    toks = rng.zipf(1.3, size=(batch, seq + 1)).astype(np.int64)
    toks = np.minimum(toks, vocab - 1).astype(np.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def recsys_batch(
    seed: int, step: int, batch: int, seq_len: int, item_vocab: int, cate_vocab: int,
    profile_len: int = 32,
) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 7]))
    hist_items = rng.integers(0, item_vocab, (batch, seq_len)).astype(np.int32)
    lengths = rng.integers(5, seq_len + 1, (batch,))
    mask = np.arange(seq_len)[None, :] < lengths[:, None]
    hist_items = np.where(mask, hist_items, -1)
    hist_cates = np.where(mask, hist_items % cate_vocab, -1).astype(np.int32)
    target_item = rng.integers(0, item_vocab, (batch,)).astype(np.int32)
    profile = rng.integers(0, cate_vocab, (batch, profile_len)).astype(np.int32)
    profile[rng.random((batch, profile_len)) < 0.3] = -1
    # click label correlated with overlap of target category and history
    overlap = (hist_cates == (target_item % cate_vocab)[:, None]).sum(1)
    p = 1.0 / (1.0 + np.exp(-(overlap - 1.0)))
    labels = (rng.random(batch) < p).astype(np.float32)
    return {
        "hist_items": hist_items,
        "hist_cates": hist_cates,
        "target_item": target_item,
        "target_cate": (target_item % cate_vocab).astype(np.int32),
        "profile_bag": profile,
        "labels": labels,
    }


def retrieval_batch(
    seed: int, seq_len: int, n_candidates: int, item_vocab: int, cate_vocab: int,
    profile_len: int = 32,
) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    hist = rng.integers(0, item_vocab, (1, seq_len)).astype(np.int32)
    cand = rng.integers(0, item_vocab, (n_candidates,)).astype(np.int32)
    return {
        "hist_items": hist,
        "hist_cates": (hist % cate_vocab).astype(np.int32),
        "profile_bag": rng.integers(0, cate_vocab, (1, profile_len)).astype(np.int32),
        "cand_items": cand,
        "cand_cates": (cand % cate_vocab).astype(np.int32),
    }


def random_positions_distances(rng, src, dst, n_nodes, box: float = 10.0):
    pos = rng.random((n_nodes, 3)).astype(np.float32) * box
    d = np.linalg.norm(pos[src] - pos[dst], axis=-1).astype(np.float32)
    return pos, d


def graph_batch_from_coo(
    src: np.ndarray,
    dst: np.ndarray,
    n_nodes: int,
    d_feat: int,
    seed: int = 0,
    n_classes: int = 8,
    with_dist: bool = True,
) -> Tuple[GraphBatch, np.ndarray]:
    """Single full graph -> GraphBatch + node labels (classification)."""
    rng = np.random.default_rng(seed)
    feat = rng.standard_normal((n_nodes, d_feat)).astype(np.float32)
    labels = rng.integers(0, n_classes, (n_nodes,)).astype(np.int32)
    dist = None
    if with_dist:
        _, dist = random_positions_distances(rng, src, dst, n_nodes)
    batch = GraphBatch(
        node_feat=feat,
        edge_src=src.astype(np.int32),
        edge_dst=dst.astype(np.int32),
        node_mask=np.ones(n_nodes, bool),
        edge_mask=np.ones(len(src), bool),
        graph_id=np.zeros(n_nodes, np.int32),
        n_graphs=1,
        edge_dist=dist,
    )
    return batch, labels


def batched_molecules(
    seed: int, n_graphs: int, nodes_per: int, edges_per: int, d_feat: int,
    n_classes: int = 2,
) -> Tuple[GraphBatch, np.ndarray]:
    """TU-style batch of small graphs (molecule shape: 30 nodes / 64 edges)."""
    rng = np.random.default_rng(seed)
    n = n_graphs * nodes_per
    e = n_graphs * edges_per
    src = np.zeros(e, np.int32)
    dst = np.zeros(e, np.int32)
    for g in range(n_graphs):
        s = rng.integers(0, nodes_per, edges_per)
        d = rng.integers(0, nodes_per, edges_per)
        src[g * edges_per : (g + 1) * edges_per] = g * nodes_per + s
        dst[g * edges_per : (g + 1) * edges_per] = g * nodes_per + d
    feat = rng.standard_normal((n, d_feat)).astype(np.float32)
    _, dist = random_positions_distances(rng, src, dst, n)
    batch = GraphBatch(
        node_feat=feat,
        edge_src=src,
        edge_dst=dst,
        node_mask=np.ones(n, bool),
        edge_mask=np.ones(e, bool),
        graph_id=np.repeat(np.arange(n_graphs, dtype=np.int32), nodes_per),
        n_graphs=n_graphs,
        edge_dist=dist,
    )
    labels = rng.integers(0, n_classes, (n_graphs,)).astype(np.int32)
    return batch, labels
