"""Host-side fan-out neighbor sampler (GraphSAGE-style) for the
``minibatch_lg`` shape: seed nodes -> k-hop sampled subgraph with static
padded shapes (batch_nodes=1024, fanout 15-10).

Returns a GraphBatch whose first ``batch_nodes`` rows are the seed nodes
(loss is computed on those) plus all sampled neighbors, with edges oriented
neighbor -> seed-side (pull), matching the engine's inverse-CSR orientation.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np

from repro.core.graph import COOGraph, coo_to_csr
from repro.models.gnn.common import GraphBatch

__all__ = ["NeighborSampler"]


class NeighborSampler:
    def __init__(self, g: COOGraph, fanouts: Sequence[int], d_feat: int, seed: int = 0):
        # sample over the undirected closure's out-edges (standard SAGE)
        self.csr = coo_to_csr(g)
        self.fanouts = tuple(fanouts)
        self.d_feat = d_feat
        self.num_vertices = g.num_vertices
        self._feat_rng = np.random.default_rng(seed)

    def max_nodes(self, batch_nodes: int) -> int:
        n = batch_nodes
        total = batch_nodes
        for f in self.fanouts:
            n = n * f
            total += n
        return total

    def max_edges(self, batch_nodes: int) -> int:
        n = batch_nodes
        total = 0
        for f in self.fanouts:
            total += n * f
            n = n * f
        return total

    def sample(self, seed: int, step: int, batch_nodes: int) -> Tuple[GraphBatch, np.ndarray]:
        rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
        seeds = rng.integers(0, self.num_vertices, batch_nodes).astype(np.int64)

        max_n, max_e = self.max_nodes(batch_nodes), self.max_edges(batch_nodes)
        node_ids = np.zeros(max_n, np.int64)
        node_ids[:batch_nodes] = seeds
        n_nodes = batch_nodes
        src_l, dst_l = [], []
        frontier_lo, frontier_hi = 0, batch_nodes
        indptr, indices = self.csr.indptr, self.csr.indices
        for f in self.fanouts:
            frontier = node_ids[frontier_lo:frontier_hi]
            deg = (indptr[frontier + 1] - indptr[frontier]).astype(np.int64)
            # sample up to f neighbors per frontier node (with replacement)
            pick = rng.integers(0, np.maximum(deg, 1)[:, None], (len(frontier), f))
            nbr = indices[np.minimum(indptr[frontier][:, None] + pick, indptr[frontier + 1][:, None] - 1)]
            ok = (deg > 0)[:, None] & np.ones((1, f), bool)
            new = nbr[ok].astype(np.int64)
            dst_local = np.repeat(np.arange(frontier_lo, frontier_hi), f)[ok.ravel()]
            lo = n_nodes
            node_ids[lo : lo + len(new)] = new
            src_l.append(np.arange(lo, lo + len(new), dtype=np.int64))
            dst_l.append(dst_local)
            frontier_lo, frontier_hi = lo, lo + len(new)
            n_nodes = lo + len(new)

        src = np.concatenate(src_l) if src_l else np.zeros(0, np.int64)
        dst = np.concatenate(dst_l) if dst_l else np.zeros(0, np.int64)
        n_edges = len(src)
        edge_src = np.zeros(max_e, np.int32)
        edge_dst = np.zeros(max_e, np.int32)
        edge_src[:n_edges] = src
        edge_dst[:n_edges] = dst
        edge_mask = np.zeros(max_e, bool)
        edge_mask[:n_edges] = True
        node_mask = np.zeros(max_n, bool)
        node_mask[:n_nodes] = True
        # features hashed from global node id (deterministic, no big table)
        feat = self._features(node_ids, max_n)
        dist = rng.random(max_e).astype(np.float32) * 10.0
        labels = (node_ids[:batch_nodes] % 16).astype(np.int32)
        batch = GraphBatch(
            node_feat=feat,
            edge_src=edge_src,
            edge_dst=edge_dst,
            node_mask=node_mask,
            edge_mask=edge_mask,
            graph_id=np.zeros(max_n, np.int32),
            n_graphs=1,
            edge_dist=dist,
        )
        return batch, labels

    def _features(self, node_ids: np.ndarray, max_n: int) -> np.ndarray:
        rng = np.random.default_rng(12345)
        proj = rng.standard_normal((8, self.d_feat)).astype(np.float32)
        base = np.stack(
            [np.sin(node_ids * (k + 1) * 0.001) for k in range(8)], axis=1
        ).astype(np.float32)
        return (base @ proj)[:max_n]
