from repro.data import neighbor_sampler, synthetic  # noqa: F401
