from repro.data import neighbor_sampler, rmat, synthetic  # noqa: F401
from repro.data.rmat import RMATStream, materialize, rmat_chunks  # noqa: F401
