"""Sharded host data pipeline: deterministic, prefetched, restart-exact.

On a real multi-host pod each process feeds only its addressable shard
(``jax.make_array_from_process_local_data``); in this single-process container
the loader builds global arrays and device_puts them with the target sharding.
The cursor (seed, step) lives in checkpoint meta, so restarts replay nothing
(dist/fault_tolerance.run_with_recovery).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import numpy as np

__all__ = ["ShardedLoader", "prefetch"]


class ShardedLoader:
    """Wraps a deterministic ``make_batch(seed, step) -> dict[str, np.ndarray]``
    into a sharded device iterator."""

    def __init__(
        self,
        make_batch: Callable[[int, int], Dict[str, np.ndarray]],
        seed: int,
        shardings: Optional[Any] = None,  # tree matching the batch dict
        start_step: int = 0,
    ):
        self.make_batch = make_batch
        self.seed = seed
        self.step = start_step
        self.shardings = shardings

    def __iter__(self) -> Iterator[Any]:
        return self

    def __next__(self):
        batch = self.make_batch(self.seed, self.step)
        self.step += 1
        if self.shardings is not None:
            return jax.tree.map(
                lambda x, s: jax.device_put(x, s), batch, self.shardings
            )
        return jax.tree.map(jax.numpy.asarray, batch)

    def state(self) -> Dict[str, int]:
        """Checkpointable cursor."""
        return {"seed": self.seed, "next_step": self.step}


def prefetch(it: Iterator, depth: int = 2) -> Iterator:
    """Host-thread prefetcher: hides batch construction + device_put behind
    step compute (the CPU-side analogue of the paper's prefetch phase)."""
    q: "queue.Queue" = queue.Queue(maxsize=depth)
    stop = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        finally:
            q.put(stop)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is stop:
            return
        yield item
