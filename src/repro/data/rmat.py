"""Graph500-style streaming R-MAT generator (Kronecker a/b/c/d quadrants).

The in-memory ``repro.core.graph.rmat`` materializes the full ``(E, 2)`` edge
array — fine for unit-test scales, hopeless at graph500 scales where the edge
list alone dwarfs the packed partition it becomes. This module generates the
SAME family of graphs as a re-iterable stream of edge chunks, the ingestion
protocol ``partition_2d_streaming`` consumes: edges are produced
``chunk_edges`` at a time, each chunk seeded independently from
``(seed, chunk_index)`` so the stream replays bit-identically on every pass
(the two-pass builder's hard requirement) without any state carried between
chunks — and chunk k can be regenerated without generating chunks 0..k-1.

No global deduplication: a streaming generator cannot see across chunks, and
graph500 explicitly permits multi-edges and self-loops in the generated edge
list. All engine problems tolerate duplicates (min/or reduces are idempotent;
PageRank treats a duplicate as a parallel edge), so benchmark MTEPS rates are
computed over the generated edge count, duplicates included — exactly how
graph500 counts TEPS.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.graph import COOGraph

__all__ = ["RMATStream", "rmat_chunks", "materialize"]


@dataclasses.dataclass(frozen=True)
class RMATStream:
    """A replayable chunked R-MAT edge stream.

    Calling the stream opens one pass over its chunks (each a ``(src, dst)``
    or ``(src, dst, weights)`` tuple), so an ``RMATStream`` is itself a valid
    ``chunks`` argument for ``partition_2d_streaming``. ``num_edges`` counts
    generated (directed) edges, doubled when ``symmetric``.
    """

    scale: int
    edge_factor: int = 16
    a: float = 0.57
    b: float = 0.19
    c: float = 0.19
    seed: int = 0
    chunk_edges: int = 1 << 18
    symmetric: bool = False
    weighted: bool = False

    def __post_init__(self):
        if self.scale < 1:
            raise ValueError(f"scale must be >= 1, got {self.scale}")
        if self.edge_factor < 1:
            raise ValueError(f"edge_factor must be >= 1, got {self.edge_factor}")
        if self.chunk_edges < 1:
            raise ValueError(f"chunk_edges must be >= 1, got {self.chunk_edges}")
        if not (0.0 < self.a and 0.0 <= self.b and 0.0 <= self.c
                and self.a + self.b + self.c < 1.0):
            raise ValueError(
                f"quadrant probabilities must satisfy a > 0, b, c >= 0, "
                f"a + b + c < 1 (d is the remainder): "
                f"a={self.a}, b={self.b}, c={self.c}"
            )

    @property
    def num_vertices(self) -> int:
        return 1 << self.scale

    @property
    def base_edges(self) -> int:
        """Directed edges before symmetrization."""
        return self.num_vertices * self.edge_factor

    @property
    def num_edges(self) -> int:
        return self.base_edges * (2 if self.symmetric else 1)

    @property
    def num_chunks(self) -> int:
        return -(-self.base_edges // self.chunk_edges)

    def _chunk(self, idx: int):
        """Generate chunk ``idx`` — a pure function of (params, seed, idx)."""
        start = idx * self.chunk_edges
        m = min(self.chunk_edges, self.base_edges - start)
        # independent per-chunk entropy: replay and random access both free
        rng = np.random.default_rng([self.seed, idx])
        src = np.zeros(m, dtype=np.int64)
        dst = np.zeros(m, dtype=np.int64)
        ab, abc = self.a + self.b, self.a + self.b + self.c
        for _bit in range(self.scale):
            # quadrant probabilities: a (00), b (01), c (10), d (11)
            r = rng.random(m)
            src_bit = (r >= ab).astype(np.int64)  # c or d -> src high bit
            dst_bit = (((r >= self.a) & (r < ab)) | (r >= abc)).astype(np.int64)
            src = (src << 1) | src_bit
            dst = (dst << 1) | dst_bit
        if self.symmetric:
            src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        if self.weighted:
            w = rng.random(src.shape[0]).astype(np.float32)
            return src, dst, w
        return src, dst

    def __call__(self):
        for idx in range(self.num_chunks):
            yield self._chunk(idx)


def rmat_chunks(
    scale: int,
    edge_factor: int = 16,
    *,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
    chunk_edges: int = 1 << 18,
    symmetric: bool = False,
    weighted: bool = False,
) -> RMATStream:
    """Seeded graph500-style chunked R-MAT stream (see ``RMATStream``)."""
    return RMATStream(
        scale=scale, edge_factor=edge_factor, a=a, b=b, c=c, seed=seed,
        chunk_edges=chunk_edges, symmetric=symmetric, weighted=weighted,
    )


def materialize(stream: RMATStream) -> COOGraph:
    """Concatenate a stream's chunks into one in-RAM COOGraph — the edge list
    is IDENTICAL (same edges, same order) to what the chunks yield, so an
    in-memory ``partition_2d`` of the result is the bit-identity oracle for
    ``partition_2d_streaming(stream, ...)``. Only for scales where O(E) host
    RAM is acceptable (tests, agreement checks)."""
    chunks = list(stream())
    src = np.concatenate([ch[0] for ch in chunks])
    dst = np.concatenate([ch[1] for ch in chunks])
    w = (
        np.concatenate([ch[2] for ch in chunks]).astype(np.float32)
        if stream.weighted
        else None
    )
    return COOGraph(
        src=src.astype(np.uint32),
        dst=dst.astype(np.uint32),
        num_vertices=stream.num_vertices,
        weights=w,
    )
