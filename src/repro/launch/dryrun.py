import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct inputs (no allocation), prove memory fit, and extract the
roofline terms.

MUST be run as its own process (the XLA_FLAGS line above executes before any
jax import — 512 host devices exist only here, never in tests/benchmarks).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all 40 cells x 2 meshes
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --out results/dryrun
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs.registry import ARCHS  # noqa: E402
from repro.launch.cells import build_cell  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import collective_bytes, roofline_report  # noqa: E402


def _compile(cell, mesh):
    with mesh:
        lowered = jax.jit(
            cell.fn,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate_argnums,
        ).lower(*cell.args)
        compiled = lowered.compile()
    return compiled


def _layer_count(arch) -> int:
    return arch.model.n_layers


def _has_layer_scan(arch) -> bool:
    if arch.family == "recsys":
        return False
    if arch.family == "gnn" and arch.model.name == "gat":
        return False  # two explicit layers, no scan: costs are exact
    return True


def run_cell(arch_id: str, shape_name: str, mesh_name: str, out_dir: str,
             model_overrides=None, tag: str = "") -> dict:
    """Three compiles per cell:
      1. the REAL program (rolled scans): the deliverable compile; its
         memory_analysis() is the per-device fit proof.
      2./3. probe compiles with n_layers=1 and n_layers=2, scans unrolled:
         XLA cost_analysis counts while-loop bodies ONCE regardless of trip
         count (measured), so honest FLOP/byte/collective totals come from the
         exact linear reconstruction  total(L) = const + L * per_layer.
    Validated against a fully-unrolled compile (EXPERIMENTS.md §Dry-run)."""
    arch = ARCHS[arch_id]
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = mesh.size

    t0 = time.time()
    cell = build_cell(arch, shape_name, mesh, model_overrides=model_overrides)
    compiled = _compile(cell, mesh)
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()  # proves per-device fit
    rolled_cost = compiled.cost_analysis()

    if _has_layer_scan(arch):
        probes = {}
        for lcount in (1, 2):
            ovr = dict(model_overrides or {})
            ovr.update(n_layers=lcount, scan_unroll=True)
            pc = build_cell(arch, shape_name, mesh, model_overrides=ovr)
            pcomp = _compile(pc, mesh)
            probes[lcount] = (
                pcomp.cost_analysis(),
                collective_bytes(pcomp.as_text(), chips),
            )
        L = _layer_count(arch)

        def fit(v1, v2):
            per_layer = v2 - v1
            return max(v1 - per_layer, 0.0) + L * per_layer

        cost = {
            "flops": fit(probes[1][0].get("flops", 0.0), probes[2][0].get("flops", 0.0)),
            "bytes accessed": fit(
                probes[1][0].get("bytes accessed", 0.0),
                probes[2][0].get("bytes accessed", 0.0),
            ),
        }
        coll_total = fit(
            probes[1][1]["total_wire_bytes_per_device"],
            probes[2][1]["total_wire_bytes_per_device"],
        )
        coll = {
            "total_wire_bytes_per_device": coll_total,
            "bytes_by_kind": {
                k: fit(probes[1][1]["bytes_by_kind"][k], probes[2][1]["bytes_by_kind"][k])
                for k in probes[1][1]["bytes_by_kind"]
            },
            "count_by_kind": probes[2][1]["count_by_kind"],
            "method": "linear-reconstruction L=1,2 probes (scan bodies costed once)",
        }
    else:
        cost = rolled_cost
        coll = collective_bytes(compiled.as_text(), chips)
        coll["method"] = "exact (no layer scan)"

    terms = roofline_report(
        key=cell.key,
        mesh_name=mesh_name,
        chips=chips,
        cost=cost,
        coll=coll,
        model_flops=cell.meta.get("model_flops", 0.0),
        memory_stats=mem,
        extras={"meta": {k: v for k, v in cell.meta.items() if isinstance(v, (int, float, str))},
                "compile_s": t_compile,
                "rolled_flops_per_device": float(rolled_cost.get("flops", 0.0))},
    )
    rec = terms.to_dict()
    rec["memory_analysis"] = {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "generated_code_bytes": mem.generated_code_size_in_bytes,
    }
    rec["collectives"] = coll
    rec["status"] = "ok"
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    fname = f"{arch_id}__{shape_name}__{mesh_name}{suffix}.json".replace("/", "_")
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(rec, f, indent=1)
    print(
        f"[OK] {cell.key} mesh={mesh_name} chips={chips} "
        f"compile={t_compile:.1f}s flops/dev={terms.flops_per_device:.3e} "
        f"bytes/dev={terms.bytes_per_device:.3e} coll/dev={terms.collective_bytes_per_device:.3e} "
        f"dominant={terms.dominant} "
        f"mem/dev={(rec['memory_analysis']['argument_bytes'] + rec['memory_analysis']['temp_bytes'])/2**30:.2f}GiB",
        flush=True,
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape name (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    arch_ids = [args.arch] if args.arch else list(ARCHS)
    failures = []
    n_ok = 0
    for arch_id in arch_ids:
        arch = ARCHS[arch_id]
        shape_names = [args.shape] if args.shape else [s.name for s in arch.shapes]
        for shape_name in shape_names:
            for mesh_name in meshes:
                try:
                    run_cell(arch_id, shape_name, mesh_name, args.out)
                    n_ok += 1
                except Exception as e:  # noqa: BLE001
                    failures.append((arch_id, shape_name, mesh_name, repr(e)))
                    print(f"[FAIL] {arch_id}/{shape_name} mesh={mesh_name}: {e}", flush=True)
                    traceback.print_exc()
    print(f"\ndry-run complete: {n_ok} ok, {len(failures)} failed")
    for f in failures:
        print("  FAILED:", *f[:3], "--", f[3][:200])
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
