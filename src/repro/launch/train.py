"""Production trainer CLI: `--arch <id>` selects an assigned architecture;
reduced configs run end-to-end on CPU, full configs target the mesh.

    PYTHONPATH=src python -m repro.launch.train --arch gin-tu --steps 100
    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --reduced \
        --steps 50 --ckpt results/ckpt

Wiring: configs.registry -> train.steps builders -> dist.fault_tolerance
recovery loop (+ dist.checkpoint). Full-size multi-pod runs use the same code
path with make_production_mesh() and dist.sharding rules (see launch/cells.py
for the exact shardings the dry-run proves out).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, get
from repro.data.synthetic import batched_molecules, graph_batch_from_coo, lm_batch, recsys_batch
from repro.dist.fault_tolerance import CheckpointPolicy, StepMonitor, run_with_recovery
from repro.train.optim import AdamWConfig
from repro.train import steps as steps_mod


def _lm_runner(cfg, ocfg, steps, batch, seq):
    from repro.models.transformer import init_params

    train = jax.jit(steps_mod.make_lm_train_step(cfg, ocfg), donate_argnums=0)

    def init_state():
        return steps_mod.init_train_state(init_params(jax.random.key(0), cfg), ocfg)

    def step_fn(state, i):
        b = lm_batch(seed=0, step=i, batch=batch, seq=seq, vocab=cfg.vocab)
        return train(state, {k: jnp.asarray(v) for k, v in b.items()})

    return init_state, step_fn


def _gnn_runner(arch, cfg, ocfg, steps):
    from repro.models.gnn import archs as gnn

    task = "graph_class" if arch.gnn_task == "graph_class" else "node_class"
    out_dim = 4
    train = jax.jit(steps_mod.make_gnn_train_step(cfg, ocfg, task=task))

    def init_state():
        return steps_mod.init_train_state(
            gnn.init(jax.random.key(0), cfg, 16, out_dim), ocfg
        )

    import repro.core.graph as G

    if task == "graph_class":
        def step_fn(state, i):
            b, lab = batched_molecules(i, n_graphs=16, nodes_per=16, edges_per=32, d_feat=16)
            return train(state, b, jnp.asarray(lab % out_dim))
    else:
        g = G.symmetrize(G.rmat(10, 8, seed=0))
        b, lab = graph_batch_from_coo(
            np.asarray(g.src), np.asarray(g.dst), g.num_vertices, 16, n_classes=out_dim
        )

        def step_fn(state, i):
            return train(state, b, jnp.asarray(lab))

    return init_state, step_fn


def _din_runner(cfg, ocfg, steps, batch):
    from repro.models.recsys.din import init as din_init

    train = jax.jit(steps_mod.make_din_train_step(cfg, ocfg), donate_argnums=0)

    def init_state():
        return steps_mod.init_train_state(din_init(jax.random.key(0), cfg), ocfg)

    def step_fn(state, i):
        b = recsys_batch(0, i, batch, cfg.seq_len, cfg.item_vocab, cfg.cate_vocab,
                         cfg.profile_bag_len)
        return train(state, {k: jnp.asarray(v) for k, v in b.items()})

    return init_state, step_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--reduced", action="store_true", default=True,
                    help="use the smoke config (CPU container default)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    arch = get(args.arch)
    cfg = arch.smoke() if args.reduced else arch.model
    ocfg = AdamWConfig(lr=1e-3, total_steps=args.steps, warmup_steps=min(20, args.steps))

    if arch.family == "lm":
        init_state, step_fn = _lm_runner(cfg, ocfg, args.steps, args.batch, args.seq)
    elif arch.family == "gnn":
        init_state, step_fn = _gnn_runner(arch, cfg, ocfg, args.steps)
    else:
        init_state, step_fn = _din_runner(cfg, ocfg, args.steps, args.batch)

    monitor = StepMonitor()
    losses = []

    def wrapped(state, i):
        state, m = step_fn(state, i)
        loss = float(m["loss"])
        losses.append(loss)
        if i % 10 == 0:
            print(f"step {i:5d}  loss {loss:.4f}", flush=True)
        return state, m

    if args.ckpt:
        policy = CheckpointPolicy(
            directory=args.ckpt, every_steps=args.ckpt_every,
            install_signal_handler=True,
        )
        run_with_recovery(wrapped, init_state, args.steps, policy, monitor=monitor)
    else:
        state = init_state()
        for i in range(args.steps):
            state, _ = wrapped(state, i)
    print(f"final: loss {losses[0]:.4f} -> {losses[-1]:.4f}; {monitor.summary()}")


if __name__ == "__main__":
    main()
