"""Production mesh definitions.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod slice).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the 'pod' axis crosses
the slow inter-pod links and is used for data parallelism only (the graph
mesh's channel <-> device mapping is docs/distributed.md §1).

``make_production_mesh`` is a function (never a module-level constant) so
importing this module touches no jax device state — the dry-run must set
XLA_FLAGS before anything initializes the backend.
"""
from __future__ import annotations

import jax

from repro.core import jax_compat

jax_compat.install()  # make_mesh(axis_types=...) / AxisType on jax 0.4.x

__all__ = ["make_production_mesh", "make_graph_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_graph_mesh(num_cores: int, axis: str = "graph") -> jax.sharding.Mesh:
    """Flat mesh for the GraphScale engine (one axis of graph cores)."""
    return jax.make_mesh(
        (num_cores,), (axis,), axis_types=(jax.sharding.AxisType.Auto,)
    )


class HW:
    """TPU v5e-class roofline constants (per chip), per the assignment."""

    PEAK_FLOPS_BF16 = 197e12  # FLOP/s
    HBM_BW = 819e9  # B/s
    ICI_BW = 50e9  # B/s per chip (one ~50 GB/s link budget, conservative)
    HBM_BYTES = 16 * 1024**3
