"""Roofline term extraction from compiled dry-run artifacts.

  compute    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory     = HLO_bytes_per_device / HBM_BW
  collective = collective_wire_bytes_per_device / ICI_BW

``cost_analysis()`` is per-device for SPMD executables (verified against
hand-counted einsums). Collective bytes are parsed from the compiled HLO
text — XLA does not report them in cost_analysis — with per-kind wire-cost
factors for a ring/torus:

  all-gather      output_bytes * (n-1)/n       (each device receives n-1 shards)
  reduce-scatter  input_bytes  * (n-1)/n
  all-reduce      2 * bytes * (n-1)/n          (RS + AG)
  all-to-all      bytes * (n-1)/n
  collective-permute  bytes
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Dict, List, Optional

from repro.launch.mesh import HW

__all__ = ["collective_bytes", "roofline_report", "RooflineTerms"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# matches e.g. "f32[16,1088]{1,0}" or "bf16[2,4096]" or "(f32[8]{0}, f32[8]{0})"
_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|c64|c128)\[([\d,]*)\]")

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _replica_group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:  # iota replica groups [num_groups, group_size]
        return int(m.group(2))
    return default


def collective_bytes(hlo_text: str, num_devices: int) -> Dict[str, Any]:
    """Scan the (per-device SPMD) HLO for collective ops; return wire bytes
    per device, per kind, plus op counts."""
    per_kind_bytes: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    per_kind_count: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"(?:ROOT )?%?[\w.\-]+ = (.*?) (all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(?:-start)?\(", stripped)
        if not m:
            continue
        out_shape_txt, kind = m.group(1), m.group(2)
        if "-done" in stripped.split("(")[0]:
            continue
        n = _replica_group_size(stripped, num_devices)
        if n <= 1:
            continue
        out_bytes = _shape_bytes(out_shape_txt)
        frac = (n - 1) / n
        if kind == "all-gather":
            wire = out_bytes * frac
        elif kind == "reduce-scatter":
            wire = out_bytes * (n - 1)  # out is 1/n of input; wire = in*(n-1)/n
        elif kind == "all-reduce":
            wire = 2 * out_bytes * frac
        elif kind == "all-to-all":
            wire = out_bytes * frac
        else:  # collective-permute
            wire = out_bytes
        per_kind_bytes[kind] += wire
        per_kind_count[kind] += 1
    total = sum(per_kind_bytes.values())
    return {
        "total_wire_bytes_per_device": total,
        "bytes_by_kind": per_kind_bytes,
        "count_by_kind": per_kind_count,
    }


@dataclasses.dataclass
class RooflineTerms:
    key: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_total: float
    useful_ratio: float
    memory_per_device_bytes: Optional[float] = None
    extras: Optional[Dict] = None

    def to_dict(self):
        return dataclasses.asdict(self)


def roofline_report(
    key: str,
    mesh_name: str,
    chips: int,
    cost: Dict[str, float],
    coll: Dict[str, Any],
    model_flops: float,
    memory_stats=None,
    extras: Optional[Dict] = None,
) -> RooflineTerms:
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    cw = float(coll["total_wire_bytes_per_device"])
    compute_s = flops / HW.PEAK_FLOPS_BF16
    memory_s = byts / HW.HBM_BW
    coll_s = cw / HW.ICI_BW
    dominant = max(
        [("compute", compute_s), ("memory", memory_s), ("collective", coll_s)],
        key=lambda kv: kv[1],
    )[0]
    hlo_total = flops * chips
    mem_bytes = None
    if memory_stats is not None:
        mem_bytes = float(
            memory_stats.argument_size_in_bytes
            + memory_stats.output_size_in_bytes
            + memory_stats.temp_size_in_bytes
            - memory_stats.alias_size_in_bytes
        )
    return RooflineTerms(
        key=key,
        mesh=mesh_name,
        chips=chips,
        flops_per_device=flops,
        bytes_per_device=byts,
        collective_bytes_per_device=cw,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=coll_s,
        dominant=dominant,
        model_flops=model_flops,
        hlo_flops_total=hlo_total,
        useful_ratio=(model_flops / hlo_total) if hlo_total else 0.0,
        memory_per_device_bytes=mem_bytes,
        extras=extras,
    )
