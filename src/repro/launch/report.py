"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from dry-run records.

    PYTHONPATH=src python -m repro.launch.report > results/roofline.md
"""
from __future__ import annotations

import glob
import json
import os
from typing import List


def load(out_dir: str = "results/dryrun") -> List[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def dryrun_table(recs: List[dict]) -> str:
    lines = [
        "| cell | mesh | chips | compile s | bytes/dev (arg+tmp) GiB | fits 16G | "
        "FLOPs/dev | HLO bytes/dev | coll bytes/dev | collective mix |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["key"], r["mesh"])):
        ma = r["memory_analysis"]
        dev_gib = (ma["argument_bytes"] + ma["temp_bytes"]) / 2**30
        mix = ", ".join(
            f"{k.split('-')[-1] if False else k}:{int(v)}"
            for k, v in r["collectives"]["count_by_kind"].items()
            if v
        ) or "none"
        lines.append(
            f"| {r['key']} | {r['mesh']} | {r['chips']} | "
            f"{r['extras']['compile_s']:.1f} | {dev_gib:.2f} | "
            f"{'Y' if dev_gib <= 16 else 'NO'} | {r['flops_per_device']:.2e} | "
            f"{r['bytes_per_device']:.2e} | {r['collective_bytes_per_device']:.2e} | {mix} |"
        )
    return "\n".join(lines)


def _lever(r: dict) -> str:
    """One sentence: what would move this cell's dominant term down."""
    key, dom = r["key"], r["dominant"]
    arch = key.split("/")[0]
    shape = key.split("/")[1]
    is_lm = arch in ("qwen3-14b", "smollm-135m", "llama3-8b",
                     "granite-moe-1b-a400m", "qwen3-moe-30b-a3b")
    is_gnn = arch in ("meshgraphnet", "schnet", "gat-cora", "gin-tu",
                      "gcn-cora", "graphsage")
    if dom == "memory":
        if is_lm and shape in ("train_4k", "prefill_32k"):
            return ("flip use_pallas flash attention on TPU: the f32 "
                    "online-softmax working set (~55% of bytes) stays in VMEM")
        if is_lm:
            return "KV-cache layout/quantization (bf16->int8 cache halves reads)"
        if is_gnn:
            return "fuse gather+segment ops via the csr_gather_reduce kernel tiles"
        return "batch the per-user attention MLP into wider GEMMs"
    if dom == "collective":
        if is_lm and shape == "train_4k":
            return "remaining AR/AG is FSDP param movement: overlap with compute (latency-hiding scheduler) or int8 grads on the pod axis"
        if is_lm:
            return "shard KV heads instead of sequence where divisible"
        if is_gnn:
            return "owner-computes GraphScale layout (measured 3.7x on gat; dist/gnn_parallel + gat_parallel)"
        return "crossbar exchange instead of GSPMD table all-gather (measured 46x)"
    return "increase per-chip work (larger microbatch) to amortize"


def roofline_table(recs: List[dict], mesh: str) -> str:
    lines = [
        "| cell | compute s | memory s | collective s | dominant | MODEL_FLOPs | "
        "HLO FLOPs (total) | useful | what moves the dominant term |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: r["key"]):
        if r["mesh"] != mesh:
            continue
        dom = r["dominant"]
        lines.append(
            f"| {r['key']} | {r['compute_s']:.2e} | {r['memory_s']:.2e} | "
            f"{r['collective_s']:.2e} | **{dom}** | {r['model_flops']:.2e} | "
            f"{r['hlo_flops_total']:.2e} | {r['useful_ratio']:.3f} | {_lever(r)} |"
        )
    return "\n".join(lines)


def main():
    recs = load()
    print(f"<!-- {len(recs)} dry-run records -->\n")
    print("### Dry-run (all cells, both meshes)\n")
    print(dryrun_table(recs))
    for mesh in ("single", "multi"):
        print(f"\n### Roofline — mesh={mesh}\n")
        print(roofline_table(recs, mesh))
    # summary stats
    doms = {}
    for r in recs:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    print(f"\ndominant-term distribution: {doms}")


if __name__ == "__main__":
    main()
