import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Hillclimb cell C: gat-cora / ogb_products on the GraphScale layout.

Baseline (GSPMD auto-sharding of take/segment ops) measured: 4.97e10 FLOPs
and 6.85e9 collective bytes per device, useful_ratio 0.023 — full (V, 64)
node tensors replicated + all-reduced on all 256 chips.

This variant lowers the SAME training math on the paper's layout: vertices
dst-partitioned (p = mesh size, l = 1 since V/p fits the 2^21 scratch pad),
one all-gather of the projected payload per layer, everything else local.
Honest shapes: the edge layout comes from an actual 2-D partition of an
R-MAT graph at ogb_products scale (61.8M edges), with and without stride
mapping (the paper's balance optimization changes E_pad = the padding the
TPU actually pays).

    PYTHONPATH=src python -m repro.launch.hillclimb_gat
"""
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

import repro.core.graph as G  # noqa: E402
from repro.core.partition import PartitionConfig, partition_2d  # noqa: E402
from repro.dist.gat_parallel import make_gat_graphscale_loss  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import collective_bytes, roofline_report  # noqa: E402
from repro.models.gnn import archs as gnn  # noqa: E402
from repro.train.optim import AdamWConfig, adamw_update, init_adamw  # noqa: E402

OUT = "results/hillclimb"
F_DIM, H, HD, OUT_DIM = 100, 8, 8, 47
OCFG = AdamWConfig(lr=3e-4, total_steps=100_000, warmup_steps=2000)


def build_partition(p: int, stride):
    t0 = time.time()
    g = G.rmat(21, 29, seed=7, dedup=False)  # ~2.1M x 60.8M edges (ogb-scale)
    pg = partition_2d(g, PartitionConfig(p=p, l=1, lane=8, edge_pad=8, stride=stride))
    print(
        f"partitioned |V|={g.num_vertices} |E|={g.num_edges} p={p} stride={stride}: "
        f"E_pad={pg.edge_pad} imbalance={pg.imbalance:.2f} "
        f"padding={pg.padding_ratio:.2%} ({time.time() - t0:.0f}s)",
        flush=True,
    )
    return pg


def run_variant(mesh_name: str, pg, tag: str, wire_dtype=None):
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    axes = tuple(mesh.axis_names)
    chips = mesh.size
    assert pg.p == chips
    vpc = pg.vertices_per_core
    cfg = gnn.GNNConfig(name="gat", n_layers=2, d_hidden=HD, n_heads=H)
    params_struct = jax.eval_shape(
        lambda: gnn.init(jax.random.key(0), cfg, F_DIM, OUT_DIM)
    )
    loss_fn = make_gat_graphscale_loss(mesh, axes, vpc, H, HD, wire_dtype=wire_dtype)

    def train_step(state, feat, sg, dl, vm, labels, lmask):
        loss, grads = jax.value_and_grad(loss_fn)(
            state["params"], feat, sg, dl, vm, labels, lmask
        )
        new_p, new_opt = adamw_update(state["params"], grads, state["opt"], OCFG)
        return {"params": new_p, "opt": new_opt}, loss

    rep = lambda s: NamedSharding(mesh, P())  # noqa: E731
    sh = lambda *spec: NamedSharding(mesh, P(*spec))  # noqa: E731
    state_struct = jax.eval_shape(
        lambda: {
            "params": gnn.init(jax.random.key(0), cfg, F_DIM, OUT_DIM),
            "opt": init_adamw(
                gnn.init(jax.random.key(0), cfg, F_DIM, OUT_DIM), OCFG
            ),
        }
    )
    state_sds = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=rep(None)),
        state_struct,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )
    v_pad = pg.padded_vertices
    args = (
        state_sds,
        jax.ShapeDtypeStruct((v_pad, F_DIM), jnp.float32, sharding=sh(axes, None)),
        jax.ShapeDtypeStruct(pg.src_gidx.shape, jnp.int32, sharding=sh(axes, None, None)),
        jax.ShapeDtypeStruct(pg.dst_lidx.shape, jnp.int32, sharding=sh(axes, None, None)),
        jax.ShapeDtypeStruct(pg.valid.shape, jnp.bool_, sharding=sh(axes, None, None)),
        jax.ShapeDtypeStruct((v_pad,), jnp.int32, sharding=sh(axes)),
        jax.ShapeDtypeStruct((v_pad,), jnp.float32, sharding=sh(axes)),
    )
    t0 = time.time()
    with mesh:
        compiled = jax.jit(train_step, donate_argnums=(0,)).lower(*args).compile()
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text(), chips)
    coll["method"] = "exact (no layer scan)"
    # analytic model flops: same formula as the baseline cell
    hh = HD * H
    n, e = 2449029, 61859140
    fwd = 2 * n * F_DIM * hh + 2 * (2 * n * hh * hh + 3 * e * hh) + 2 * n * hh * OUT_DIM
    terms = roofline_report(
        key=f"gat-cora/ogb_products[{tag}]",
        mesh_name=mesh_name,
        chips=chips,
        cost=cost,
        coll=coll,
        model_flops=3.0 * fwd,
        memory_stats=mem,
        extras={
            "compile_s": t_compile,
            "edge_pad": pg.edge_pad,
            "imbalance": pg.imbalance,
            "padding_ratio": pg.padding_ratio,
        },
    )
    rec = terms.to_dict()
    rec["memory_analysis"] = {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
    }
    rec["collectives"] = coll
    os.makedirs(OUT, exist_ok=True)
    with open(f"{OUT}/gat-cora__ogb_products__{mesh_name}__{tag}.json", "w") as f:
        json.dump(rec, f, indent=1)
    print(
        f"[OK] gat/ogb[{tag}] mesh={mesh_name} compile={t_compile:.1f}s "
        f"flops/dev={terms.flops_per_device:.3e} bytes/dev={terms.bytes_per_device:.3e} "
        f"coll/dev={terms.collective_bytes_per_device:.3e} dominant={terms.dominant} "
        f"mem/dev={(mem.argument_size_in_bytes + mem.temp_size_in_bytes) / 2**30:.2f}GiB",
        flush=True,
    )
    return rec


def main():
    import sys
    only = sys.argv[1] if len(sys.argv) > 1 else None
    if only == "it3":
        import jax.numpy as jnp2
        pg = build_partition(256, stride=100)
        run_variant("single", pg, "it3_bf16_wire", wire_dtype=jnp.bfloat16)
        return
    # iteration 1: GraphScale layout, stride mapping ON (paper default)
    pg = build_partition(256, stride=100)
    run_variant("single", pg, "it1_graphscale_stride")
    # iteration 2 (ablation): stride mapping OFF -> larger E_pad (padding cost)
    pg_ns = build_partition(256, stride=None)
    run_variant("single", pg_ns, "it2_graphscale_nostride")
    # multi-pod with stride
    pg512 = build_partition(512, stride=100)
    run_variant("multi", pg512, "it1_graphscale_stride")


if __name__ == "__main__":
    main()
