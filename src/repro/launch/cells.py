"""Dry-run cell builder: (arch x shape x mesh) -> (step_fn, sharded input
structs, out shardings, donation, analytic MODEL_FLOPS).

Inputs are ``jax.ShapeDtypeStruct``s carrying NamedShardings — nothing is
allocated; ``jit(fn).lower(*args).compile()`` is the whole proof.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeCell
from repro.dist import sharding as shd
from repro.models import transformer as tfm
from repro.models.gnn import archs as gnn
from repro.models.gnn.common import GraphBatch
from repro.models.recsys import din as din_mod
from repro.train import steps as steps_mod
from repro.train.optim import AdamWConfig

__all__ = ["Cell", "build_cell", "OPT_CFG"]

OPT_CFG = AdamWConfig(lr=3e-4, total_steps=100_000, warmup_steps=2000)


@dataclasses.dataclass
class Cell:
    key: str
    fn: Callable
    args: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...]
    meta: Dict[str, Any]


def _sds(struct_tree, spec_tree, mesh):
    """Zip eval_shape structs with PartitionSpecs -> sharded SDS tree."""

    def one(s, p):
        return jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=NamedSharding(mesh, p))

    return jax.tree.map(
        one, struct_tree, spec_tree, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)
    )


def _ns(spec_tree, mesh):
    return jax.tree.map(
        lambda p: NamedSharding(mesh, p), spec_tree, is_leaf=lambda x: isinstance(x, P)
    )


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS (documented approximations, kept next to each formula)
# ---------------------------------------------------------------------------


def _lm_flops(cfg: tfm.LMConfig, kind: str, batch: int, seq: int) -> float:
    n_act = tfm.active_params(cfg)
    if kind == "train":
        t = batch * seq
        attn = 12 * cfg.n_layers * batch * seq * seq * cfg.n_heads * cfg.hd // 2
        return 6.0 * n_act * t + attn  # 6ND + causal attention term
    if kind == "prefill":
        t = batch * seq
        attn = 4 * cfg.n_layers * batch * seq * seq * cfg.n_heads * cfg.hd // 2
        return 2.0 * n_act * t + attn
    # decode: one token per sequence against a seq-length cache
    attn = 4.0 * cfg.n_layers * batch * cfg.n_heads * cfg.hd * seq
    return 2.0 * n_act * batch + attn


def _gnn_flops(arch: ArchConfig, dims: Dict[str, int], train: bool) -> float:
    cfg: gnn.GNNConfig = arch.model
    n, e, h = dims.get("n_nodes", 0), dims.get("n_edges", 0), cfg.d_hidden
    f = dims.get("d_feat", 16)
    if cfg.name in ("gin", "gcn", "sage"):
        fwd = 2 * n * (f * h + h * h) + cfg.n_layers * (e * h + 2 * n * 2 * h * h)
    elif cfg.name == "gat":
        hh = h * cfg.n_heads
        fwd = 2 * n * f * hh + 2 * (2 * n * hh * hh + 3 * e * hh) + 2 * n * hh * arch.gnn_out_dim
    elif cfg.name == "schnet":
        fwd = 2 * n * (f * h + h * h) + cfg.n_layers * (
            2 * e * (cfg.rbf * h + h * h) + 2 * n * (3 * h * h) + e * h
        )
    else:  # meshgraphnet
        fwd = 2 * n * (f * h + h * h) + cfg.n_layers * (
            2 * e * (3 * h * h + h * h) + 2 * n * (2 * h * h + h * h) + e * h
        )
    fwd += 2 * n * (h * h + h * arch.gnn_out_dim)
    return 3.0 * fwd if train else fwd


def _din_flops(cfg: din_mod.DINConfig, batch: int, n_cand: int = 0, train: bool = False) -> float:
    e = 2 * cfg.embed_dim
    a1, a2 = cfg.attn_mlp
    o1, o2 = cfg.out_mlp
    per_pair = 2 * (4 * e * a1 + a1 * a2 + a2)  # attention unit per history elem
    per_user = cfg.seq_len * per_pair + 2 * ((2 * e + cfg.embed_dim) * o1 + o1 * o2 + o2)
    units = batch if n_cand == 0 else n_cand
    return (3.0 if train else 1.0) * units * per_user


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------


def _lm_cell(arch: ArchConfig, shape: ShapeCell, mesh) -> Cell:
    r = shd.rules_for_mesh(mesh)
    d = shape.dims
    # thread mesh-specific activation constraints into the model config.
    # Activations are SEQUENCE-sharded over the model axis (Megatron-SP
    # style): the remat carry stack (L x (B,S,d)) shrinks by |tp| AND
    # attention compute parallelizes over query positions even when the head
    # count doesn't divide the axis (smollm: 9 heads vs 16-way model axis).
    b_axis = r.axis_if(r.fsdp, d["batch"])
    seq = d["seq"] if shape.kind != "decode" else 1
    s_axis = r.axis_if(r.tp, seq)
    # one dispatch group per data shard: capacity shards over fsdp instead of
    # replicating expert GEMMs on every data replica (measured 16x overcompute
    # + 29 GiB/dev OOM on the ungrouped iteration-0 baseline; EXPERIMENTS.md
    # §Perf). Groups must divide the token count (decode lowers B tokens;
    # long_500k has 1) — ungrouped cells keep the 3-D (E, C, d) constraint.
    tokens = d["batch"] * (d["seq"] if shape.kind in ("train", "prefill") else 1)
    moe_groups = (
        r.size(r.fsdp)
        if arch.model.moe is not None and tokens % r.size(r.fsdp) == 0
        else 1
    )
    if arch.model.moe is None:
        expert_sharding = None
    else:
        e_axis = r.axis_if(r.tp, arch.model.moe.num_experts)
        expert_sharding = NamedSharding(
            mesh,
            P(r.fsdp, e_axis, None, None)  # grouped: (G, E, C, d)
            if moe_groups > 1
            else P(e_axis, None, None),  # ungrouped: (E, C, d)
        )
    cfg: tfm.LMConfig = dataclasses.replace(
        arch.model,
        act_sharding=NamedSharding(mesh, P(b_axis, s_axis, None)),
        logit_sharding=NamedSharding(
            mesh, P(b_axis, None, r.axis_if(r.tp, arch.model.vocab))
        ),
        attn_sharding=NamedSharding(mesh, P(b_axis, None, s_axis, None)),
        expert_sharding=expert_sharding,
        moe_groups=moe_groups,
    )
    pspecs = shd.lm_param_specs(r, cfg)
    params_struct = jax.eval_shape(lambda: tfm.init_params(jax.random.key(0), cfg))

    if shape.kind == "train":
        sspecs = shd.state_specs(pspecs)
        state_struct = jax.eval_shape(
            lambda: steps_mod.init_train_state(
                tfm.init_params(jax.random.key(0), cfg), OPT_CFG
            )
        )
        state_sds = _sds(state_struct, sspecs, mesh)
        bspecs = shd.lm_batch_specs(r, d["batch"])
        batch_sds = {
            k: jax.ShapeDtypeStruct(
                (d["batch"], d["seq"]), jnp.int32, sharding=NamedSharding(mesh, v)
            )
            for k, v in bspecs.items()
        }
        fn = steps_mod.make_lm_train_step(cfg, OPT_CFG)
        out_sh = (_ns(sspecs, mesh), {"loss": NamedSharding(mesh, P())})
        return Cell(
            key=f"{arch.arch_id}/{shape.name}",
            fn=fn,
            args=(state_sds, batch_sds),
            out_shardings=out_sh,
            donate_argnums=(0,),
            meta=dict(
                family="lm", kind="train",
                model_flops=_lm_flops(cfg, "train", d["batch"], d["seq"]),
                tokens=d["batch"] * d["seq"],
                params=tfm.count_params(cfg), active_params=tfm.active_params(cfg),
            ),
        )

    params_sds = _sds(params_struct, pspecs, mesh)
    if shape.kind == "prefill":
        tok_sds = jax.ShapeDtypeStruct(
            (d["batch"], d["seq"]), jnp.int32,
            sharding=NamedSharding(mesh, shd.lm_batch_specs(r, d["batch"])["tokens"]),
        )
        fn = steps_mod.make_lm_prefill(cfg)
        logits_spec = P(r.axis_if(r.fsdp, d["batch"]), None, r.axis_if(r.tp, cfg.vocab))
        return Cell(
            key=f"{arch.arch_id}/{shape.name}",
            fn=fn,
            args=(params_sds, tok_sds),
            out_shardings=NamedSharding(mesh, logits_spec),
            donate_argnums=(),
            meta=dict(
                family="lm", kind="prefill",
                model_flops=_lm_flops(cfg, "prefill", d["batch"], d["seq"]),
                tokens=d["batch"] * d["seq"], params=tfm.count_params(cfg),
            ),
        )

    # decode (decode_32k / long_500k)
    cache_struct = jax.eval_shape(
        lambda: tfm.init_kv_cache(cfg, d["batch"], d["seq"])
    )
    cspecs = shd.lm_cache_specs(r, cfg, d["batch"], d["seq"])
    cache_sds = _sds(cache_struct, cspecs, mesh)
    b_axis = r.axis_if(r.fsdp, d["batch"])
    tok_sds = jax.ShapeDtypeStruct(
        (d["batch"], 1), jnp.int32, sharding=NamedSharding(mesh, P(b_axis, None))
    )
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P()))
    fn = steps_mod.make_lm_decode_step(cfg)
    out_sh = (
        NamedSharding(mesh, P(b_axis, r.axis_if(r.tp, cfg.vocab))),
        _ns(cspecs, mesh),
    )
    return Cell(
        key=f"{arch.arch_id}/{shape.name}",
        fn=fn,
        args=(params_sds, cache_sds, tok_sds, pos_sds),
        out_shardings=out_sh,
        donate_argnums=(1,),
        meta=dict(
            family="lm", kind="decode",
            model_flops=_lm_flops(cfg, "decode", d["batch"], d["seq"]),
            tokens=d["batch"], params=tfm.count_params(cfg),
        ),
    )


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------


def _gnn_batch_sds(arch: ArchConfig, d: Dict[str, int], n_graphs: int, mesh):
    r = shd.rules_for_mesh(mesh)
    n, e, f = d["n_nodes"], d["n_edges"], d["d_feat"]
    specs = shd.gnn_batch_specs(r, n, e, n_graphs)
    batch = GraphBatch(
        node_feat=jax.ShapeDtypeStruct((n, f), jnp.float32),
        edge_src=jax.ShapeDtypeStruct((e,), jnp.int32),
        edge_dst=jax.ShapeDtypeStruct((e,), jnp.int32),
        node_mask=jax.ShapeDtypeStruct((n,), jnp.bool_),
        edge_mask=jax.ShapeDtypeStruct((e,), jnp.bool_),
        graph_id=jax.ShapeDtypeStruct((n,), jnp.int32),
        n_graphs=n_graphs,
        edge_dist=jax.ShapeDtypeStruct((e,), jnp.float32),
    )
    return _sds(batch, specs, mesh), specs


def _gnn_cell(arch: ArchConfig, shape: ShapeCell, mesh) -> Cell:
    # per-layer remat is the production default: without it meshgraphnet on
    # ogb_products holds 15 layers of edge activations (18.1 GiB/dev measured)
    cfg: gnn.GNNConfig = (
        arch.model if arch.model.remat else dataclasses.replace(arch.model, remat=True)
    )
    r = shd.rules_for_mesh(mesh)
    d = dict(shape.dims)
    if shape.kind == "gnn_molecule":
        d["n_nodes"] = d["n_graphs"] * d["nodes_per"]
        d["n_edges"] = d["n_graphs"] * d["edges_per"]
        n_graphs = d["n_graphs"]
        task = "graph_class"
    else:
        n_graphs = 1
        task = arch.gnn_task
    out_dim = d.get("n_classes", arch.gnn_out_dim) if task.endswith("class") else arch.gnn_out_dim

    params_struct = jax.eval_shape(
        lambda: gnn.init(jax.random.key(0), cfg, d["d_feat"], out_dim)
    )
    pspecs = shd.replicated_specs(params_struct)
    sspecs = shd.state_specs(pspecs)
    state_struct = jax.eval_shape(
        lambda: steps_mod.init_train_state(
            gnn.init(jax.random.key(0), cfg, d["d_feat"], out_dim), OPT_CFG
        )
    )
    state_sds = _sds(state_struct, sspecs, mesh)
    batch_sds, bspecs = _gnn_batch_sds(arch, d, n_graphs, mesh)

    gaxes = r.all_axes
    if task == "graph_class":
        lab_sds = jax.ShapeDtypeStruct(
            (n_graphs,), jnp.int32,
            sharding=NamedSharding(mesh, P(r.axis_if(gaxes, n_graphs))),
        )
    elif task == "node_reg":
        lab_sds = jax.ShapeDtypeStruct(
            (d["n_nodes"], out_dim), jnp.float32,
            sharding=NamedSharding(mesh, P(r.axis_if(gaxes, d["n_nodes"]), None)),
        )
    else:
        lab_sds = jax.ShapeDtypeStruct(
            (d["n_nodes"],), jnp.int32,
            sharding=NamedSharding(mesh, P(r.axis_if(gaxes, d["n_nodes"]))),
        )

    loss_nodes = d.get("batch_nodes") if shape.kind == "gnn_minibatch" else None
    fn = steps_mod.make_gnn_train_step(cfg, OPT_CFG, task=task, loss_nodes=loss_nodes)
    out_sh = (_ns(sspecs, mesh), {"loss": NamedSharding(mesh, P())})
    return Cell(
        key=f"{arch.arch_id}/{shape.name}",
        fn=fn,
        args=(state_sds, batch_sds, lab_sds),
        out_shardings=out_sh,
        donate_argnums=(0,),
        meta=dict(
            family="gnn", kind=shape.kind, task=task,
            model_flops=_gnn_flops(arch, d, train=True),
            edges=d["n_edges"], nodes=d["n_nodes"],
        ),
    )


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------


def _din_batch_sds(cfg: din_mod.DINConfig, batch: int, mesh, with_labels: bool):
    r = shd.rules_for_mesh(mesh)
    specs = shd.din_batch_specs(r, batch)
    tree = {
        "hist_items": jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32),
        "hist_cates": jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32),
        "target_item": jax.ShapeDtypeStruct((batch,), jnp.int32),
        "target_cate": jax.ShapeDtypeStruct((batch,), jnp.int32),
        "profile_bag": jax.ShapeDtypeStruct((batch, cfg.profile_bag_len), jnp.int32),
    }
    if with_labels:
        tree["labels"] = jax.ShapeDtypeStruct((batch,), jnp.float32)
    specs = {k: specs[k] for k in tree}
    return _sds(tree, specs, mesh)


def _din_cell(arch: ArchConfig, shape: ShapeCell, mesh) -> Cell:
    cfg: din_mod.DINConfig = arch.model
    # training prefers the FULL crossbar: table grads + Adam moments shard
    # over the whole mesh, eliminating the fsdp gradient all-reduce
    # (20.6x collective reduction measured; serving keeps the tp-crossbar
    # whose per-lookup overhead is lower). §Perf it2.
    if shape.kind == "serve_train" and cfg.lookup == "crossbar":
        cfg = dataclasses.replace(cfg, lookup="crossbar_full")
    r = shd.rules_for_mesh(mesh)
    d = shape.dims
    pspecs = shd.din_param_specs(r, cfg)
    params_struct = jax.eval_shape(lambda: din_mod.init(jax.random.key(0), cfg))
    lookup_fn = None
    if cfg.lookup == "crossbar":
        from repro.dist.embedding import make_crossbar_lookup

        # ids sharded over the whole mesh; each model-axis group exchanges
        # requests/responses with its 16 table shards (docs/distributed.md §4)
        lookup_fn = make_crossbar_lookup(
            mesh, table_axis=r.tp, batch_axes=r.all_axes, capacity_factor=2.0
        )
    elif cfg.lookup == "crossbar_full":
        from repro.dist.embedding import make_crossbar_lookup

        # full two-level crossbar: unique row shard per device; table grads
        # and Adam moments are fully sharded (no fsdp all-reduce)
        lookup_fn = make_crossbar_lookup(
            mesh, table_axis=r.all_axes, batch_axes=r.all_axes, capacity_factor=2.0
        )

    if shape.kind == "serve_train":
        sspecs = shd.state_specs(pspecs)
        state_struct = jax.eval_shape(
            lambda: steps_mod.init_train_state(din_mod.init(jax.random.key(0), cfg), OPT_CFG)
        )
        state_sds = _sds(state_struct, sspecs, mesh)
        batch_sds = _din_batch_sds(cfg, d["batch"], mesh, with_labels=True)
        fn = steps_mod.make_din_train_step(cfg, OPT_CFG, lookup_fn=lookup_fn)
        return Cell(
            key=f"{arch.arch_id}/{shape.name}",
            fn=fn,
            args=(state_sds, batch_sds),
            out_shardings=(_ns(sspecs, mesh), {"loss": NamedSharding(mesh, P())}),
            donate_argnums=(0,),
            meta=dict(family="recsys", kind="train",
                      model_flops=_din_flops(cfg, d["batch"], train=True)),
        )

    params_sds = _sds(params_struct, pspecs, mesh)
    if shape.kind == "serve":
        batch_sds = _din_batch_sds(cfg, d["batch"], mesh, with_labels=False)
        fn = steps_mod.make_din_serve(cfg, lookup_fn=lookup_fn)
        b = r.axis_if(r.all_axes, d["batch"]) or r.axis_if(r.fsdp, d["batch"])
        return Cell(
            key=f"{arch.arch_id}/{shape.name}",
            fn=fn,
            args=(params_sds, batch_sds),
            out_shardings=NamedSharding(mesh, P(b)),
            donate_argnums=(),
            meta=dict(family="recsys", kind="serve",
                      model_flops=_din_flops(cfg, d["batch"])),
        )

    # retrieval: one user, n_candidates items (vectorized, no chunk loop)
    rspecs = shd.din_retrieval_specs(r, d["n_candidates"])
    tree = {
        "hist_items": jax.ShapeDtypeStruct((1, cfg.seq_len), jnp.int32),
        "hist_cates": jax.ShapeDtypeStruct((1, cfg.seq_len), jnp.int32),
        "profile_bag": jax.ShapeDtypeStruct((1, cfg.profile_bag_len), jnp.int32),
        "cand_items": jax.ShapeDtypeStruct((d["n_candidates"],), jnp.int32),
        "cand_cates": jax.ShapeDtypeStruct((d["n_candidates"],), jnp.int32),
    }
    batch_sds = _sds(tree, {k: rspecs[k] for k in tree}, mesh)
    fn = steps_mod.make_din_retrieval(cfg, chunk=None)
    c = r.axis_if(r.all_axes, d["n_candidates"])
    return Cell(
        key=f"{arch.arch_id}/{shape.name}",
        fn=fn,
        args=(params_sds, batch_sds),
        out_shardings=NamedSharding(mesh, P(c)),
        donate_argnums=(),
        meta=dict(family="recsys", kind="retrieval",
                  model_flops=_din_flops(cfg, 1, n_cand=d["n_candidates"])),
    )


def build_cell(
    arch: ArchConfig,
    shape_name: str,
    mesh,
    model_overrides: Optional[Dict[str, Any]] = None,
) -> Cell:
    shape = arch.shape(shape_name)
    if model_overrides:
        arch = dataclasses.replace(
            arch, model=dataclasses.replace(arch.model, **model_overrides)
        )
    if arch.family == "lm":
        return _lm_cell(arch, shape, mesh)
    if arch.family == "gnn":
        return _gnn_cell(arch, shape, mesh)
    return _din_cell(arch, shape, mesh)
