"""Serving CLI: batched decode for LM archs, pointwise/retrieval scoring for
DIN, and lane-batched graph query serving — reduced configs on CPU;
production shapes via launch/cells.py.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --tokens 32
    PYTHONPATH=src python -m repro.launch.serve --arch din --mode retrieval
    PYTHONPATH=src python -m repro.launch.serve --arch graph --lanes 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, get
from repro.data.synthetic import recsys_batch, retrieval_batch


def serve_lm(arch, tokens: int, batch: int):
    from repro.models.transformer import decode_step, init_kv_cache, init_params

    cfg = arch.smoke()
    params = init_params(jax.random.key(0), cfg)
    max_len = tokens + 8
    cache = init_kv_cache(cfg, batch, max_len, dtype=jnp.float32)
    step = jax.jit(lambda p, c, t, i: decode_step(p, c, t, i, cfg), donate_argnums=1)
    tok = jnp.zeros((batch, 1), jnp.int32)
    # greedy decode loop with KV cache
    t0 = time.perf_counter()
    out = []
    for i in range(tokens):
        logits, cache = step(params, cache, tok, jnp.int32(i))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok[:, 0]))
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(f"decoded {tokens} tokens x batch {batch} in {dt:.2f}s "
          f"({tokens * batch / dt:.1f} tok/s single-CPU)")
    print("sample:", np.stack(out, 1)[0][:16].tolist())


def serve_din(arch, mode: str):
    from repro.models.recsys.din import init as din_init, score, score_candidates

    cfg = arch.smoke()
    params = din_init(jax.random.key(0), cfg)
    if mode == "retrieval":
        rb = retrieval_batch(0, cfg.seq_len, 4096, cfg.item_vocab, cfg.cate_vocab,
                             cfg.profile_bag_len)
        rb = {k: jnp.asarray(v) for k, v in rb.items()}
        fn = jax.jit(lambda p, b: score_candidates(p, b, cfg, chunk=512))
        s = fn(params, rb).block_until_ready()
        t0 = time.perf_counter()
        s = fn(params, rb).block_until_ready()
        dt = time.perf_counter() - t0
        print(f"retrieval: 4096 candidates in {dt * 1e3:.1f} ms; "
              f"top item {int(rb['cand_items'][int(np.argmax(np.asarray(s)))])}")
    else:
        b = recsys_batch(0, 0, 512, cfg.seq_len, cfg.item_vocab, cfg.cate_vocab,
                         cfg.profile_bag_len)
        b = {k: jnp.asarray(v) for k, v in b.items() if k != "labels"}
        fn = jax.jit(lambda p, b: score(p, b, cfg))
        s = fn(params, b).block_until_ready()
        t0 = time.perf_counter()
        s = fn(params, b).block_until_ready()
        dt = time.perf_counter() - t0
        print(f"pointwise: batch 512 in {dt * 1e3:.2f} ms ({512 / dt:.0f} QPS)")


def serve_graph(
    problem_kind: str,
    lanes: int,
    queries: int,
    scale: int,
    degree: int,
    seed: int,
):
    """Always-on graph query serving, first slice (ROADMAP): hold ONE
    partitioned graph device-resident, admission-batch incoming BFS/SSSP
    roots into K lanes, and answer each batch with a single lane-batched
    engine run — one compressed edge-stream pass per batch instead of one
    per query (docs/tile_layout.md §8).

    The jit cache is kept warm at one batch width: a multi-query problem's
    trace depends only on K, so a template problem is the static jit key and
    each batch's roots enter through the label init (``engine.run(labels=)``).
    Reports per-query latency and QPS; batch 0 separately (it pays the
    compile)."""
    import repro.core.graph as G
    from repro.core.engine import EngineOptions, prepare_labels, run
    from repro.core.partition import PartitionConfig, partition_2d
    from repro.core.problems import bfs_multi, sssp_multi
    from repro.data.synthetic import admission_batches, query_workload

    g = G.symmetrize(G.rmat(scale, degree, seed=1))
    if problem_kind == "sssp":
        w = (np.random.default_rng(2).random(g.src.shape[0]) + 0.1).astype(
            np.float32
        )
        g = G.COOGraph(src=g.src, dst=g.dst, num_vertices=g.num_vertices, weights=w)
    make = bfs_multi if problem_kind == "bfs" else sssp_multi
    pg = partition_2d(g, PartitionConfig(p=4, l=2))  # device-resident, reused
    opts = EngineOptions(lanes=lanes)  # admission check: K must match
    roots = query_workload(queries, g.num_vertices, seed=seed)
    batches = admission_batches(roots, lanes)
    template = make(batches[0][0])

    stats = []
    for i, (chunk, served) in enumerate(batches):
        labels = prepare_labels(make(chunk), g, pg)
        t0 = time.perf_counter()
        res = run(template, g, pg, opts, labels=labels)
        dt = time.perf_counter() - t0
        stats.append((served, dt, res.iterations))
        print(
            f"batch {i}: {served} queries in {dt * 1e3:.1f} ms "
            f"({dt * 1e3 / served:.2f} ms/query, {res.iterations} iters, "
            f"1 edge-stream pass/iter for all {served})"
            + ("  [includes compile]" if i == 0 else "")
        )
    warm = stats[1:] or stats
    served = sum(s for s, _, _ in warm)
    wall = sum(t for _, t, _ in warm)
    passes = sum(it for _, _, it in warm)
    print(
        f"steady state: {served} queries / {wall:.3f} s = {served / wall:.1f} QPS; "
        f"amortized {g.src.shape[0] * served / wall / 1e6:.2f} MTEPS/query-pass; "
        f"{passes} batched edge-stream passes vs ~{passes * lanes} sequential"
    )
    return stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--arch", required=True, choices=sorted(ARCHS) + ["graph"],
        help="model arch, or 'graph' for lane-batched graph query serving",
    )
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--mode", default="pointwise", choices=["pointwise", "retrieval"])
    ap.add_argument("--graph-problem", default="bfs", choices=["bfs", "sssp"])
    ap.add_argument("--lanes", type=int, default=16, help="admission batch width K")
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--scale", type=int, default=9, help="rmat scale (graph mode)")
    ap.add_argument("--degree", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.arch == "graph":
        serve_graph(
            args.graph_problem, args.lanes, args.queries, args.scale,
            args.degree, args.seed,
        )
        return
    arch = get(args.arch)
    if arch.family == "lm":
        serve_lm(arch, args.tokens, args.batch)
    elif arch.family == "recsys":
        serve_din(arch, args.mode)
    else:
        raise SystemExit("GNN archs serve via launch.train / examples/gnn_training.py")


if __name__ == "__main__":
    main()
