"""Serving CLI: batched decode for LM archs, pointwise/retrieval scoring for
DIN — reduced configs on CPU; production shapes via launch/cells.py.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --tokens 32
    PYTHONPATH=src python -m repro.launch.serve --arch din --mode retrieval
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, get
from repro.data.synthetic import recsys_batch, retrieval_batch


def serve_lm(arch, tokens: int, batch: int):
    from repro.models.transformer import decode_step, init_kv_cache, init_params

    cfg = arch.smoke()
    params = init_params(jax.random.key(0), cfg)
    max_len = tokens + 8
    cache = init_kv_cache(cfg, batch, max_len, dtype=jnp.float32)
    step = jax.jit(lambda p, c, t, i: decode_step(p, c, t, i, cfg), donate_argnums=1)
    tok = jnp.zeros((batch, 1), jnp.int32)
    # greedy decode loop with KV cache
    t0 = time.perf_counter()
    out = []
    for i in range(tokens):
        logits, cache = step(params, cache, tok, jnp.int32(i))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok[:, 0]))
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(f"decoded {tokens} tokens x batch {batch} in {dt:.2f}s "
          f"({tokens * batch / dt:.1f} tok/s single-CPU)")
    print("sample:", np.stack(out, 1)[0][:16].tolist())


def serve_din(arch, mode: str):
    from repro.models.recsys.din import init as din_init, score, score_candidates

    cfg = arch.smoke()
    params = din_init(jax.random.key(0), cfg)
    if mode == "retrieval":
        rb = retrieval_batch(0, cfg.seq_len, 4096, cfg.item_vocab, cfg.cate_vocab,
                             cfg.profile_bag_len)
        rb = {k: jnp.asarray(v) for k, v in rb.items()}
        fn = jax.jit(lambda p, b: score_candidates(p, b, cfg, chunk=512))
        s = fn(params, rb).block_until_ready()
        t0 = time.perf_counter()
        s = fn(params, rb).block_until_ready()
        dt = time.perf_counter() - t0
        print(f"retrieval: 4096 candidates in {dt * 1e3:.1f} ms; "
              f"top item {int(rb['cand_items'][int(np.argmax(np.asarray(s)))])}")
    else:
        b = recsys_batch(0, 0, 512, cfg.seq_len, cfg.item_vocab, cfg.cate_vocab,
                         cfg.profile_bag_len)
        b = {k: jnp.asarray(v) for k, v in b.items() if k != "labels"}
        fn = jax.jit(lambda p, b: score(p, b, cfg))
        s = fn(params, b).block_until_ready()
        t0 = time.perf_counter()
        s = fn(params, b).block_until_ready()
        dt = time.perf_counter() - t0
        print(f"pointwise: batch 512 in {dt * 1e3:.2f} ms ({512 / dt:.0f} QPS)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--mode", default="pointwise", choices=["pointwise", "retrieval"])
    args = ap.parse_args()
    arch = get(args.arch)
    if arch.family == "lm":
        serve_lm(arch, args.tokens, args.batch)
    elif arch.family == "recsys":
        serve_din(arch, args.mode)
    else:
        raise SystemExit("GNN archs serve via launch.train / examples/gnn_training.py")


if __name__ == "__main__":
    main()
