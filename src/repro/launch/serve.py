"""Serving CLI: batched decode for LM archs, pointwise/retrieval scoring for
DIN, and lane-batched graph query serving — reduced configs on CPU;
production shapes via launch/cells.py.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --tokens 32
    PYTHONPATH=src python -m repro.launch.serve --arch din --mode retrieval
    PYTHONPATH=src python -m repro.launch.serve --arch graph --lanes 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, get
from repro.data.synthetic import recsys_batch, retrieval_batch


def serve_lm(arch, tokens: int, batch: int):
    from repro.models.transformer import decode_step, init_kv_cache, init_params

    cfg = arch.smoke()
    params = init_params(jax.random.key(0), cfg)
    max_len = tokens + 8
    cache = init_kv_cache(cfg, batch, max_len, dtype=jnp.float32)
    step = jax.jit(lambda p, c, t, i: decode_step(p, c, t, i, cfg), donate_argnums=1)
    tok = jnp.zeros((batch, 1), jnp.int32)
    # greedy decode loop with KV cache
    t0 = time.perf_counter()
    out = []
    for i in range(tokens):
        logits, cache = step(params, cache, tok, jnp.int32(i))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok[:, 0]))
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(f"decoded {tokens} tokens x batch {batch} in {dt:.2f}s "
          f"({tokens * batch / dt:.1f} tok/s single-CPU)")
    print("sample:", np.stack(out, 1)[0][:16].tolist())


def serve_din(arch, mode: str):
    from repro.models.recsys.din import init as din_init, score, score_candidates

    cfg = arch.smoke()
    params = din_init(jax.random.key(0), cfg)
    if mode == "retrieval":
        rb = retrieval_batch(0, cfg.seq_len, 4096, cfg.item_vocab, cfg.cate_vocab,
                             cfg.profile_bag_len)
        rb = {k: jnp.asarray(v) for k, v in rb.items()}
        fn = jax.jit(lambda p, b: score_candidates(p, b, cfg, chunk=512))
        s = fn(params, rb).block_until_ready()
        t0 = time.perf_counter()
        s = fn(params, rb).block_until_ready()
        dt = time.perf_counter() - t0
        print(f"retrieval: 4096 candidates in {dt * 1e3:.1f} ms; "
              f"top item {int(rb['cand_items'][int(np.argmax(np.asarray(s)))])}")
    else:
        b = recsys_batch(0, 0, 512, cfg.seq_len, cfg.item_vocab, cfg.cate_vocab,
                         cfg.profile_bag_len)
        b = {k: jnp.asarray(v) for k, v in b.items() if k != "labels"}
        fn = jax.jit(lambda p, b: score(p, b, cfg))
        s = fn(params, b).block_until_ready()
        t0 = time.perf_counter()
        s = fn(params, b).block_until_ready()
        dt = time.perf_counter() - t0
        print(f"pointwise: batch 512 in {dt * 1e3:.2f} ms ({512 / dt:.0f} QPS)")


def _serve_events(workload, deltas):
    """Interleave a mixed query workload with delta-ingest batches: each
    insertion batch (followed by an explicit flush) lands at an even split
    point of the query stream — the 'graph mutates mid-stream' scenario."""
    from repro.serve import Query

    n = len(workload)
    cuts = {
        max(1, (i + 1) * n // (len(deltas) + 1)): d for i, d in enumerate(deltas)
    }
    events = []
    for i, q in enumerate(workload):
        if i in cuts:
            events.append(("delta", cuts[i]))
            events.append(("flush", None))
        events.append(
            ("query", Query(kind=q["kind"], root=q["root"], target=q["target"], qid=i))
        )
    return events


def serve_graph(
    lanes: int,
    queries: int,
    scale: int,
    degree: int,
    seed: int,
    smoke: bool = False,
    delta_edges: int = 96,
):
    """Always-on graph serving on the repro.serve subsystem (ROADMAP item,
    docs/serving.md): ONE resident partitioned graph answers a mixed
    neighbors-of / distance-to (BFS+SSSP lanes) / ppr / recommend-for query
    stream through the bounded-admission request loop, while streamed edge
    insertions are delta-ingested mid-stream — flushes re-tile only the
    dirty (core, phase) buckets and swap the resident partition between
    batches.

    ``smoke`` (CI, scripts/check.sh): after the run, re-answer every query
    on BOTH the final resident partition (incrementally re-tiled) and a
    from-scratch repartition of the final graph, and assert the answers are
    bit-for-bit identical; also assert full BFS/WCC/SSSP label equality and
    that every flush re-tiled a strict subset of buckets it reports."""
    import repro.core.graph as G
    from repro.core.partition import PartitionConfig, partition_2d
    from repro.data.synthetic import edge_insertion_stream, mixed_query_workload
    from repro.serve import (
        GraphService, LoopConfig, RecommendScorer, RequestLoop,
    )

    g0 = G.symmetrize(G.rmat(scale, degree, seed=1))
    w = (np.random.default_rng(2).random(g0.num_edges) + 0.1).astype(np.float32)
    g = G.COOGraph(src=g0.src, dst=g0.dst, num_vertices=g0.num_vertices, weights=w)
    cfg = PartitionConfig(p=4, l=2)
    scorer = RecommendScorer(pool_size=64, topk=8)
    service = GraphService(g, cfg, lanes=lanes, scorer=scorer)
    loop = RequestLoop(service, LoopConfig(max_wait_ms=20.0, host_batch=lanes))

    workload = mixed_query_workload(queries, g.num_vertices, seed=seed)
    deltas = edge_insertion_stream(
        delta_edges, g.num_vertices, num_batches=2, weighted=True, seed=seed + 1
    )
    events = _serve_events(workload, deltas)
    completions = loop.run(events)
    s = loop.metrics.summary()

    lat = s["latency"]
    print(
        f"served {s['queries']} queries ({s['rejected']} rejected) in "
        f"{s['wall_s']:.2f}s = {s['qps']:.1f} QPS; latency p50 "
        f"{lat['p50_ms']:.1f} / p95 {lat['p95_ms']:.1f} / p99 "
        f"{lat['p99_ms']:.1f} ms"
    )
    print(
        f"{s['batches']} batches ({s['cold_batches']} cold), steady batch "
        f"{s['steady_batch_ms']:.2f} ms"
        + (
            f", amortized {s['amortized_mteps']:.2f} MTEPS"
            if s["amortized_mteps"] else ""
        )
    )
    for f in s["flushes"]:
        print(
            f"flush: +{f['edges_added']} edges re-tiled "
            f"{f['buckets_retiled']}/{f['total_buckets']} buckets "
            f"({100 * f['repacked_fraction']:.0f}% of packed bytes) in "
            f"{f['wall_s'] * 1e3:.1f} ms"
        )
    if not smoke:
        return s

    # -- smoke equivalence: resident (incrementally re-tiled) partition vs a
    # from-scratch repartition of the final graph, bit for bit
    assert len(completions) == len(workload), (len(completions), len(workload))
    assert s["flushes"], "smoke must exercise delta ingest"
    for f in s["flushes"]:
        assert f["buckets_retiled"] <= f["total_buckets"]
        assert f["repacked_fraction"] <= 1.0
    g_final, pg_res = service.g, service.pg
    assert g_final.num_edges == g.num_edges + delta_edges
    pg_cold = partition_2d(g_final, cfg)

    def replay(pg):
        svc = GraphService(
            g_final, pg, lanes=lanes,
            scorer=RecommendScorer(pool_size=64, topk=8),
        )
        lp = RequestLoop(service=svc, cfg=LoopConfig(max_wait_ms=20.0, host_batch=lanes))
        return lp.run(_serve_events(workload, []))

    res_a, res_b = replay(pg_res), replay(pg_cold)
    assert len(res_a) == len(res_b) == len(workload)
    for ca, cb in zip(res_a, res_b):
        assert ca.qid == cb.qid and ca.kind == cb.kind
        a, b = ca.answer, cb.answer
        for k in a:
            assert np.array_equal(np.asarray(a[k]), np.asarray(b[k])), (
                ca.kind, ca.qid, k, a[k], b[k]
            )
    # full-label equality on the resident partition (incl. WCC, which the
    # router does not serve): the delta-ingest acceptance criterion
    from repro.core.engine import EngineOptions as EO, run as erun
    from repro.core.problems import bfs, sssp, wcc

    for prob in (bfs(0), wcc(), sssp(0)):
        ra = erun(prob, g_final, pg_res, EO())
        rb = erun(prob, g_final, pg_cold, EO())
        assert ra.iterations == rb.iterations, prob.name
        for k in ra.labels:
            assert np.array_equal(ra.labels[k], rb.labels[k]), (prob.name, k)
    print(
        "serve smoke OK: resident delta-retiled partition matches "
        "from-scratch repartition bit-for-bit "
        f"({len(workload)} answers + BFS/WCC/SSSP labels)"
    )
    return s


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--arch", required=True, choices=sorted(ARCHS) + ["graph"],
        help="model arch, or 'graph' for lane-batched graph query serving",
    )
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--mode", default="pointwise", choices=["pointwise", "retrieval"])
    ap.add_argument("--lanes", type=int, default=16, help="admission batch width K")
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--scale", type=int, default=9, help="rmat scale (graph mode)")
    ap.add_argument("--degree", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--delta-edges", type=int, default=96,
                    help="edge insertions streamed mid-run (graph mode)")
    ap.add_argument("--smoke", action="store_true",
                    help="bounded CI run: assert delta-retiled answers match "
                         "a from-scratch repartition bit-for-bit")
    args = ap.parse_args()
    if args.arch == "graph":
        if args.smoke:
            # bounded: small graph, few queries, still covers all kinds +
            # two mid-stream delta flushes
            serve_graph(
                lanes=8, queries=40, scale=8, degree=6, seed=args.seed,
                smoke=True, delta_edges=64,
            )
            return
        serve_graph(
            args.lanes, args.queries, args.scale, args.degree, args.seed,
            delta_edges=args.delta_edges,
        )
        return
    arch = get(args.arch)
    if arch.family == "lm":
        serve_lm(arch, args.tokens, args.batch)
    elif arch.family == "recsys":
        serve_din(arch, args.mode)
    else:
        raise SystemExit("GNN archs serve via launch.train / examples/gnn_training.py")


if __name__ == "__main__":
    main()
