"""granite-moe-1b-a400m [moe] 24L d_model=1024 16H (GQA kv=8) d_ff=512
vocab=49155, MoE 32e top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LM_SHAPES
from repro.models.layers import MoEConfig
from repro.models.transformer import LMConfig


def _smoke():
    return LMConfig(
        name="granite-moe-smoke", n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
        head_dim=12, d_ff=64, vocab=255, dtype=jnp.float32, attn_chunk=32,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32),
    )


ARCH = ArchConfig(
    arch_id="granite-moe-1b-a400m",
    family="lm",
    model=LMConfig(
        name="granite-moe-1b-a400m", n_layers=24, d_model=1024, n_heads=16,
        n_kv_heads=8, head_dim=64, d_ff=512,
        # true vocab 49155 padded to a 128-multiple so logits shard over the
        # model axis (unsharded f32 logits measured 12.9 GiB/dev; §Perf it2);
        # the loss masks columns >= vocab_real.
        vocab=49280, vocab_real=49155,
        rope_theta=10_000.0, dtype=jnp.bfloat16, attn_chunk=512,
        moe=MoEConfig(num_experts=32, top_k=8, d_ff_expert=512),
    ),
    shapes=LM_SHAPES,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
    smoke=_smoke,
)
