"""qwen3-14b [dense] 40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936
— qk_norm, GQA [hf:Qwen/Qwen3-8B; hf]."""
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LM_SHAPES
from repro.models.transformer import LMConfig


def _smoke():
    return LMConfig(
        name="qwen3-14b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=256, qk_norm=True, dtype=jnp.float32,
        attn_chunk=32,
    )


ARCH = ArchConfig(
    arch_id="qwen3-14b",
    family="lm",
    model=LMConfig(
        name="qwen3-14b", n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
        head_dim=128, d_ff=17408, vocab=151936, qk_norm=True,
        rope_theta=1_000_000.0, dtype=jnp.bfloat16,
        # 40 heads don't divide the 16-way model axis -> scores stay
        # head-replicated; a small KV chunk bounds the (B,40,S,chunk) buffer.
        attn_chunk=256,
    ),
    shapes=LM_SHAPES,
    source="hf:Qwen/Qwen3-8B; hf",
    smoke=_smoke,
)
