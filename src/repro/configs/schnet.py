"""schnet [gnn] n_interactions=3 d_hidden=64 rbf=300 cutoff=10
[arXiv:1706.08566; paper]."""
from repro.configs.base import ArchConfig, GNN_SHAPES
from repro.models.gnn.archs import GNNConfig


def _smoke():
    return GNNConfig(name="schnet", n_layers=2, d_hidden=16, rbf=20, cutoff=10.0)


ARCH = ArchConfig(
    arch_id="schnet",
    family="gnn",
    model=GNNConfig(name="schnet", n_layers=3, d_hidden=64, rbf=300, cutoff=10.0),
    shapes=GNN_SHAPES,
    source="arXiv:1706.08566; paper",
    gnn_task="node_reg",
    gnn_out_dim=1,
    smoke=_smoke,
)
