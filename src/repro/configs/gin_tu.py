"""gin-tu [gnn] n_layers=5 d_hidden=64 aggregator=sum eps=learnable
[arXiv:1810.00826; paper]."""
from repro.configs.base import ArchConfig, GNN_SHAPES
from repro.models.gnn.archs import GNNConfig


def _smoke():
    return GNNConfig(name="gin", n_layers=2, d_hidden=16)


ARCH = ArchConfig(
    arch_id="gin-tu",
    family="gnn",
    model=GNNConfig(
        name="gin", n_layers=5, d_hidden=64, aggregator="sum", eps_learnable=True
    ),
    shapes=GNN_SHAPES,
    source="arXiv:1810.00826; paper",
    gnn_task="graph_class",
    gnn_out_dim=2,
    smoke=_smoke,
)
