"""din [recsys] embed_dim=18 seq_len=100 attn_mlp=80-40 mlp=200-80
interaction=target-attn [arXiv:1706.06978; paper]."""
from repro.configs.base import ArchConfig, RECSYS_SHAPES
from repro.models.recsys.din import DINConfig


def _smoke():
    return DINConfig(
        name="din-smoke", embed_dim=8, seq_len=12, attn_mlp=(16, 8),
        out_mlp=(24, 12), item_vocab=500, cate_vocab=20, profile_bag_len=6,
    )


ARCH = ArchConfig(
    arch_id="din",
    family="recsys",
    model=DINConfig(
        name="din", embed_dim=18, seq_len=100, attn_mlp=(80, 40),
        out_mlp=(200, 80),
        # 10M items padded to a 512-multiple so the table row-shards over the
        # full mesh (crossbar_full for training; §Perf it2)
        item_vocab=10_000_384, cate_vocab=10_000,
        profile_bag_len=32,
        # GraphScale two-level crossbar replaces GSPMD's full-table all-gather
        # (717 MB/step -> 15 MB/step measured on serve_bulk; §Perf it1)
        lookup="crossbar",
    ),
    shapes=RECSYS_SHAPES,
    source="arXiv:1706.06978; paper",
    smoke=_smoke,
)
