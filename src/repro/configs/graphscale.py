"""Paper-native GraphScale configuration (Table II parameterization).

FPGA -> framework mapping:
  * 4 memory channels            -> p = 4 graph cores (or mesh size)
  * vertex label scratch 2^21    -> scratch_size = 2**21 labels per core-phase
  * 16 scratch-pad banks         -> lane quantum (8x128 vector layout on TPU)
  * 8 vertex pipelines           -> edge-tile width Eb in the Pallas kernel
  * reorder depth 32             -> crossbar capacity factor (dist/embedding)
  * stride mapping stride 100    -> PartitionConfig.stride
"""
from __future__ import annotations

import dataclasses

from repro.core.partition import PartitionConfig

PAPER_SCRATCH_LABELS = 1 << 21
PAPER_STRIDE = 100
PAPER_CHANNELS = 4


def paper_partition_config(
    p: int = PAPER_CHANNELS,
    stride: int | None = PAPER_STRIDE,
    lane: int = 8,
) -> PartitionConfig:
    return PartitionConfig(
        p=p, l=1, lane=lane, stride=stride, scratch_size=PAPER_SCRATCH_LABELS
    )


@dataclasses.dataclass(frozen=True)
class KernelTiling:
    """Pallas accumulator tile parameters (TPU target)."""

    vb: int = 128  # rows per output block (sublane multiple)
    eb: int = 1024  # edges per tile (8 x 128 lanes)


PAPER_KERNEL_TILING = KernelTiling()
