"""EXTRA pool arch (beyond assignment): gcn [arXiv:1609.02907]
2 layers, hidden 16, symmetric-normalized SpMM convolution."""
from repro.configs.base import ArchConfig, GNN_SHAPES
from repro.models.gnn.archs import GNNConfig


def _smoke():
    return GNNConfig(name="gcn", n_layers=2, d_hidden=8)


ARCH = ArchConfig(
    arch_id="gcn-cora",
    family="gnn",
    model=GNNConfig(name="gcn", n_layers=2, d_hidden=16),
    shapes=GNN_SHAPES,
    source="arXiv:1609.02907; paper (extra, beyond assignment)",
    gnn_task="node_class",
    gnn_out_dim=7,
    smoke=_smoke,
)
