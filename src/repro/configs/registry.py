"""--arch <id> registry: the ten ASSIGNED architectures + extra pool archs."""
from repro.configs import (  # noqa: F401
    din,
    gat_cora,
    gcn_cora,
    gin_tu,
    granite_moe_1b_a400m,
    graphsage,
    llama3_8b,
    meshgraphnet,
    qwen3_14b,
    qwen3_moe_30b_a3b,
    schnet,
    smollm_135m,
)

ASSIGNED = (
    qwen3_14b, smollm_135m, llama3_8b, granite_moe_1b_a400m,
    qwen3_moe_30b_a3b, meshgraphnet, schnet, gat_cora, gin_tu, din,
)
EXTRA = (gcn_cora, graphsage)

ARCHS = {m.ARCH.arch_id: m.ARCH for m in ASSIGNED + EXTRA}
ASSIGNED_IDS = tuple(m.ARCH.arch_id for m in ASSIGNED)


def get(arch_id: str):
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(ARCHS)}")
    return ARCHS[arch_id]
