"""Config schema for the assigned architectures.

Every arch module exposes ``ARCH: ArchConfig`` registered in
``configs.registry``; the launcher selects with ``--arch <id>`` and
``--shape <name>``. ``smoke()`` returns a CPU-sized reduction of the same
family used by the per-arch smoke tests (full configs are only ever lowered,
never allocated, per the dry-run contract).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

__all__ = ["ShapeCell", "ArchConfig", "LM_SHAPES", "GNN_SHAPES", "RECSYS_SHAPES"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str  # train | prefill | decode | gnn_full | gnn_minibatch | gnn_molecule | serve | serve_train | retrieval
    dims: Dict[str, int]
    note: str = ""


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    arch_id: str
    family: str  # 'lm' | 'gnn' | 'recsys'
    model: Any  # LMConfig | GNNConfig | DINConfig
    shapes: Tuple[ShapeCell, ...]
    source: str  # public provenance tag
    # family-specific extras
    gnn_task: str = "node_class"  # gnn: default task kind
    gnn_out_dim: int = 8
    smoke: Optional[Callable[[], Any]] = None  # reduced model cfg for CPU

    def shape(self, name: str) -> ShapeCell:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.arch_id} has no shape {name}: {[s.name for s in self.shapes]}")


# The four LM shapes (seq_len x global_batch). decode_* / long_* lower
# serve_step (one token against a seq_len KV cache), NOT train_step.
LM_SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", "train", dict(seq=4096, batch=256)),
    ShapeCell("prefill_32k", "prefill", dict(seq=32768, batch=32)),
    ShapeCell("decode_32k", "decode", dict(seq=32768, batch=128)),
    ShapeCell(
        "long_500k",
        "decode",
        dict(seq=524288, batch=1),
        note=(
            "pure full-attention arch: skippable per assignment; run anyway "
            "because DECODE against a 500k cache is O(S) per token with the "
            "sequence-parallel cache (500k PREFILL would be quadratic and is "
            "not attempted)"
        ),
    ),
)

# GNN shapes: node/edge counts padded to multiples of 512 (mesh divisibility);
# originals in notes. Features/classes per standard datasets.
GNN_SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell(
        "full_graph_sm",
        "gnn_full",
        dict(n_nodes=4096, n_edges=16384, d_feat=1433, n_classes=7),
        note="cora 2708/10556 padded to 4096/16384",
    ),
    ShapeCell(
        "minibatch_lg",
        "gnn_minibatch",
        dict(
            batch_nodes=1024, fanout1=15, fanout2=10,
            n_nodes=169984, n_edges=168960,  # sampler max_nodes/max_edges
            d_feat=602, n_classes=41,
        ),
        note="reddit-scale (233k nodes / 115M edges) via fanout-15,10 sampler",
    ),
    ShapeCell(
        "ogb_products",
        "gnn_full",
        dict(n_nodes=2449408, n_edges=61859328, d_feat=100, n_classes=47),
        note="ogbn-products 2,449,029/61,859,140 padded to x512 multiples",
    ),
    ShapeCell(
        "molecule",
        "gnn_molecule",
        dict(n_graphs=128, nodes_per=32, edges_per=64, d_feat=16, n_classes=2),
        note="30 nodes padded to 32 for lane alignment; batch=128 graphs",
    ),
)

RECSYS_SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_batch", "serve_train", dict(batch=65536)),
    ShapeCell("serve_p99", "serve", dict(batch=512)),
    ShapeCell("serve_bulk", "serve", dict(batch=262144)),
    ShapeCell(
        "retrieval_cand",
        "retrieval",
        dict(batch=1, n_candidates=1048576),
        note="1,000,000 padded to 2^20 for mesh divisibility",
    ),
)
