"""gat-cora [gnn] n_layers=2 d_hidden=8 n_heads=8 aggregator=attn
[arXiv:1710.10903; paper]."""
from repro.configs.base import ArchConfig, GNN_SHAPES
from repro.models.gnn.archs import GNNConfig


def _smoke():
    return GNNConfig(name="gat", n_layers=2, d_hidden=4, n_heads=2, aggregator="attn")


ARCH = ArchConfig(
    arch_id="gat-cora",
    family="gnn",
    model=GNNConfig(
        name="gat", n_layers=2, d_hidden=8, n_heads=8, aggregator="attn"
    ),
    shapes=GNN_SHAPES,
    source="arXiv:1710.10903; paper",
    gnn_task="node_class",
    gnn_out_dim=7,
    smoke=_smoke,
)
