"""smollm-135m [dense] 30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152
— llama-arch small [hf:HuggingFaceTB/SmolLM-135M; hf]."""
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LM_SHAPES
from repro.models.transformer import LMConfig


def _smoke():
    return LMConfig(
        name="smollm-135m-smoke", n_layers=2, d_model=48, n_heads=3, n_kv_heads=3,
        head_dim=16, d_ff=128, vocab=256, dtype=jnp.float32, attn_chunk=32,
    )


ARCH = ArchConfig(
    arch_id="smollm-135m",
    family="lm",
    model=LMConfig(
        name="smollm-135m", n_layers=30, d_model=576, n_heads=9, n_kv_heads=3,
        head_dim=64, d_ff=1536, vocab=49152, rope_theta=10_000.0,
        dtype=jnp.bfloat16, attn_chunk=512,
    ),
    shapes=LM_SHAPES,
    source="hf:HuggingFaceTB/SmolLM-135M; hf",
    smoke=_smoke,
)
