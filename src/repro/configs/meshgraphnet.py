"""meshgraphnet [gnn] n_layers=15 d_hidden=128 aggregator=sum mlp_layers=2
[arXiv:2010.03409; unverified]."""
from repro.configs.base import ArchConfig, GNN_SHAPES
from repro.models.gnn.archs import GNNConfig


def _smoke():
    return GNNConfig(name="meshgraphnet", n_layers=3, d_hidden=16, mlp_layers=2)


ARCH = ArchConfig(
    arch_id="meshgraphnet",
    family="gnn",
    model=GNNConfig(
        name="meshgraphnet", n_layers=15, d_hidden=128, aggregator="sum",
        mlp_layers=2,
    ),
    shapes=GNN_SHAPES,
    source="arXiv:2010.03409; unverified",
    gnn_task="node_reg",
    gnn_out_dim=2,
    smoke=_smoke,
)
