"""EXTRA pool arch (beyond assignment): GraphSAGE [arXiv:1706.02216]
2 layers, hidden 64, mean aggregator + L2 normalization."""
from repro.configs.base import ArchConfig, GNN_SHAPES
from repro.models.gnn.archs import GNNConfig


def _smoke():
    return GNNConfig(name="sage", n_layers=2, d_hidden=16, aggregator="mean")


ARCH = ArchConfig(
    arch_id="graphsage",
    family="gnn",
    model=GNNConfig(name="sage", n_layers=2, d_hidden=64, aggregator="mean"),
    shapes=GNN_SHAPES,
    source="arXiv:1706.02216; paper (extra, beyond assignment)",
    gnn_task="node_class",
    gnn_out_dim=41,
    smoke=_smoke,
)
