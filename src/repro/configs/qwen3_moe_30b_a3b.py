"""qwen3-moe-30b-a3b [moe] 48L d_model=2048 32H (GQA kv=4) d_ff=768
vocab=151936, MoE 128e top-8 [hf:Qwen/Qwen3-30B-A3B; hf]."""
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LM_SHAPES
from repro.models.layers import MoEConfig
from repro.models.transformer import LMConfig


def _smoke():
    return LMConfig(
        name="qwen3-moe-smoke", n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
        head_dim=12, d_ff=64, vocab=255, qk_norm=True, dtype=jnp.float32,
        attn_chunk=32, moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32),
    )


ARCH = ArchConfig(
    arch_id="qwen3-moe-30b-a3b",
    family="lm",
    model=LMConfig(
        name="qwen3-moe-30b-a3b", n_layers=48, d_model=2048, n_heads=32,
        n_kv_heads=4, head_dim=128, d_ff=768, vocab=151936, qk_norm=True,
        rope_theta=1_000_000.0, dtype=jnp.bfloat16, attn_chunk=512,
        moe=MoEConfig(num_experts=128, top_k=8, d_ff_expert=768),
    ),
    shapes=LM_SHAPES,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
    smoke=_smoke,
)
