"""llama3-8b [dense] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256
— GQA 128k vocab [arXiv:2407.21783; unverified]."""
import jax.numpy as jnp

from repro.configs.base import ArchConfig, LM_SHAPES
from repro.models.transformer import LMConfig


def _smoke():
    return LMConfig(
        name="llama3-8b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=160, vocab=256, dtype=jnp.float32, attn_chunk=32,
    )


ARCH = ArchConfig(
    arch_id="llama3-8b",
    family="lm",
    model=LMConfig(
        name="llama3-8b", n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
        head_dim=128, d_ff=14336, vocab=128256, rope_theta=500_000.0,
        dtype=jnp.bfloat16, attn_chunk=512,
    ),
    shapes=LM_SHAPES,
    source="arXiv:2407.21783; unverified",
    smoke=_smoke,
)
