"""Wrapper: lay traced per-edge scores into the static tile layout and run
the two-pass online segment softmax."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.kernels.csr_gather_reduce.ops import TileLayout
from repro.kernels.segment_softmax.kernel import segment_softmax_pallas
from repro.kernels.segment_softmax.ref import segment_softmax_reference

__all__ = ["segment_softmax", "segment_softmax_tiled"]


def segment_softmax_tiled(
    scores_flat: jnp.ndarray,  # (E,) traced scores in ORIGINAL edge order
    tiles: TileLayout,
    *,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (weights in tile order (R,T,Eb), tile layout echo)."""
    assert tiles.gather_idx is not None
    tiled = jnp.take(scores_flat, jnp.asarray(tiles.gather_idx), axis=0)
    w = segment_softmax_pallas(
        tiled.astype(jnp.float32),
        jnp.asarray(tiles.dstb),
        jnp.asarray(tiles.valid),
        num_rows=tiles.num_rows,
        vb=tiles.vb,
        interpret=interpret,
    )
    return w, tiled


def segment_softmax(
    scores: jnp.ndarray,
    dst: jnp.ndarray,
    valid: jnp.ndarray,
    num_rows: int,
    *,
    use_pallas: bool = False,
    tiles: TileLayout | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Per-segment softmax in ORIGINAL edge order (scatter back from tiles)."""
    if not use_pallas:
        return segment_softmax_reference(scores, dst, valid, num_rows)
    assert tiles is not None and tiles.gather_idx is not None
    w_tiled, _ = segment_softmax_tiled(scores, tiles, interpret=interpret)
    e = scores.shape[0]
    # padding slots are routed to a dump index e and sliced off afterwards
    flat_val = np.asarray(tiles.valid).reshape(-1)
    flat_idx = np.where(flat_val, np.asarray(tiles.gather_idx).reshape(-1), e)
    out = jnp.zeros((e + 1,), jnp.float32)
    out = out.at[jnp.asarray(flat_idx)].set(w_tiled.reshape(-1))
    return out[:e]
