"""Pure-jnp oracle for segment softmax (GAT edge softmax over in-edges)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["segment_softmax_reference"]

_NEG = -1e30


def segment_softmax_reference(
    scores: jnp.ndarray,  # (E,)
    dst: jnp.ndarray,  # (E,) segment ids
    valid: jnp.ndarray,  # (E,) bool
    num_rows: int,
) -> jnp.ndarray:
    """Numerically-stable per-segment softmax; invalid edges get weight 0."""
    s = jnp.where(valid, scores, _NEG)
    seg_max = jax.ops.segment_max(s, dst, num_segments=num_rows)
    seg_max = jnp.where(seg_max <= _NEG / 2, 0.0, seg_max)  # empty rows
    e = jnp.where(valid, jnp.exp(s - seg_max[dst]), 0.0)
    seg_sum = jax.ops.segment_sum(e, dst, num_segments=num_rows)
    return jnp.where(valid, e / jnp.maximum(seg_sum[dst], 1e-30), 0.0)
