from repro.kernels.segment_softmax import ops, ref  # noqa: F401
from repro.kernels.segment_softmax.kernel import segment_softmax_pallas  # noqa: F401
from repro.kernels.segment_softmax.ops import segment_softmax, segment_softmax_tiled  # noqa: F401
