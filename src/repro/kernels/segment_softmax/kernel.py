"""Pallas TPU kernels: segment softmax via online (flash-style) statistics.

GAT's edge softmax normalizes attention scores over each destination vertex's
in-edges. On an FPGA this would be another accumulator pass; on TPU we fuse it
as two Pallas passes over the SAME (R, T, Eb) row-block tiling as the
gather-reduce accumulator:

  pass 1 (stats):    online max/sum-exp update per row block — the identical
                     recurrence flash attention uses across KV tiles:
                       m' = max(m, max_tile)
                       l' = l * exp(m - m') + sum_tile(exp(s - m'))
  pass 2 (normalize): w_e = exp(s_e - m[row]) / l[row]

Both passes are (Vb, Eb) broadcast-compare VPU work with one revisited output
block; stats stay resident in VMEM across a row block's tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["segment_softmax_pallas"]

_NEG = -1e30


def _stats_kernel(score_ref, dst_ref, val_ref, m_ref, l_ref, *, vb):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref[...], _NEG)
        l_ref[...] = jnp.zeros_like(l_ref[...])

    s = score_ref[0, 0, :]
    dstb = dst_ref[0, 0, :].astype(jnp.int32)
    val = val_ref[0, 0, :]
    eb = s.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (vb, eb), 0)
    onehot = (rows == dstb[None, :]) & val[None, :]
    s_mat = jnp.where(onehot, s[None, :], _NEG)  # (Vb, Eb)
    tile_max = s_mat.max(axis=1)
    m_old = m_ref[...]
    m_new = jnp.maximum(m_old, tile_max)
    # exp(m_old - m_new) with both at _NEG (untouched row) must stay 0-scale
    scale = jnp.where(m_old <= _NEG / 2, 0.0, jnp.exp(m_old - m_new))
    contrib = jnp.where(onehot, jnp.exp(s[None, :] - m_new[:, None]), 0.0).sum(axis=1)
    l_ref[...] = l_ref[...] * scale + contrib
    m_ref[...] = m_new


def _norm_kernel(score_ref, dst_ref, val_ref, m_ref, l_ref, out_ref, *, vb):
    s = score_ref[0, 0, :]
    dstb = dst_ref[0, 0, :].astype(jnp.int32)
    val = val_ref[0, 0, :]
    m = jnp.take(m_ref[...], dstb, axis=0)
    l = jnp.take(l_ref[...], dstb, axis=0)
    w = jnp.exp(s - m) / jnp.maximum(l, 1e-30)
    out_ref[0, 0, :] = jnp.where(val, w, 0.0)


@functools.partial(jax.jit, static_argnames=("num_rows", "vb", "interpret"))
def segment_softmax_pallas(
    scores: jnp.ndarray,  # (R, T, Eb) f32, tile layout
    dstb: jnp.ndarray,  # (R, T, Eb) int32 row-in-block
    valid: jnp.ndarray,  # (R, T, Eb) bool
    *,
    num_rows: int,
    vb: int,
    interpret: bool = True,
) -> jnp.ndarray:
    r_blocks, t_tiles, eb = scores.shape
    assert r_blocks * vb == num_rows
    edge_block = pl.BlockSpec((1, 1, eb), lambda r, t: (r, t, 0))
    row_block = pl.BlockSpec((vb,), lambda r, t: (r,))

    m, l = pl.pallas_call(
        functools.partial(_stats_kernel, vb=vb),
        grid=(r_blocks, t_tiles),
        in_specs=[edge_block, edge_block, edge_block],
        out_specs=[row_block, row_block],
        out_shape=[
            jax.ShapeDtypeStruct((num_rows,), jnp.float32),
            jax.ShapeDtypeStruct((num_rows,), jnp.float32),
        ],
        interpret=interpret,
    )(scores, dstb, valid)

    return pl.pallas_call(
        functools.partial(_norm_kernel, vb=vb),
        grid=(r_blocks, t_tiles),
        in_specs=[edge_block, edge_block, edge_block, row_block, row_block],
        out_specs=edge_block,
        out_shape=jax.ShapeDtypeStruct((r_blocks, t_tiles, eb), jnp.float32),
        interpret=interpret,
    )(scores, dstb, valid, m, l)
