"""Pallas TPU kernels for the framework's compute hot spots.

Each kernel package ships kernel.py (pl.pallas_call + BlockSpec), ops.py
(jit'd wrapper + host prep), ref.py (pure-jnp oracle used by tests).
"""
