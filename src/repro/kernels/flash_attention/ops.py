"""Jitted wrapper: flash attention (Pallas TPU target) or XLA reference."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import gqa_attention_reference

__all__ = ["flash_attention"]


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    scale: float | None = None,
    use_pallas: bool = False,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    if use_pallas:
        return flash_attention_pallas(
            q, k, v, causal=causal, scale=scale,
            block_q=block_q, block_k=block_k, interpret=interpret,
        )
    return gqa_attention_reference(q, k, v, causal=causal, scale=scale)
