from repro.kernels.flash_attention import ops, ref  # noqa: F401
from repro.kernels.flash_attention.kernel import flash_attention_pallas  # noqa: F401
from repro.kernels.flash_attention.ops import flash_attention  # noqa: F401
