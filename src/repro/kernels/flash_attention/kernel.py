"""Pallas TPU kernel: causal GQA flash attention (forward).

Grid (B*Hq, nQ, nKV), KV innermost ('arbitrary'); the (Bq, D) output
accumulator and the (Bq,) online-softmax stats live in VMEM scratch that
persists across a query block's KV tiles. Causal blocks strictly above the
diagonal are skipped (no compute, no accumulate) via pl.when — the TPU
analogue of not issuing work rather than masking it.

GQA is expressed in the K/V BlockSpec index maps (q-head -> kv-head =
h // group), so no repeated K/V materialization happens anywhere.

This kernel is the TPU-target implementation; the model stack's XLA
chunked-attention (models/layers.py) is its differentiable twin used for
dry-run lowering and training.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc, m_s, l_s, *, scale, bq, bk, causal, n_kv):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc[...])
        m_s[...] = jnp.full_like(m_s[...], _NEG)
        l_s[...] = jnp.zeros_like(l_s[...])

    # skip fully-masked blocks above the causal diagonal
    run = (not causal) or (ki * bk <= qi * bq + bq - 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (Bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (Bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (Bq, Bk)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, _NEG)
        m_old = m_s[...]
        m_new = jnp.maximum(m_old, s.max(axis=1))
        alpha = jnp.exp(m_old - m_new)  # m_old starts at _NEG -> exp() == 0
        p = jnp.exp(s - m_new[:, None])
        l_s[...] = l_s[...] * alpha + p.sum(axis=1)
        acc[...] = acc[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_s[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l_s[...], 1e-30)[:, None]).astype(
            o_ref.dtype
        )


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret", "scale")
)
def flash_attention_pallas(
    q: jnp.ndarray,  # (B, Hq, S, D)
    k: jnp.ndarray,  # (B, Hkv, S, D)
    v: jnp.ndarray,  # (B, Hkv, S, D)
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    scale = (d ** -0.5) if scale is None else scale
    n_q, n_kv = s // block_q, s // block_k
    grid = (b * hq, n_q, n_kv)

    q_spec = pl.BlockSpec(
        (1, 1, block_q, d), lambda bh, qi, ki: (bh // hq, bh % hq, qi, 0)
    )
    kv_spec = pl.BlockSpec(
        (1, 1, block_k, d), lambda bh, qi, ki: (bh // hq, (bh % hq) // group, ki, 0)
    )
    kern = functools.partial(
        _kernel, scale=scale, bq=block_q, bk=block_k, causal=causal, n_kv=n_kv
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("parallel", "parallel", "arbitrary"))
        )
        if not interpret
        else None,
    )(q, k, v)
