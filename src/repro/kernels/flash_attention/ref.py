"""Pure-jnp oracle: causal grouped-query attention (full materialization)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["gqa_attention_reference"]


def gqa_attention_reference(
    q: jnp.ndarray,  # (B, Hq, S, D)
    k: jnp.ndarray,  # (B, Hkv, S, D)
    v: jnp.ndarray,  # (B, Hkv, S, D)
    causal: bool = True,
    scale: float | None = None,
) -> jnp.ndarray:
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = (d ** -0.5) if scale is None else scale
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kk).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits, -1e30)
    w = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    w = w / w.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", w.astype(q.dtype), vv)
