"""Pure-jnp oracle for embedding_bag (gather + per-bag reduce).

JAX has no native EmbeddingBag; this reference (take + masked sum/mean) is
both the kernel oracle and the XLA fallback used inside models (DIN).
Padding ids are negative.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["embedding_bag_reference"]


def embedding_bag_reference(
    table: jnp.ndarray,  # (N, D)
    ids: jnp.ndarray,  # (B, L) int32, -1 = padding
    mode: str = "sum",  # 'sum' | 'mean'
    weights: jnp.ndarray | None = None,  # (B, L) per-id weights
) -> jnp.ndarray:
    valid = ids >= 0
    rows = jnp.take(table, jnp.maximum(ids, 0), axis=0)  # (B, L, D)
    w = valid.astype(table.dtype)
    if weights is not None:
        w = w * weights.astype(table.dtype)
    out = jnp.einsum("bl,bld->bd", w, rows)
    if mode == "mean":
        cnt = jnp.maximum(valid.sum(axis=1, keepdims=True), 1).astype(table.dtype)
        out = out / cnt
    return out
