from repro.kernels.embedding_bag import ops, ref  # noqa: F401
from repro.kernels.embedding_bag.kernel import embedding_bag_pallas  # noqa: F401
from repro.kernels.embedding_bag.ops import embedding_bag  # noqa: F401
