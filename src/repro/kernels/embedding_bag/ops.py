"""Jitted wrapper for EmbeddingBag: Pallas on TPU, XLA reference elsewhere."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.embedding_bag.kernel import embedding_bag_pallas
from repro.kernels.embedding_bag.ref import embedding_bag_reference

__all__ = ["embedding_bag"]


def embedding_bag(
    table: jnp.ndarray,
    ids: jnp.ndarray,
    *,
    mode: str = "sum",
    use_pallas: bool = False,
    bags_per_tile: int = 8,
    interpret: bool = True,
) -> jnp.ndarray:
    """(N, D) table x (B, L) ids (-1 pad) -> (B, D) bag embeddings."""
    if use_pallas:
        return embedding_bag_pallas(
            table, ids, mode=mode, bags_per_tile=bags_per_tile, interpret=interpret
        )
    return embedding_bag_reference(table, ids, mode=mode)
