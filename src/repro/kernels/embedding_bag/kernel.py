"""Pallas TPU kernel: EmbeddingBag = DMA row gather + per-bag reduce.

The embedding table stays in HBM (tables are 10^6..10^9 rows; only touched
rows should move). Bag ids are scalar-prefetched to SMEM; each grid step owns
one tile of bags and streams its rows HBM->VMEM with **double-buffered async
copies** (DMA latency hidden behind the accumulate of the previous row) — the
TPU translation of GraphScale's label-scratch-pad random reads, with the
crossbar's "requests overtake each other" freedom realized as in-flight DMAs.

Padding ids are negative: their copy is redirected to row 0 and the
accumulate is masked.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["embedding_bag_pallas"]


def _kernel(ids_ref, table_ref, out_ref, scratch, sem, *, bags_per_tile, mode):
    tile = pl.program_id(0)
    length = ids_ref.shape[1]
    base = tile * bags_per_tile

    def bag_body(k, _):
        b = base + k

        def row_id(i):
            return jnp.maximum(ids_ref[b, i], 0)

        # warm-up DMA for element 0 into slot 0
        pltpu.make_async_copy(table_ref.at[row_id(0)], scratch.at[0], sem.at[0]).start()

        def body(i, acc):
            slot = jax.lax.rem(i, 2)
            nxt = jax.lax.rem(i + 1, 2)

            @pl.when(i + 1 < length)
            def _prefetch():  # overlap next row's HBM fetch with this add
                pltpu.make_async_copy(
                    table_ref.at[row_id(i + 1)], scratch.at[nxt], sem.at[nxt]
                ).start()

            pltpu.make_async_copy(
                table_ref.at[row_id(i)], scratch.at[slot], sem.at[slot]
            ).wait()
            valid = ids_ref[b, i] >= 0
            return acc + jnp.where(valid, scratch[slot], jnp.zeros_like(acc))

        acc = jax.lax.fori_loop(
            0, length, body, jnp.zeros(scratch.shape[1:], scratch.dtype)
        )
        if mode == "mean":
            valid_cnt = jnp.zeros((), jnp.float32)

            def count(i, c):
                return c + (ids_ref[b, i] >= 0).astype(jnp.float32)

            valid_cnt = jax.lax.fori_loop(0, length, count, valid_cnt)
            acc = acc / jnp.maximum(valid_cnt, 1.0).astype(acc.dtype)
        out_ref[k, :] = acc
        return 0

    jax.lax.fori_loop(0, bags_per_tile, bag_body, 0)


@functools.partial(
    jax.jit, static_argnames=("mode", "bags_per_tile", "interpret")
)
def embedding_bag_pallas(
    table: jnp.ndarray,  # (N, D) in HBM
    ids: jnp.ndarray,  # (B, L) int32, -1 padding
    *,
    mode: str = "sum",
    bags_per_tile: int = 8,
    interpret: bool = True,
) -> jnp.ndarray:
    b, _ = ids.shape
    n, d = table.shape
    assert b % bags_per_tile == 0, (b, bags_per_tile)
    grid = (b // bags_per_tile,)
    kern = functools.partial(_kernel, bags_per_tile=bags_per_tile, mode=mode)
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,  # ids live in SMEM before the grid runs
            grid=grid,
            in_specs=[pl.BlockSpec(memory_space=pl.ANY)],  # table stays in HBM
            out_specs=pl.BlockSpec((bags_per_tile, d), lambda t, ids: (t, 0)),
            scratch_shapes=[
                pltpu.VMEM((2, d), table.dtype),  # double buffer
                pltpu.SemaphoreType.DMA((2,)),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        interpret=interpret,
    )(ids, table)
