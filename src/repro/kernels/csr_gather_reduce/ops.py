"""Host-side tile preparation + jitted wrapper for the accumulator kernel.

``prepare_tiles`` bins a (dst-sorted) edge bucket into (R, T, Eb) row-block
tiles at partition time (numpy). With ``split_threshold`` set it also SPLITS
hub rows whose edge count exceeds the threshold into multiple *virtual rows*
(even chunks) before LPT packing, so a single fat row no longer sets T for
the whole bucket; the kernel then reduces each virtual row independently
(level 1) and ``combine_split_rows`` merges the virtual-row partials back
into true rows with the problem's reduce op (level 2) — the TPU analogue of
the paper's two-level crossbar absorbing power-law skew. ``pack_edge_words``
bit-packs the (src, dstb, valid) index triple of each edge slot into the
compressed word stream the fused engine path reads (see ``kernel.py`` for the
word format and ``choose_src_bits`` for the 16/32-bit regime rule).
``gather_reduce`` runs the Pallas kernel; ``segment_reduce_rows`` is the
reduce-only variant used when contributions are already materialized (engine
fallback path).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.csr_gather_reduce.kernel import gather_reduce_pallas
from repro.kernels.csr_gather_reduce.ref import gather_reduce_reference

__all__ = [
    "TileLayout",
    "TilePlan",
    "PushTileLayout",
    "plan_tiles",
    "plan_push_tiles",
    "prepare_tiles",
    "prepare_push_tiles",
    "choose_src_bits",
    "pack_edge_words",
    "stack_packed_tiles",
    "stack_push_tiles",
    "tile_coverage_words",
    "split_map_from_row_orig",
    "combine_split_rows",
    "gather_reduce",
    "segment_reduce_rows",
]

# packed-word field bounds (see kernel.py "Compressed edge stream" docstring)
SRC16_LIMIT = 1 << 16  # gathered-block offsets that fit the 16-bit src field
DSTB16_LIMIT = 1 << 15  # row-block offsets that fit next to a 16-bit src


def choose_src_bits(gathered_size: int, vb: int) -> int:
    """Packed-word regime rule: 16-bit src iff every gathered-block offset fits
    16 bits AND the row-block offset fits the remaining 15 bits (bit 31 is the
    valid flag). Otherwise fall back to a two-word (32-bit src) stream."""
    return 16 if gathered_size <= SRC16_LIMIT and vb <= DSTB16_LIMIT else 32


def pack_edge_words(
    src: np.ndarray,  # (...,) int, gathered-block offsets in [0, G)
    dstb: np.ndarray,  # (...,) int, row offsets WITHIN the row block [0, vb)
    valid: np.ndarray,  # (...,) bool
    *,
    src_bits: int,
) -> tuple[np.ndarray, np.ndarray | None]:
    """Bit-pack edge-slot index triples into the compressed stream (numpy,
    partition time). Returns ``(word, word_hi)`` int32 arrays of ``src.shape``;
    ``word_hi`` is None in the 16-bit regime.

      src_bits=16: word    = valid<<31 | dstb<<16 | src          (4 B/edge)
      src_bits=32: word    = src                                  (8 B/edge)
                   word_hi = valid<<31 | dstb

    Padding slots (valid=False) pack to words with bit 31 clear, so the
    in-kernel validity test is simply ``word < 0`` (resp. ``word_hi < 0``).
    """
    src64 = np.asarray(src, dtype=np.int64)
    dstb64 = np.asarray(dstb, dtype=np.int64)
    # 32-bit bounds are the int32-REPRESENTABLE ranges: the kernel reads the
    # words back as int32, so src in [2^31, 2^32) would gather at a negative
    # index and dstb's bit 31 is the valid flag.
    src_limit = SRC16_LIMIT if src_bits == 16 else 1 << 31
    dstb_limit = DSTB16_LIMIT if src_bits == 16 else 1 << 31
    if src_bits not in (16, 32):
        raise ValueError(f"src_bits must be 16 or 32, got {src_bits}")
    if src64.size and not (0 <= int(src64.min()) and int(src64.max()) < src_limit):
        raise ValueError(
            f"src offsets [{int(src64.min())}, {int(src64.max())}] do not fit "
            f"the {src_bits}-bit field"
            + ("; use src_bits=32" if src_bits == 16 else "")
        )
    if dstb64.size and not (0 <= int(dstb64.min()) and int(dstb64.max()) < dstb_limit):
        raise ValueError(
            f"dstb offsets [{int(dstb64.min())}, {int(dstb64.max())}] do not fit "
            f"the {15 if src_bits == 16 else 31}-bit field"
            + ("; use src_bits=32" if src_bits == 16 else "")
        )
    src_u = src64.astype(np.uint32)
    dstb_u = dstb64.astype(np.uint32)
    vbit = np.asarray(valid, dtype=np.uint32) << 31
    if src_bits == 16:
        return (vbit | (dstb_u << 16) | src_u).view(np.int32), None
    return src_u.view(np.int32), (vbit | dstb_u).view(np.int32)


def stack_packed_tiles(
    layouts: list[TileLayout], *, src_bits: int
) -> tuple[np.ndarray, np.ndarray | None, np.ndarray, np.ndarray | None]:
    """Pack each layout's (src, dstb, valid) triple and stack to one
    uniform-(R, T) compressed stream: ``(word, word_hi, counts, weights)``
    with shapes (n, R_max, T_max, Eb) / (n, R_max). Layouts shorter than
    R_max / T_max are padded with all-invalid words that ``counts`` (0 for
    padded row blocks) tells the kernel to skip. The
    single source of truth for the stream layout the engine, benchmarks, and
    tests consume."""
    n = len(layouts)
    eb = layouts[0].src.shape[2]
    # hub-row splitting can grow R per bucket; pad both R and T to the max
    # (extra blocks have counts 0, so the kernel's early-out skips them).
    r_max = max(t.src.shape[0] for t in layouts)
    t_max = max(t.src.shape[1] for t in layouts)
    word = np.zeros((n, r_max, t_max, eb), np.int32)
    word_hi = np.zeros((n, r_max, t_max, eb), np.int32) if src_bits == 32 else None
    counts = np.zeros((n, r_max), np.int32)
    any_w = any(t.weights is not None for t in layouts)
    weights = np.zeros((n, r_max, t_max, eb), np.float32) if any_w else None
    for i, t in enumerate(layouts):
        rr, tt = t.src.shape[:2]
        w0, w1 = pack_edge_words(t.src, t.dstb, t.valid, src_bits=src_bits)
        word[i, :rr, :tt] = w0
        if word_hi is not None:
            word_hi[i, :rr, :tt] = w1
        counts[i, :rr] = t.tile_counts
        if weights is not None and t.weights is not None:
            weights[i, :rr, :tt] = t.weights
    return word, word_hi, counts, weights


def tile_coverage_words(
    word: np.ndarray,  # (..., Eb) int32 packed edge words (one tile per row)
    word_hi: np.ndarray | None,  # (..., Eb) int32 in the 32-bit regime
    *,
    src_bits: int,
    p: int,
    sub_size: int,
) -> np.ndarray:
    """Per-tile source-coverage bitmaps for frontier-aware dynamic skipping.

    Decodes each tile's packed words (numpy, partition time — the ONLY place
    the compressed stream is ever unpacked outside the kernel) and records, at
    frontier-WORD granularity, which 32-source groups of the phase's gathered
    block the tile reads: coverage bit ``j`` is set iff some valid edge's
    gathered src index lands in frontier word ``j`` (``j = src_core * Ws +
    (src mod sub_size) // 32`` with ``Ws = ceil(sub_size / 32)`` — the layout
    contract shared with ``core.frontier_words``). Returns (..., Wc) uint32
    with ``Wc = ceil(p * Ws / 32)``: 32x smaller than per-source bitmaps, and
    conservative only — a tile whose coverage misses every live frontier word
    provably reads no changed source. All-invalid (padding) tiles get
    all-zero coverage, so they stay dead under any frontier.
    """
    word = np.asarray(word)
    ws = -(-sub_size // 32)
    wc = -(-(p * ws) // 32)
    if src_bits == 16:
        valid = word < 0
        src = (word.view(np.uint32) & np.uint32(0xFFFF)).astype(np.int64)
    else:
        valid = np.asarray(word_hi) < 0
        src = word.view(np.uint32).astype(np.int64)
    # gathered index -> frontier-word slot in the phase's gathered block
    widx = (src // sub_size) * ws + (src % sub_size) // 32
    lead = word.shape[:-1]
    cov = np.zeros(lead + (wc,), dtype=np.uint32)
    flat = cov.reshape(-1, wc)
    tile_of_slot = np.repeat(np.arange(flat.shape[0]), word.shape[-1])
    keep = valid.reshape(-1)
    ti, wsel = tile_of_slot[keep], widx.reshape(-1)[keep]
    np.bitwise_or.at(
        flat,
        (ti, wsel // 32),
        np.left_shift(np.uint32(1), (wsel % 32).astype(np.uint32)),
    )
    return cov


@dataclasses.dataclass(frozen=True)
class PushTileLayout:
    """One bucket's CSC-style push (scatter) tiles, binned by SOURCE block.

    The pull layout bins edges by destination row block so the kernel's
    accumulator is a pure function of the grid; the push layout bins the SAME
    edge set by source block ``b = gidx // block_sources`` so a NARROW
    frontier maps to few tiles: every out-edge of the 32-aligned source group
    ``[b * bs, (b+1) * bs)`` lives in block b's tiles, and a frontier that
    touches no source of a block never streams it. ``dst`` carries the FULL
    local destination index in [0, num_rows) — the scatter kernel's output is
    the whole per-core label row, so there is no row-block offset to strip.
    """

    src: np.ndarray  # (B, Tp, Eb) int32 gathered-block offsets
    dst: np.ndarray  # (B, Tp, Eb) int32 FULL local dst in [0, num_rows)
    valid: np.ndarray  # (B, Tp, Eb) bool
    weights: np.ndarray | None  # (B, Tp, Eb) f32
    tile_counts: np.ndarray  # (B,) int32 real edge tiles per source block
    block_sources: int
    num_rows: int


def prepare_push_tiles(
    src_gidx: np.ndarray,  # (E,) int32 gathered-block offsets
    dst_lidx: np.ndarray,  # (E,) int32 local dst in [0, num_rows)
    valid: np.ndarray,  # (E,) bool
    *,
    gathered_size: int,
    block_sources: int,
    num_rows: int,
    eb: int,
    weights: np.ndarray | None = None,
) -> PushTileLayout:
    """Bin one (core, phase) bucket's edges by source block for the push
    (scatter) stream. ``block_sources`` must be a multiple of 32 so every
    block covers whole frontier words and the coverage-word activity test
    (``tile_coverage_words`` on the push stream) is exact at block
    granularity. Edges inside a block are ordered (src, dst) — the order is
    irrelevant for the min/or reduces the push path admits (associative,
    commutative, idempotent), but a deterministic layout keeps partitions
    reproducible."""
    assert block_sources % 32 == 0, block_sources
    keep = np.asarray(valid)
    src = np.asarray(src_gidx)[keep].astype(np.int64)
    dst = np.asarray(dst_lidx)[keep].astype(np.int64)
    w = np.asarray(weights)[keep] if weights is not None else None
    n_blocks = max(1, -(-gathered_size // block_sources))
    blk = src // block_sources
    order = np.lexsort((dst, src))  # blk is src // bs, so this is block-major
    src, dst, blk = src[order], dst[order], blk[order]
    if w is not None:
        w = w[order]
    counts = np.bincount(blk, minlength=n_blocks)
    t_tiles = max(1, int(-(-counts.max() // eb))) if counts.size else 1
    src_t = np.zeros((n_blocks, t_tiles, eb), dtype=np.int32)
    dst_t = np.zeros((n_blocks, t_tiles, eb), dtype=np.int32)
    val_t = np.zeros((n_blocks, t_tiles, eb), dtype=bool)
    w_t = (
        np.zeros((n_blocks, t_tiles, eb), dtype=np.float32)
        if w is not None
        else None
    )
    starts = np.zeros(n_blocks + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    for b in range(n_blocks):
        s, e = int(starts[b]), int(starts[b + 1])
        n = e - s
        src_t[b].reshape(-1)[:n] = src[s:e]
        dst_t[b].reshape(-1)[:n] = dst[s:e]
        val_t[b].reshape(-1)[:n] = True
        if w_t is not None:
            w_t[b].reshape(-1)[:n] = w[s:e]
    return PushTileLayout(
        src=src_t, dst=dst_t, valid=val_t, weights=w_t,
        tile_counts=(-(-counts // eb)).astype(np.int32),
        block_sources=block_sources, num_rows=num_rows,
    )


def stack_push_tiles(
    layouts: list[PushTileLayout], *, src_bits: int
) -> tuple[np.ndarray, np.ndarray | None, np.ndarray, np.ndarray | None]:
    """Pack + stack per-bucket push layouts to one uniform (n, B, Tp, Eb)
    compressed scatter stream: ``(word, word_hi, counts, weights)``. Every
    bucket shares B (the gathered block size is phase-invariant); Tp is
    padded to the max, and ``counts`` tells the kernel which tiles are real —
    the exact mirror of ``stack_packed_tiles`` for the pull stream. The
    packed ``dstb`` field holds the FULL local destination row, so the
    16-bit regime additionally requires ``num_rows <= 2^15`` (the caller
    picks ``src_bits`` via ``choose_src_bits(gathered_size, num_rows)``)."""
    n = len(layouts)
    eb = layouts[0].src.shape[2]
    b_max = max(t.src.shape[0] for t in layouts)
    t_max = max(t.src.shape[1] for t in layouts)
    word = np.zeros((n, b_max, t_max, eb), np.int32)
    word_hi = np.zeros((n, b_max, t_max, eb), np.int32) if src_bits == 32 else None
    counts = np.zeros((n, b_max), np.int32)
    any_w = any(t.weights is not None for t in layouts)
    weights = np.zeros((n, b_max, t_max, eb), np.float32) if any_w else None
    for i, t in enumerate(layouts):
        bb, tt = t.src.shape[:2]
        w0, w1 = pack_edge_words(t.src, t.dst, t.valid, src_bits=src_bits)
        word[i, :bb, :tt] = w0
        if word_hi is not None:
            word_hi[i, :bb, :tt] = w1
        counts[i, :bb] = t.tile_counts
        if weights is not None and t.weights is not None:
            weights[i, :bb, :tt] = t.weights
    return word, word_hi, counts, weights


@dataclasses.dataclass(frozen=True)
class TileLayout:
    """(R, T, Eb) row-block binned edges; padding slots have valid=False.

    With hub-row splitting engaged (``row_orig`` set) the R*vb kernel-output
    positions hold VIRTUAL rows: a natural row above the split threshold owns
    several of them, each reduced independently by the kernel, and R may
    exceed ``num_rows / vb``. ``row_orig`` maps every packed position back to
    its natural row (-1 = spare slot, holds the reduce identity); the
    second-level combine (``combine_split_rows``) folds the partials together.
    ``row_pos`` and ``row_orig`` are mutually exclusive.
    """

    src: np.ndarray  # (R, T, Eb) int32
    dstb: np.ndarray  # (R, T, Eb) int32 in [0, vb)
    valid: np.ndarray  # (R, T, Eb) bool
    weights: np.ndarray | None  # (R, T, Eb) f32
    vb: int
    num_rows: int  # NATURAL rows (combine output size); packed rows = R * vb
    # slot -> index into the ORIGINAL (pre-binning) edge arrays, 0 on padding.
    # Lets runtime-traced per-edge values (e.g. GAT scores) be laid out into
    # tile order with one static gather.
    gather_idx: np.ndarray | None = None  # (R, T, Eb) int64
    # degree-aware packing: natural row i's reduction lives at kernel-output
    # position row_pos[i] (None = identity layout). Undo with out[row_pos].
    row_pos: np.ndarray | None = None  # (num_rows,) int32
    # real edge tiles per row block: ceil(real_edges[r] / Eb). Tiles with
    # t >= tile_counts[r] are all-padding; the fused kernel skips them.
    tile_counts: np.ndarray | None = None  # (R,) int32
    # hub-row splitting (level-2 reduce): packed position -> natural row
    # (-1 = spare slot carrying the reduce identity). None = no row was split.
    row_orig: np.ndarray | None = None  # (R * vb,) int32
    num_split_rows: int = 0  # natural rows split into > 1 virtual rows
    # T this bucket would have needed WITHOUT splitting (== own T when no row
    # was split) — the denominator of the t_max_reduction metric.
    t_tiles_unsplit: int = 0

    @property
    def tile_padding_ratio(self) -> float:
        total = self.valid.size
        return 1.0 - float(self.valid.sum()) / max(total, 1)


def _balance_row_blocks(row_counts: np.ndarray, r_blocks: int, vb: int) -> np.ndarray:
    """LPT row->block assignment: rows sorted by in-degree, each placed in the
    least-loaded block with a free slot. Minimizes the max per-block edge count
    so one hub row no longer inflates T for EVERY row block. Returns row_pos
    (natural row -> packed output position)."""
    order = np.argsort(-row_counts, kind="stable")
    load = np.zeros(r_blocks, dtype=np.int64)
    slots = np.zeros(r_blocks, dtype=np.int64)
    row_pos = np.empty(row_counts.shape[0], dtype=np.int32)
    full = np.int64(np.iinfo(np.int64).max)
    for row in order:
        cand = np.where(slots < vb, load, full)
        b = int(cand.argmin())
        row_pos[row] = b * vb + slots[b]
        slots[b] += 1
        load[b] += row_counts[row]
    return row_pos


def _lpt_max_load(row_counts: np.ndarray, r_blocks: int, vb: int) -> int:
    """Max per-block edge load the LPT packer achieves WITHOUT splitting."""
    if r_blocks <= 1:
        return int(row_counts.sum())
    pos = _balance_row_blocks(row_counts, r_blocks, vb)
    loads = np.bincount(pos // vb, weights=row_counts.astype(np.float64),
                        minlength=r_blocks)
    return int(loads.max())


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """Shape + row-map decisions of one bucket's tile layout, computed from
    the per-row edge counts ALONE — no edge data needed.

    This is the single source of truth for everything about a bucket's layout
    that does not depend on which concrete edges fill the slots: the
    out-of-core streaming partitioner (``partition_2d_streaming``) calls
    ``plan_tiles`` during its counting pass to pre-size the stacked packed
    buffers before any edge is placed, and ``prepare_tiles`` consumes the
    same plan to place edges — so the two paths cannot disagree on shapes,
    split chunking, or row placement. A natural row with ``count`` edges and
    ``k = n_chunks[row]`` virtual rows splits into even chunks whose sizes
    are fully determined by (count, k): chunk ``c`` holds the edges ``j``
    with ``j * k // count == c``, i.e. ``ceil((c+1)*count/k) -
    ceil(c*count/k)`` edges — what ``virt_counts`` records.
    """

    r_blocks: int  # row blocks (>= num_rows/vb when virtual rows need room)
    t_tiles: int  # max real edge tiles over the row blocks
    t_tiles_unsplit: int  # T without splitting (== t_tiles when no split)
    num_split_rows: int  # natural rows split into > 1 virtual rows
    s_max: int  # split-map width: max virtual rows per natural row (>= 1)
    # exactly one of row_pos / row_orig is set when the layout is non-trivial:
    row_pos: np.ndarray | None  # (num_rows,) natural row -> packed position
    row_orig: np.ndarray | None  # (r_blocks * vb,) packed position -> row
    # split-mode edge-placement inputs (None when no row split):
    n_chunks: np.ndarray | None  # (num_rows,) virtual rows per natural row
    virt_base: np.ndarray | None  # (num_rows,) first virtual-row id per row
    virt_pos: np.ndarray | None  # (num_virtual,) virtual row -> packed pos


def plan_tiles(
    row_counts: np.ndarray,  # (num_rows,) real edges per natural row
    *,
    num_rows: int,
    vb: int,
    eb: int,
    balance_rows: bool = False,
    split_threshold: int | None = None,
) -> TilePlan:
    """Decide one bucket's tile-layout shape from row counts alone.

    Mirrors (and is consumed by) ``prepare_tiles``: the split decision, even
    chunking, LPT placement, and the resulting (R, T) are pure functions of
    the per-row counts, so a streaming builder can size its output buffers in
    a counting pass and the edge-placement pass is guaranteed to fit."""
    assert num_rows % vb == 0, (num_rows, vb)
    r_base = num_rows // vb
    row_counts = np.asarray(row_counts, dtype=np.int64)
    thr = max(int(split_threshold), 1) if split_threshold is not None else None
    do_split = (
        balance_rows and thr is not None and bool((row_counts > thr).any())
    )
    if do_split:
        n_chunks = np.maximum(1, -(-row_counts // thr)).astype(np.int64)
        num_split_rows = int((n_chunks > 1).sum())
        num_virtual = int(n_chunks.sum())
        r_blocks = max(r_base, -(-num_virtual // vb))
        t_unsplit = max(1, -(-_lpt_max_load(row_counts, r_base, vb) // eb))
        virt_base = np.cumsum(n_chunks) - n_chunks
        virt_orig = np.repeat(np.arange(num_rows, dtype=np.int64), n_chunks)
        # even-chunk sizes from (count, k) alone: chunk c of a row with count
        # edges and k chunks holds ceil((c+1)*count/k) - ceil(c*count/k).
        vidx = np.arange(num_virtual, dtype=np.int64) - virt_base[virt_orig]
        cnt, k = row_counts[virt_orig], n_chunks[virt_orig]
        virt_counts = (-(-((vidx + 1) * cnt) // k)) - (-(-(vidx * cnt) // k))
        pos_v = _balance_row_blocks(virt_counts, r_blocks, vb)
        row_orig = np.full(r_blocks * vb, -1, dtype=np.int32)
        row_orig[pos_v] = virt_orig
        loads = np.bincount(
            pos_v // vb, weights=virt_counts.astype(np.float64),
            minlength=r_blocks,
        )
        t_tiles = max(1, int(-(-int(loads.max()) // eb)))
        return TilePlan(
            r_blocks=r_blocks, t_tiles=t_tiles, t_tiles_unsplit=t_unsplit,
            num_split_rows=num_split_rows, s_max=int(n_chunks.max()),
            row_pos=None, row_orig=row_orig, n_chunks=n_chunks,
            virt_base=virt_base, virt_pos=pos_v,
        )
    if balance_rows and r_base > 1:
        row_pos = _balance_row_blocks(row_counts, r_base, vb)
        loads = np.bincount(
            row_pos // vb, weights=row_counts.astype(np.float64),
            minlength=r_base,
        )
        t_tiles = max(1, int(-(-int(loads.max()) // eb)))
    else:
        row_pos = None
        loads = row_counts.reshape(r_base, vb).sum(axis=1)
        t_tiles = max(1, int(-(-int(loads.max()) // eb))) if loads.size else 1
    return TilePlan(
        r_blocks=r_base, t_tiles=t_tiles, t_tiles_unsplit=t_tiles,
        num_split_rows=0, s_max=1, row_pos=row_pos, row_orig=None,
        n_chunks=None, virt_base=None, virt_pos=None,
    )


def plan_push_tiles(
    src_counts: np.ndarray,  # (gathered_size,) real edges per gathered source
    *,
    gathered_size: int,
    block_sources: int,
    eb: int,
) -> tuple[int, int]:
    """Push-stream shape from per-source counts alone: ``(B, Tp)`` matching
    what ``prepare_push_tiles`` will produce for the same bucket."""
    n_blocks = max(1, -(-gathered_size // block_sources))
    src_counts = np.asarray(src_counts, dtype=np.int64)
    pad = n_blocks * block_sources - src_counts.shape[0]
    if pad:
        src_counts = np.concatenate([src_counts, np.zeros(pad, np.int64)])
    counts = src_counts.reshape(n_blocks, block_sources).sum(axis=1)
    t_tiles = max(1, int(-(-int(counts.max()) // eb))) if counts.size else 1
    return n_blocks, t_tiles


def prepare_tiles(
    src_gidx: np.ndarray,  # (E,) int32
    dst_lidx: np.ndarray,  # (E,) int32, sorted ascending
    valid: np.ndarray,  # (E,) bool
    num_rows: int,
    vb: int,
    eb: int,
    weights: np.ndarray | None = None,
    *,
    balance_rows: bool = False,
    split_threshold: int | None = None,
    plan: TilePlan | None = None,
) -> TileLayout:
    """Bin one (dst-sorted) edge bucket into (R, T, Eb) row-block tiles.

    ``split_threshold`` (requires ``balance_rows``: virtual rows only help
    when the LPT packer can spread them) caps the edge count of any single
    kernel-output row: a natural row with more edges is split into
    ``ceil(count / threshold)`` even chunks, each a virtual row the packer
    places independently — R grows past ``num_rows / vb`` when the virtual
    rows need the slots. The returned layout then carries ``row_orig`` and
    the caller must apply the second-level combine (``combine_split_rows``).
    When no row exceeds the threshold the output is byte-for-byte identical
    to the unsplit layout.

    ``plan``: a ``TilePlan`` previously computed by ``plan_tiles`` for THIS
    bucket's row counts under the same (vb, eb, balance_rows,
    split_threshold) — skips the redundant re-plan (the LPT pass is the
    expensive part at large vpc). The caller owns the consistency; the
    t_tiles assertion below catches a mismatched plan.
    """
    assert num_rows % vb == 0, (num_rows, vb)
    src_gidx = np.asarray(src_gidx)
    dst_lidx = np.asarray(dst_lidx)
    valid = np.asarray(valid)

    keep = valid
    orig_idx = np.nonzero(keep)[0]
    src_r = src_gidx[keep]
    dst_r = dst_lidx[keep]
    w_r = weights[keep] if weights is not None else None
    row_counts = np.bincount(dst_r, minlength=num_rows)
    if plan is None:
        plan = plan_tiles(
            row_counts, num_rows=num_rows, vb=vb, eb=eb,
            balance_rows=balance_rows, split_threshold=split_threshold,
        )
    r_blocks = plan.r_blocks
    if plan.row_orig is not None:
        # level-1 layout over VIRTUAL rows: chunk c of natural row v holds
        # the edges j with j * n_chunks[v] // count[v] == c (even split, so
        # chunk sizes differ by at most 1 and never exceed the threshold).
        row_starts = np.cumsum(row_counts) - row_counts
        pos_in_row = np.arange(dst_r.shape[0], dtype=np.int64) - row_starts[dst_r]
        chunk = pos_in_row * plan.n_chunks[dst_r] // np.maximum(row_counts[dst_r], 1)
        vrow = plan.virt_base[dst_r] + chunk
        pdst = plan.virt_pos[vrow]
        order = np.argsort(pdst // vb, kind="stable")
        src_r, pdst, orig_idx = src_r[order], pdst[order], orig_idx[order]
        if w_r is not None:
            w_r = w_r[order]
    elif plan.row_pos is not None:
        pdst = plan.row_pos[dst_r]
        # packed positions are not sorted; regroup by block, keeping the
        # original (dst-sorted) edge order inside each block (stable).
        order = np.argsort(pdst // vb, kind="stable")
        src_r, pdst, orig_idx = src_r[order], pdst[order], orig_idx[order]
        if w_r is not None:
            w_r = w_r[order]
    else:
        pdst = dst_r
    block = pdst // vb
    counts = np.bincount(block, minlength=r_blocks)
    t_tiles = max(1, int(-(-counts.max() // eb))) if counts.size else 1
    assert t_tiles == plan.t_tiles, (t_tiles, plan.t_tiles)
    src_t = np.zeros((r_blocks, t_tiles, eb), dtype=np.int32)
    dst_t = np.zeros((r_blocks, t_tiles, eb), dtype=np.int32)
    val_t = np.zeros((r_blocks, t_tiles, eb), dtype=bool)
    gat_t = np.zeros((r_blocks, t_tiles, eb), dtype=np.int64)
    w_t = np.zeros((r_blocks, t_tiles, eb), dtype=np.float32) if w_r is not None else None
    starts = np.zeros(r_blocks + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    for r in range(r_blocks):
        s, e = int(starts[r]), int(starts[r + 1])
        n = e - s
        src_t[r].reshape(-1)[:n] = src_r[s:e]
        dst_t[r].reshape(-1)[:n] = pdst[s:e] - r * vb
        val_t[r].reshape(-1)[:n] = True
        gat_t[r].reshape(-1)[:n] = orig_idx[s:e]
        if w_t is not None:
            w_t[r].reshape(-1)[:n] = w_r[s:e]
    return TileLayout(
        src=src_t, dstb=dst_t, valid=val_t, weights=w_t, vb=vb,
        num_rows=num_rows, gather_idx=gat_t, row_pos=plan.row_pos,
        tile_counts=(-(-counts // eb)).astype(np.int32),
        row_orig=plan.row_orig, num_split_rows=plan.num_split_rows,
        t_tiles_unsplit=plan.t_tiles_unsplit,
    )


def split_map_from_row_orig(row_orig: np.ndarray, num_rows: int) -> np.ndarray:
    """Invert a packed-position -> natural-row map into the gather form the
    second-level combine consumes: ``(num_rows, S_max)`` packed positions per
    natural row, padded with -1. Every natural row owns at least one virtual
    row (empty rows get one whose kernel output is the reduce identity), so
    column 0 is always a real position."""
    row_orig = np.asarray(row_orig)
    pos = np.nonzero(row_orig >= 0)[0]
    orig = row_orig[pos].astype(np.int64)
    order = np.argsort(orig, kind="stable")
    orig_s, pos_s = orig[order], pos[order]
    counts = np.bincount(orig_s, minlength=num_rows)
    assert counts.min() >= 1, "every natural row must own >= 1 virtual row"
    s_max = int(counts.max())
    starts = np.cumsum(counts) - counts
    rank = np.arange(pos_s.shape[0], dtype=np.int64) - starts[orig_s]
    out = np.full((num_rows, s_max), -1, dtype=np.int32)
    out[orig_s, rank] = pos_s
    return out


def combine_split_rows(
    reduced: jnp.ndarray,  # (..., P[, K]) level-1 kernel output, packed rows
    split_map: jnp.ndarray,  # (..., num_rows, S) packed positions, -1 = pad
    *,
    kind: str,  # 'min' | 'sum' | 'or' — the problem's reduce UDF
    identity: float,  # the SAME problem's identity (INF for min, 0 for sum/or)
) -> jnp.ndarray:
    """Level-2 reduce: fold virtual-row partials into natural rows.

    Must use the problem's own reduce op and identity: padding entries
    (-1) contribute ``identity``, so a min-problem sees INF (never 0) and a
    sum-problem sees exactly 0.0 — a split row is neither double-counted nor
    corrupted. Gather-based (static shapes, S_max is small), so min problems
    stay bit-identical to the oracle: min over partial mins == total min.

    Lane-batched problems (docs/tile_layout.md §8) pass ``reduced`` with a
    trailing lane axis (..., P, K); the fold is over the packed-row axis and
    broadcasts per lane — one gather serves all K columns.
    """
    *lead, v, s = split_map.shape
    idx = jnp.maximum(split_map, 0)
    ident = jnp.asarray(identity, reduced.dtype)
    if reduced.ndim == split_map.ndim:  # trailing lane axis (..., P, K)
        k = reduced.shape[-1]
        vals = jnp.take_along_axis(
            reduced, idx.reshape(*lead, v * s, 1), axis=-2
        )  # (..., v*s, K) — the size-1 index lane broadcasts over K
        vals = vals.reshape(*lead, v, s, k)
        vals = jnp.where(split_map[..., None] >= 0, vals, ident)
        if kind == "min":
            return jnp.min(vals, axis=-2)
        if kind == "sum":
            return jnp.sum(vals, axis=-2)
        out = jnp.full(vals.shape[:-2] + (k,), ident, reduced.dtype)
        for j in range(s):  # S_max is small & static: unrolled word-OR fold
            out = out | vals[..., j, :]
        return out
    vals = jnp.take_along_axis(reduced, idx.reshape(*lead, v * s), axis=-1)
    vals = jnp.where(split_map >= 0, vals.reshape(split_map.shape), ident)
    if kind == "or":
        out = jnp.full(split_map.shape[:-1], ident, reduced.dtype)
        for j in range(s):
            out = out | vals[..., j]
        return out
    return jnp.min(vals, axis=-1) if kind == "min" else jnp.sum(vals, axis=-1)


def gather_reduce(
    payload: jnp.ndarray,
    tiles: TileLayout,
    *,
    kind: str = "min",
    edge_op: str = "none",
    identity: float = 0.0,
    interpret: bool = True,
    use_reference: bool = False,
) -> jnp.ndarray:
    """Run the accumulator over one (core, phase) bucket."""
    # with hub-row splitting the kernel reduces PACKED (virtual) rows — may
    # be more than the natural num_rows — and level 2 folds them back.
    packed_rows = tiles.src.shape[0] * tiles.vb
    if use_reference:
        r_blocks = tiles.src.shape[0]
        block_base = np.arange(r_blocks, dtype=np.int32)[:, None, None] * tiles.vb
        ref_w = None
        if edge_op == "add":
            # the kernel treats missing weights as unit weights; the reference
            # skips the add when weights is None, so make units explicit
            ref_w = (
                jnp.asarray(tiles.weights).reshape(-1)
                if tiles.weights is not None
                else jnp.ones(tiles.src.size, jnp.float32)
            )
        out = gather_reduce_reference(
            payload,
            jnp.asarray(tiles.src).reshape(-1),
            jnp.asarray(tiles.dstb + block_base).reshape(-1),
            jnp.asarray(tiles.valid).reshape(-1),
            packed_rows,
            kind=kind,
            identity=identity,
            weights=ref_w,
        )
    else:
        out = gather_reduce_pallas(
            payload,
            jnp.asarray(tiles.src),
            jnp.asarray(tiles.dstb),
            jnp.asarray(tiles.valid),
            jnp.asarray(tiles.weights) if tiles.weights is not None else None,
            num_rows=packed_rows,
            vb=tiles.vb,
            kind=kind,
            edge_op=edge_op,
            identity=identity,
            interpret=interpret,
        )
    if tiles.row_orig is not None:  # level-2 reduce over virtual-row partials
        sm = split_map_from_row_orig(tiles.row_orig, tiles.num_rows)
        out = combine_split_rows(out, jnp.asarray(sm), kind=kind, identity=identity)
    elif tiles.row_pos is not None:  # undo degree-aware row packing
        out = jnp.take(out, jnp.asarray(tiles.row_pos), axis=0)
    return out


def segment_reduce_rows(
    contrib: jnp.ndarray,  # (p, E) pre-mapped contributions (identity-padded)
    dst: jnp.ndarray,  # (p, E) sorted local rows
    *,
    num_rows: int,
    kind: str,
    identity: float,
    interpret: bool = True,
) -> jnp.ndarray:
    """Reduce-only helper for already-materialized contributions (traced dst
    => no host binning). The engine no longer routes through this — its XLA
    oracle calls segment ops directly and its primary path is the fused
    ``gather_reduce_cores_pallas`` — kept as public API for model code."""
    def seg(c, d):
        if kind == "min":
            return jax.ops.segment_min(c, d, num_segments=num_rows, indices_are_sorted=True)
        return jax.ops.segment_sum(c, d, num_segments=num_rows, indices_are_sorted=True)

    return jax.vmap(seg)(contrib, dst)
