"""Host-side tile preparation + jitted wrapper for the accumulator kernel.

``prepare_tiles`` bins a (dst-sorted) edge bucket into (R, T, Eb) row-block
tiles at partition time (numpy). ``pack_edge_words`` bit-packs the
(src, dstb, valid) index triple of each edge slot into the compressed word
stream the fused engine path reads (see ``kernel.py`` for the word format and
``choose_src_bits`` for the 16/32-bit regime rule). ``gather_reduce`` runs the
Pallas kernel; ``segment_reduce_rows`` is the reduce-only variant used when
contributions are already materialized (engine fallback path).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.csr_gather_reduce.kernel import gather_reduce_pallas
from repro.kernels.csr_gather_reduce.ref import gather_reduce_reference

__all__ = [
    "TileLayout",
    "prepare_tiles",
    "choose_src_bits",
    "pack_edge_words",
    "stack_packed_tiles",
    "gather_reduce",
    "segment_reduce_rows",
]

# packed-word field bounds (see kernel.py "Compressed edge stream" docstring)
SRC16_LIMIT = 1 << 16  # gathered-block offsets that fit the 16-bit src field
DSTB16_LIMIT = 1 << 15  # row-block offsets that fit next to a 16-bit src


def choose_src_bits(gathered_size: int, vb: int) -> int:
    """Packed-word regime rule: 16-bit src iff every gathered-block offset fits
    16 bits AND the row-block offset fits the remaining 15 bits (bit 31 is the
    valid flag). Otherwise fall back to a two-word (32-bit src) stream."""
    return 16 if gathered_size <= SRC16_LIMIT and vb <= DSTB16_LIMIT else 32


def pack_edge_words(
    src: np.ndarray,  # (...,) int, gathered-block offsets in [0, G)
    dstb: np.ndarray,  # (...,) int, row offsets WITHIN the row block [0, vb)
    valid: np.ndarray,  # (...,) bool
    *,
    src_bits: int,
) -> tuple[np.ndarray, np.ndarray | None]:
    """Bit-pack edge-slot index triples into the compressed stream (numpy,
    partition time). Returns ``(word, word_hi)`` int32 arrays of ``src.shape``;
    ``word_hi`` is None in the 16-bit regime.

      src_bits=16: word    = valid<<31 | dstb<<16 | src          (4 B/edge)
      src_bits=32: word    = src                                  (8 B/edge)
                   word_hi = valid<<31 | dstb

    Padding slots (valid=False) pack to words with bit 31 clear, so the
    in-kernel validity test is simply ``word < 0`` (resp. ``word_hi < 0``).
    """
    src64 = np.asarray(src, dtype=np.int64)
    dstb64 = np.asarray(dstb, dtype=np.int64)
    # 32-bit bounds are the int32-REPRESENTABLE ranges: the kernel reads the
    # words back as int32, so src in [2^31, 2^32) would gather at a negative
    # index and dstb's bit 31 is the valid flag.
    src_limit = SRC16_LIMIT if src_bits == 16 else 1 << 31
    dstb_limit = DSTB16_LIMIT if src_bits == 16 else 1 << 31
    if src_bits not in (16, 32):
        raise ValueError(f"src_bits must be 16 or 32, got {src_bits}")
    if src64.size and not (0 <= int(src64.min()) and int(src64.max()) < src_limit):
        raise ValueError(
            f"src offsets [{int(src64.min())}, {int(src64.max())}] do not fit "
            f"the {src_bits}-bit field"
            + ("; use src_bits=32" if src_bits == 16 else "")
        )
    if dstb64.size and not (0 <= int(dstb64.min()) and int(dstb64.max()) < dstb_limit):
        raise ValueError(
            f"dstb offsets [{int(dstb64.min())}, {int(dstb64.max())}] do not fit "
            f"the {15 if src_bits == 16 else 31}-bit field"
            + ("; use src_bits=32" if src_bits == 16 else "")
        )
    src_u = src64.astype(np.uint32)
    dstb_u = dstb64.astype(np.uint32)
    vbit = np.asarray(valid, dtype=np.uint32) << 31
    if src_bits == 16:
        return (vbit | (dstb_u << 16) | src_u).view(np.int32), None
    return src_u.view(np.int32), (vbit | dstb_u).view(np.int32)


def stack_packed_tiles(
    layouts: list[TileLayout], *, src_bits: int
) -> tuple[np.ndarray, np.ndarray | None, np.ndarray, np.ndarray | None]:
    """Pack each layout's (src, dstb, valid) triple and stack to one
    uniform-T compressed stream: ``(word, word_hi, counts, weights)`` with
    shapes (n, R, T_max, Eb) / (n, R). Layouts shorter than T_max are padded
    with all-invalid words that ``counts`` tells the kernel to skip. The
    single source of truth for the stream layout the engine, benchmarks, and
    tests consume."""
    n = len(layouts)
    r_blocks, _, eb = layouts[0].src.shape
    t_max = max(t.src.shape[1] for t in layouts)
    word = np.zeros((n, r_blocks, t_max, eb), np.int32)
    word_hi = np.zeros((n, r_blocks, t_max, eb), np.int32) if src_bits == 32 else None
    counts = np.zeros((n, r_blocks), np.int32)
    any_w = any(t.weights is not None for t in layouts)
    weights = np.zeros((n, r_blocks, t_max, eb), np.float32) if any_w else None
    for i, t in enumerate(layouts):
        tt = t.src.shape[1]
        w0, w1 = pack_edge_words(t.src, t.dstb, t.valid, src_bits=src_bits)
        word[i, :, :tt] = w0
        if word_hi is not None:
            word_hi[i, :, :tt] = w1
        counts[i] = t.tile_counts
        if weights is not None and t.weights is not None:
            weights[i, :, :tt] = t.weights
    return word, word_hi, counts, weights


@dataclasses.dataclass(frozen=True)
class TileLayout:
    """(R, T, Eb) row-block binned edges; padding slots have valid=False."""

    src: np.ndarray  # (R, T, Eb) int32
    dstb: np.ndarray  # (R, T, Eb) int32 in [0, vb)
    valid: np.ndarray  # (R, T, Eb) bool
    weights: np.ndarray | None  # (R, T, Eb) f32
    vb: int
    num_rows: int
    # slot -> index into the ORIGINAL (pre-binning) edge arrays, 0 on padding.
    # Lets runtime-traced per-edge values (e.g. GAT scores) be laid out into
    # tile order with one static gather.
    gather_idx: np.ndarray | None = None  # (R, T, Eb) int64
    # degree-aware packing: natural row i's reduction lives at kernel-output
    # position row_pos[i] (None = identity layout). Undo with out[row_pos].
    row_pos: np.ndarray | None = None  # (num_rows,) int32
    # real edge tiles per row block: ceil(real_edges[r] / Eb). Tiles with
    # t >= tile_counts[r] are all-padding; the fused kernel skips them.
    tile_counts: np.ndarray | None = None  # (R,) int32

    @property
    def tile_padding_ratio(self) -> float:
        total = self.valid.size
        return 1.0 - float(self.valid.sum()) / max(total, 1)


def _balance_row_blocks(row_counts: np.ndarray, r_blocks: int, vb: int) -> np.ndarray:
    """LPT row->block assignment: rows sorted by in-degree, each placed in the
    least-loaded block with a free slot. Minimizes the max per-block edge count
    so one hub row no longer inflates T for EVERY row block. Returns row_pos
    (natural row -> packed output position)."""
    order = np.argsort(-row_counts, kind="stable")
    load = np.zeros(r_blocks, dtype=np.int64)
    slots = np.zeros(r_blocks, dtype=np.int64)
    row_pos = np.empty(row_counts.shape[0], dtype=np.int32)
    full = np.int64(np.iinfo(np.int64).max)
    for row in order:
        cand = np.where(slots < vb, load, full)
        b = int(cand.argmin())
        row_pos[row] = b * vb + slots[b]
        slots[b] += 1
        load[b] += row_counts[row]
    return row_pos


def prepare_tiles(
    src_gidx: np.ndarray,  # (E,) int32
    dst_lidx: np.ndarray,  # (E,) int32, sorted ascending
    valid: np.ndarray,  # (E,) bool
    num_rows: int,
    vb: int,
    eb: int,
    weights: np.ndarray | None = None,
    *,
    balance_rows: bool = False,
) -> TileLayout:
    assert num_rows % vb == 0, (num_rows, vb)
    r_blocks = num_rows // vb
    src_gidx = np.asarray(src_gidx)
    dst_lidx = np.asarray(dst_lidx)
    valid = np.asarray(valid)

    keep = valid
    orig_idx = np.nonzero(keep)[0]
    src_r = src_gidx[keep]
    dst_r = dst_lidx[keep]
    w_r = weights[keep] if weights is not None else None
    row_pos = None
    if balance_rows and r_blocks > 1:
        row_counts = np.bincount(dst_r, minlength=num_rows)
        row_pos = _balance_row_blocks(row_counts, r_blocks, vb)
        pdst = row_pos[dst_r]
        # packed positions are not sorted; regroup by block, keeping the
        # original (dst-sorted) edge order inside each block (stable).
        order = np.argsort(pdst // vb, kind="stable")
        src_r, pdst, orig_idx = src_r[order], pdst[order], orig_idx[order]
        if w_r is not None:
            w_r = w_r[order]
    else:
        pdst = dst_r
    block = pdst // vb
    counts = np.bincount(block, minlength=r_blocks)
    t_tiles = max(1, int(-(-counts.max() // eb))) if counts.size else 1
    src_t = np.zeros((r_blocks, t_tiles, eb), dtype=np.int32)
    dst_t = np.zeros((r_blocks, t_tiles, eb), dtype=np.int32)
    val_t = np.zeros((r_blocks, t_tiles, eb), dtype=bool)
    gat_t = np.zeros((r_blocks, t_tiles, eb), dtype=np.int64)
    w_t = np.zeros((r_blocks, t_tiles, eb), dtype=np.float32) if w_r is not None else None
    starts = np.zeros(r_blocks + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    for r in range(r_blocks):
        s, e = int(starts[r]), int(starts[r + 1])
        n = e - s
        src_t[r].reshape(-1)[:n] = src_r[s:e]
        dst_t[r].reshape(-1)[:n] = pdst[s:e] - r * vb
        val_t[r].reshape(-1)[:n] = True
        gat_t[r].reshape(-1)[:n] = orig_idx[s:e]
        if w_t is not None:
            w_t[r].reshape(-1)[:n] = w_r[s:e]
    return TileLayout(
        src=src_t, dstb=dst_t, valid=val_t, weights=w_t, vb=vb,
        num_rows=num_rows, gather_idx=gat_t, row_pos=row_pos,
        tile_counts=(-(-counts // eb)).astype(np.int32),
    )


def gather_reduce(
    payload: jnp.ndarray,
    tiles: TileLayout,
    *,
    kind: str = "min",
    edge_op: str = "none",
    identity: float = 0.0,
    interpret: bool = True,
    use_reference: bool = False,
) -> jnp.ndarray:
    """Run the accumulator over one (core, phase) bucket."""
    if use_reference:
        r_blocks = tiles.src.shape[0]
        block_base = np.arange(r_blocks, dtype=np.int32)[:, None, None] * tiles.vb
        ref_w = None
        if edge_op == "add":
            # the kernel treats missing weights as unit weights; the reference
            # skips the add when weights is None, so make units explicit
            ref_w = (
                jnp.asarray(tiles.weights).reshape(-1)
                if tiles.weights is not None
                else jnp.ones(tiles.src.size, jnp.float32)
            )
        out = gather_reduce_reference(
            payload,
            jnp.asarray(tiles.src).reshape(-1),
            jnp.asarray(tiles.dstb + block_base).reshape(-1),
            jnp.asarray(tiles.valid).reshape(-1),
            tiles.num_rows,
            kind=kind,
            identity=identity,
            weights=ref_w,
        )
    else:
        out = gather_reduce_pallas(
            payload,
            jnp.asarray(tiles.src),
            jnp.asarray(tiles.dstb),
            jnp.asarray(tiles.valid),
            jnp.asarray(tiles.weights) if tiles.weights is not None else None,
            num_rows=tiles.num_rows,
            vb=tiles.vb,
            kind=kind,
            edge_op=edge_op,
            identity=identity,
            interpret=interpret,
        )
    if tiles.row_pos is not None:  # undo degree-aware row packing
        out = jnp.take(out, jnp.asarray(tiles.row_pos), axis=0)
    return out


def segment_reduce_rows(
    contrib: jnp.ndarray,  # (p, E) pre-mapped contributions (identity-padded)
    dst: jnp.ndarray,  # (p, E) sorted local rows
    *,
    num_rows: int,
    kind: str,
    identity: float,
    interpret: bool = True,
) -> jnp.ndarray:
    """Reduce-only helper for already-materialized contributions (traced dst
    => no host binning). The engine no longer routes through this — its XLA
    oracle calls segment ops directly and its primary path is the fused
    ``gather_reduce_cores_pallas`` — kept as public API for model code."""
    def seg(c, d):
        if kind == "min":
            return jax.ops.segment_min(c, d, num_segments=num_rows, indices_are_sorted=True)
        return jax.ops.segment_sum(c, d, num_segments=num_rows, indices_are_sorted=True)

    return jax.vmap(seg)(contrib, dst)
