from repro.kernels.csr_gather_reduce import ops, ref  # noqa: F401
from repro.kernels.csr_gather_reduce.kernel import gather_reduce_pallas  # noqa: F401
from repro.kernels.csr_gather_reduce.ops import (  # noqa: F401
    TileLayout,
    gather_reduce,
    prepare_tiles,
    segment_reduce_rows,
)
