from repro.kernels.csr_gather_reduce import ops, ref  # noqa: F401
from repro.kernels.csr_gather_reduce.kernel import (  # noqa: F401
    gather_reduce_cores_pallas,
    gather_reduce_pallas,
)
from repro.kernels.csr_gather_reduce.ops import (  # noqa: F401
    TileLayout,
    gather_reduce,
    prepare_tiles,
    segment_reduce_rows,
)
