from repro.kernels.csr_gather_reduce import ops, ref  # noqa: F401
from repro.kernels.csr_gather_reduce.kernel import (  # noqa: F401
    gather_reduce_cores_pallas,
    gather_reduce_pallas,
)
from repro.kernels.csr_gather_reduce.ops import (  # noqa: F401
    TileLayout,
    choose_src_bits,
    combine_split_rows,
    gather_reduce,
    pack_edge_words,
    prepare_tiles,
    segment_reduce_rows,
    split_map_from_row_orig,
    stack_packed_tiles,
)
