"""Pallas TPU kernel: the GraphScale graph-core accumulator.

FPGA -> TPU translation of paper Fig. 4/5: the e-edges/cycle pipeline with a
Ladner-Fischer prefix-adder + sequential stage becomes, per (row-block r,
edge-tile t) grid cell:

  1. *scratch-pad read*: gather Eb source payloads from the crossbar-gathered
     block resident in VMEM (the label scratch pad) with a dynamic take;
  2. *map UDF*: optional saturating weight add (SSSP);
  3. *reduce UDF*: an 8x128-shaped segment reduction
       - sum  -> one-hot (Vb, Eb) matmul on the MXU (the systolic analogue of
                 the adder tree),
       - min  -> masked broadcast-compare min on the VPU;
  4. *buffered writer*: the (Vb,) accumulator lives in the revisited output
     VMEM block across the row-block's tiles and is written to HBM once.

Edges are pre-binned by destination row block (host-side, partition time), so
the output BlockSpec is a pure function of the grid — the same trick as the
paper's two-dimensional partitioning, one level down.

Blocks: Eb multiple of 128 (lanes), Vb multiple of 8 (sublanes) on real TPU;
tests run interpret=True on CPU with relaxed sizes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gather_reduce_pallas"]


def _accumulate(kind: str, edge_op: str, payload, src, dstb, val, w, acc, identity, vb: int):
    """Shared tile body: gather -> map -> segment-reduce -> merge into acc."""
    vals = jnp.take(payload, src, axis=0)  # (Eb,) scratch-pad reads
    ident = jnp.asarray(identity, vals.dtype)
    if edge_op == "add":  # saturating min-plus map (SSSP)
        vals = jnp.where(vals >= ident, ident, vals + w.astype(vals.dtype))
    vals = jnp.where(val, vals, ident)
    rows = jax.lax.broadcasted_iota(jnp.int32, (vb, vals.shape[0]), 0)
    onehot = rows == dstb[None, :]
    if kind == "sum":
        contrib = jnp.dot(onehot.astype(vals.dtype), vals, precision=jax.lax.Precision.HIGHEST)
        return acc + contrib
    masked = jnp.where(onehot, vals[None, :], ident)
    return jnp.minimum(acc, masked.min(axis=1))


def _kernel(src_ref, dst_ref, val_ref, w_ref, payload_ref, out_ref, *, kind, edge_op, identity, vb):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():  # buffered-writer accumulator starts at the reduce identity
        out_ref[...] = jnp.full_like(out_ref[...], identity)

    src = src_ref[0, 0, :]
    dstb = dst_ref[0, 0, :].astype(jnp.int32)
    val = val_ref[0, 0, :]
    w = w_ref[0, 0, :] if w_ref is not None else None
    payload = payload_ref[...]
    out_ref[...] = _accumulate(
        kind, edge_op, payload, src, dstb, val, w, out_ref[...], identity, vb
    )


@functools.partial(
    jax.jit,
    static_argnames=("num_rows", "vb", "kind", "edge_op", "identity", "interpret"),
)
def gather_reduce_pallas(
    payload: jnp.ndarray,  # (G,) gathered crossbar block (f32/u32)
    src: jnp.ndarray,  # (R, T, Eb) int32 into payload
    dstb: jnp.ndarray,  # (R, T, Eb) int32 row index WITHIN block [0, Vb)
    valid: jnp.ndarray,  # (R, T, Eb) bool
    weights: jnp.ndarray | None = None,  # (R, T, Eb) f32 (edge_op == 'add')
    *,
    num_rows: int,
    vb: int,
    kind: str = "min",
    edge_op: str = "none",
    identity: float = 0.0,
    interpret: bool = True,
) -> jnp.ndarray:
    r_blocks, t_tiles, eb = src.shape
    assert r_blocks * vb == num_rows, (src.shape, vb, num_rows)
    g = payload.shape[0]

    edge_block = pl.BlockSpec((1, 1, eb), lambda r, t: (r, t, 0))
    in_specs = [
        edge_block,
        edge_block,
        edge_block,
        edge_block if weights is not None else None,
        pl.BlockSpec((g,), lambda r, t: (0,)),  # whole scratch pad resident
    ]
    kern = functools.partial(
        _kernel, kind=kind, edge_op=edge_op, identity=identity, vb=vb
    )
    if weights is None:
        def kern_nw(src_ref, dst_ref, val_ref, payload_ref, out_ref):
            _kernel(
                src_ref, dst_ref, val_ref, None, payload_ref, out_ref,
                kind=kind, edge_op=edge_op, identity=identity, vb=vb,
            )
        kern = kern_nw
        in_specs = [s for s in in_specs if s is not None]
        args = (src, dstb, valid, payload)
    else:
        args = (src, dstb, valid, weights, payload)

    return pl.pallas_call(
        kern,
        grid=(r_blocks, t_tiles),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((vb,), lambda r, t: (r,)),
        out_shape=jax.ShapeDtypeStruct((num_rows,), payload.dtype),
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("arbitrary", "arbitrary"))
        )
        if not interpret
        else None,
    )(*args)
