"""Pallas TPU kernel: the GraphScale graph-core accumulator.

FPGA -> TPU translation of paper Fig. 4/5: the e-edges/cycle pipeline with a
Ladner-Fischer prefix-adder + sequential stage becomes, per (row-block r,
edge-tile t) grid cell:

  1. *scratch-pad read*: gather Eb source payloads from the crossbar-gathered
     block resident in VMEM (the label scratch pad) with a dynamic take;
  2. *map UDF*: optional saturating weight add (SSSP);
  3. *reduce UDF*: an 8x128-shaped segment reduction
       - sum  -> one-hot (Vb, Eb) matmul on the MXU (the systolic analogue of
                 the adder tree),
       - min  -> masked broadcast-compare min on the VPU;
  4. *buffered writer*: the (Vb,) accumulator lives in the revisited output
     VMEM block across the row-block's tiles and is written to HBM once.

Edges are pre-binned by destination row block (host-side, partition time), so
the output BlockSpec is a pure function of the grid — the same trick as the
paper's two-dimensional partitioning, one level down.

Blocks: Eb multiple of 128 (lanes), Vb multiple of 8 (sublanes) on real TPU;
tests run interpret=True on CPU with relaxed sizes.

Two entry points share the tile body:

  * ``gather_reduce_pallas``  — one (core, phase) bucket, grid (R, T).
  * ``gather_reduce_cores_pallas`` — the engine's fused hot path: a leading
    core grid dimension runs ALL ``p`` graph cores of one phase in a single
    ``pallas_call`` over grid (p, R, T). The phase's gathered crossbar block
    (shape (G,) = (p * sub_size,), shared by every core exactly like the
    paper's broadcast crossbar) stays resident in VMEM for the whole launch;
    per-edge state never exists outside the (1, 1, 1, Eb) tile registers, so
    no (p, E_pad) contributions array is ever materialized in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gather_reduce_pallas", "gather_reduce_cores_pallas"]


def _accumulate(kind: str, edge_op: str, payload, src, dstb, val, w, acc, identity, vb: int):
    """Shared tile body: gather -> map -> segment-reduce -> merge into acc."""
    vals = jnp.take(payload, src, axis=0)  # (Eb,) scratch-pad reads
    ident = jnp.asarray(identity, vals.dtype)
    if edge_op == "add":  # saturating min-plus map (SSSP)
        vals = jnp.where(vals >= ident, ident, vals + w.astype(vals.dtype))
    vals = jnp.where(val, vals, ident)
    rows = jax.lax.broadcasted_iota(jnp.int32, (vb, vals.shape[0]), 0)
    onehot = rows == dstb[None, :]
    if kind == "sum":
        contrib = jnp.dot(onehot.astype(vals.dtype), vals, precision=jax.lax.Precision.HIGHEST)
        return acc + contrib
    masked = jnp.where(onehot, vals[None, :], ident)
    return jnp.minimum(acc, masked.min(axis=1))


def _kernel(src_ref, dst_ref, val_ref, w_ref, payload_ref, out_ref, *, kind, edge_op, identity, vb):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():  # buffered-writer accumulator starts at the reduce identity
        out_ref[...] = jnp.full_like(out_ref[...], identity)

    src = src_ref[0, 0, :]
    dstb = dst_ref[0, 0, :].astype(jnp.int32)
    val = val_ref[0, 0, :]
    w = w_ref[0, 0, :] if w_ref is not None else None
    payload = payload_ref[...]
    out_ref[...] = _accumulate(
        kind, edge_op, payload, src, dstb, val, w, out_ref[...], identity, vb
    )


@functools.partial(
    jax.jit,
    static_argnames=("num_rows", "vb", "kind", "edge_op", "identity", "interpret"),
)
def gather_reduce_pallas(
    payload: jnp.ndarray,  # (G,) gathered crossbar block (f32/u32)
    src: jnp.ndarray,  # (R, T, Eb) int32 into payload
    dstb: jnp.ndarray,  # (R, T, Eb) int32 row index WITHIN block [0, Vb)
    valid: jnp.ndarray,  # (R, T, Eb) bool
    weights: jnp.ndarray | None = None,  # (R, T, Eb) f32 (edge_op == 'add')
    *,
    num_rows: int,
    vb: int,
    kind: str = "min",
    edge_op: str = "none",
    identity: float = 0.0,
    interpret: bool = True,
) -> jnp.ndarray:
    r_blocks, t_tiles, eb = src.shape
    assert r_blocks * vb == num_rows, (src.shape, vb, num_rows)
    g = payload.shape[0]

    edge_block = pl.BlockSpec((1, 1, eb), lambda r, t: (r, t, 0))
    in_specs = [
        edge_block,
        edge_block,
        edge_block,
        edge_block if weights is not None else None,
        pl.BlockSpec((g,), lambda r, t: (0,)),  # whole scratch pad resident
    ]
    kern = functools.partial(
        _kernel, kind=kind, edge_op=edge_op, identity=identity, vb=vb
    )
    if weights is None:
        def kern_nw(src_ref, dst_ref, val_ref, payload_ref, out_ref):
            _kernel(
                src_ref, dst_ref, val_ref, None, payload_ref, out_ref,
                kind=kind, edge_op=edge_op, identity=identity, vb=vb,
            )
        kern = kern_nw
        in_specs = [s for s in in_specs if s is not None]
        args = (src, dstb, valid, payload)
    else:
        args = (src, dstb, valid, weights, payload)

    return pl.pallas_call(
        kern,
        grid=(r_blocks, t_tiles),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((vb,), lambda r, t: (r,)),
        out_shape=jax.ShapeDtypeStruct((num_rows,), payload.dtype),
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("arbitrary", "arbitrary"))
        )
        if not interpret
        else None,
    )(*args)


def _cores_kernel(src_ref, dst_ref, val_ref, w_ref, payload_ref, out_ref, *, kind, edge_op, identity, vb):
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref[...], identity)

    src = src_ref[0, 0, 0, :]
    dstb = dst_ref[0, 0, 0, :].astype(jnp.int32)
    val = val_ref[0, 0, 0, :]
    w = w_ref[0, 0, 0, :] if w_ref is not None else None
    payload = payload_ref[...]
    acc = out_ref[0, :]
    out_ref[0, :] = _accumulate(
        kind, edge_op, payload, src, dstb, val, w, acc, identity, vb
    )


@functools.partial(
    jax.jit,
    static_argnames=("num_rows", "vb", "kind", "edge_op", "identity", "interpret"),
)
def gather_reduce_cores_pallas(
    payload: jnp.ndarray,  # (G,) phase-gathered crossbar block, shared by cores
    src: jnp.ndarray,  # (p, R, T, Eb) int32 into payload
    dstb: jnp.ndarray,  # (p, R, T, Eb) int32 row index WITHIN block [0, Vb)
    valid: jnp.ndarray,  # (p, R, T, Eb) bool
    weights: jnp.ndarray | None = None,  # (p, R, T, Eb) f32 (edge_op == 'add')
    *,
    num_rows: int,  # rows per core (= vertices_per_core)
    vb: int,
    kind: str = "min",
    edge_op: str = "none",
    identity: float = 0.0,
    interpret: bool = True,
) -> jnp.ndarray:
    """All-cores accumulator: grid (p, R, T) -> (p, num_rows) reductions.

    Core ``c``'s output rows [r*vb, (r+1)*vb) are revisited across the T edge
    tiles of row block r (buffered writer) and written to HBM once; VMEM holds
    one (Eb,) edge tile per operand plus the (G,) scratch pad at any time.
    """
    p, r_blocks, t_tiles, eb = src.shape
    assert r_blocks * vb == num_rows, (src.shape, vb, num_rows)
    g = payload.shape[0]

    edge_block = pl.BlockSpec((1, 1, 1, eb), lambda c, r, t: (c, r, t, 0))
    in_specs = [
        edge_block,
        edge_block,
        edge_block,
        edge_block if weights is not None else None,
        pl.BlockSpec((g,), lambda c, r, t: (0,)),  # whole scratch pad resident
    ]
    if weights is None:
        def kern(src_ref, dst_ref, val_ref, payload_ref, out_ref):
            _cores_kernel(
                src_ref, dst_ref, val_ref, None, payload_ref, out_ref,
                kind=kind, edge_op=edge_op, identity=identity, vb=vb,
            )
        in_specs = [s for s in in_specs if s is not None]
        args = (src, dstb, valid, payload)
    else:
        kern = functools.partial(
            _cores_kernel, kind=kind, edge_op=edge_op, identity=identity, vb=vb
        )
        args = (src, dstb, valid, weights, payload)

    return pl.pallas_call(
        kern,
        grid=(p, r_blocks, t_tiles),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, vb), lambda c, r, t: (c, r)),
        out_shape=jax.ShapeDtypeStruct((p, num_rows), payload.dtype),
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("arbitrary", "arbitrary", "arbitrary"))
        )
        if not interpret
        else None,
    )(*args)
