"""Pallas TPU kernel: the GraphScale graph-core accumulator.

FPGA -> TPU translation of paper Fig. 4/5: the e-edges/cycle pipeline with a
Ladner-Fischer prefix-adder + sequential stage becomes, per (row-block r,
edge-tile t) grid cell:

  1. *scratch-pad read*: gather Eb source payloads from the crossbar-gathered
     block resident in VMEM (the label scratch pad) with a dynamic take;
  2. *map UDF*: optional saturating weight add (SSSP);
  3. *reduce UDF*: an 8x128-shaped segment reduction
       - sum  -> one-hot (Vb, Eb) matmul on the MXU (the systolic analogue of
                 the adder tree),
       - min  -> masked broadcast-compare min on the VPU;
  4. *buffered writer*: the (Vb,) accumulator lives in the revisited output
     VMEM block across the row-block's tiles and is written to HBM once.

Edges are pre-binned by destination row block (host-side, partition time), so
the output BlockSpec is a pure function of the grid — the same trick as the
paper's two-dimensional partitioning, one level down.

Compressed edge stream (paper §III: "compressed graph representation")
----------------------------------------------------------------------
The engine hot path (``gather_reduce_cores_pallas``) does NOT stream the
uncompressed (int32 src, int32 dstb, bool valid) triple per edge slot — that
is 9 index bytes/edge, most of which is zero-padding at the measured 66-81%
tile padding ratio. Instead each slot is ONE bit-packed int32 word, decoded
with shifts/masks in registers inside the kernel:

  16-bit regime (``src_bits=16``, when p * sub_size <= 2^16 and vb <= 2^15):
      word = valid<<31 | dstb<<16 | src                       4 B/edge
      unpack: src = word & 0xFFFF; dstb = (word >> 16) & 0x7FFF;
              valid = word < 0   (bit 31 is the int32 sign bit)
  32-bit fallback (``src_bits=32``):
      word = src;  word_hi = valid<<31 | dstb                 8 B/edge

On top of the packed words, a scalar-prefetched per-(core, row-block) tile
count (``counts``, SMEM-resident before the kernel body runs) lets the kernel
skip all-padding tiles entirely via ``@pl.when(t < counts[c, r])``: skipped
tiles are never gathered, reduced, or even decoded — only the one word stream
for the real tiles ever crosses HBM. These are the two compression levers the
paper's bandwidth claim rests on: fewer bytes per edge, and no bytes at all
for padding.

Blocks: Eb multiple of 128 (lanes), Vb multiple of 8 (sublanes) on real TPU;
tests run interpret=True on CPU with relaxed sizes.

Two entry points share the tile body:

  * ``gather_reduce_pallas``  — one (core, phase) bucket, grid (R, T),
    UNCOMPRESSED (src/dstb/valid arrays). Kept as the uncompressed-Pallas
    correctness reference and for model code whose per-edge values are traced.
  * ``gather_reduce_cores_pallas`` — the engine's fused hot path: a leading
    core grid dimension runs ALL ``p`` graph cores of one phase in a single
    ``pallas_call`` over grid (p, R, T), reading the compressed word stream.
    The phase's gathered crossbar block (shape (G,) = (p * sub_size,), shared
    by every core exactly like the paper's broadcast crossbar) stays resident
    in VMEM for the whole launch; per-edge state never exists outside the
    (1, 1, 1, Eb) tile registers, so neither a (p, E_pad) contributions array
    nor an unpacked per-edge index array is ever materialized in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "gather_reduce_pallas",
    "gather_reduce_cores_pallas",
    "scatter_reduce_cores_pallas",
]


def _or_fold(x):
    """Bitwise-OR reduce over axis 1 of (vb, n, L) by static halving — log2(n)
    word-OR steps, no lax.reduce (registers only, Mosaic-friendly)."""
    while x.shape[1] > 1:
        n = x.shape[1]
        h = n // 2
        head = x[:, :h] | x[:, h : 2 * h]
        x = jnp.concatenate([head, x[:, 2 * h :]], axis=1) if n % 2 else head
    return x[:, 0]


def _accumulate(kind: str, edge_op: str, payload, src, dstb, val, w, acc, identity, vb: int):
    """Shared tile body: gather -> map -> segment-reduce -> merge into acc.

    Multi-query lanes (docs/tile_layout.md §8): ``payload`` may carry a
    trailing lane axis (G, L) — K vector lanes for SSSP/PPR, ceil(K/32)
    packed reach words for multi-source BFS. The gather, map, and reduce all
    broadcast over it: the edge decode and the one-hot segment matrix are
    built ONCE per tile regardless of L, so a K-query batch re-uses the same
    4 B/edge index stream fetch."""
    vals = jnp.take(payload, src, axis=0)  # (Eb,) or (Eb, L) scratch-pad reads
    lanes = vals.ndim == 2
    ident = jnp.asarray(identity, vals.dtype)
    if edge_op == "add":  # saturating min-plus map (SSSP); w=None => unit weights
        step = w.astype(vals.dtype) if w is not None else jnp.asarray(1.0, vals.dtype)
        if lanes and w is not None:
            step = step[:, None]
        vals = jnp.where(vals >= ident, ident, vals + step)
    vals = jnp.where(val[:, None] if lanes else val, vals, ident)
    rows = jax.lax.broadcasted_iota(jnp.int32, (vb, vals.shape[0]), 0)
    onehot = rows == dstb[None, :]
    if kind == "sum":
        contrib = jnp.dot(onehot.astype(vals.dtype), vals, precision=jax.lax.Precision.HIGHEST)
        return acc + contrib
    if kind == "or":  # packed multi-source BFS reach words (identity = 0)
        assert lanes, "'or' reduce requires a packed lane-word payload axis"
        masked = jnp.where(onehot[:, :, None], vals[None, :, :], ident)
        return acc | _or_fold(masked)
    if lanes:
        masked = jnp.where(onehot[:, :, None], vals[None, :, :], ident)
    else:
        masked = jnp.where(onehot, vals[None, :], ident)
    return jnp.minimum(acc, masked.min(axis=1))


def _unpack_word(word, word_hi, src_bits: int):
    """Decode one packed edge-word tile (registers only; shifts + masks).

    Arithmetic >> on int32 sign-extends, so the 0x7FFF mask after shifting by
    16 both isolates the dstb field and drops the smeared valid bit."""
    if src_bits == 16:
        src = word & 0xFFFF
        dstb = (word >> 16) & 0x7FFF
        valid = word < 0
    else:
        src = word
        dstb = word_hi & 0x7FFFFFFF
        valid = word_hi < 0
    return src, dstb, valid


def _kernel(src_ref, dst_ref, val_ref, w_ref, payload_ref, out_ref, *, kind, edge_op, identity, vb):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():  # buffered-writer accumulator starts at the reduce identity
        out_ref[...] = jnp.full_like(out_ref[...], identity)

    src = src_ref[0, 0, :]
    dstb = dst_ref[0, 0, :].astype(jnp.int32)
    val = val_ref[0, 0, :]
    w = w_ref[0, 0, :] if w_ref is not None else None
    payload = payload_ref[...]
    out_ref[...] = _accumulate(
        kind, edge_op, payload, src, dstb, val, w, out_ref[...], identity, vb
    )


@functools.partial(
    jax.jit,
    static_argnames=("num_rows", "vb", "kind", "edge_op", "identity", "interpret"),
)
def gather_reduce_pallas(
    payload: jnp.ndarray,  # (G,) gathered crossbar block (f32/u32)
    src: jnp.ndarray,  # (R, T, Eb) int32 into payload
    dstb: jnp.ndarray,  # (R, T, Eb) int32 row index WITHIN block [0, Vb)
    valid: jnp.ndarray,  # (R, T, Eb) bool
    weights: jnp.ndarray | None = None,  # (R, T, Eb) f32 (edge_op == 'add')
    *,
    num_rows: int,
    vb: int,
    kind: str = "min",
    edge_op: str = "none",
    identity: float = 0.0,
    interpret: bool = True,
) -> jnp.ndarray:
    r_blocks, t_tiles, eb = src.shape
    assert r_blocks * vb == num_rows, (src.shape, vb, num_rows)
    g = payload.shape[0]

    edge_block = pl.BlockSpec((1, 1, eb), lambda r, t: (r, t, 0))
    in_specs = [
        edge_block,
        edge_block,
        edge_block,
        edge_block if weights is not None else None,
        pl.BlockSpec((g,), lambda r, t: (0,)),  # whole scratch pad resident
    ]
    kern = functools.partial(
        _kernel, kind=kind, edge_op=edge_op, identity=identity, vb=vb
    )
    if weights is None:
        def kern_nw(src_ref, dst_ref, val_ref, payload_ref, out_ref):
            _kernel(
                src_ref, dst_ref, val_ref, None, payload_ref, out_ref,
                kind=kind, edge_op=edge_op, identity=identity, vb=vb,
            )
        kern = kern_nw
        in_specs = [s for s in in_specs if s is not None]
        args = (src, dstb, valid, payload)
    else:
        args = (src, dstb, valid, weights, payload)

    return pl.pallas_call(
        kern,
        grid=(r_blocks, t_tiles),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((vb,), lambda r, t: (r,)),
        out_shape=jax.ShapeDtypeStruct((num_rows,), payload.dtype),
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("arbitrary", "arbitrary"))
        )
        if not interpret
        else None,
    )(*args)


@functools.partial(
    jax.jit,
    static_argnames=(
        "num_rows", "vb", "src_bits", "kind", "edge_op", "identity", "interpret"
    ),
)
def gather_reduce_cores_pallas(
    payload: jnp.ndarray,  # (G,) phase-gathered crossbar block, shared by cores
    word: jnp.ndarray,  # (p, R, T, Eb) int32 packed edge words
    counts: jnp.ndarray,  # (p, R) int32 real edge tiles per (core, row block)
    word_hi: jnp.ndarray | None = None,  # (p, R, T, Eb) int32, src_bits=32 only
    weights: jnp.ndarray | None = None,  # (p, R, T, Eb) f32 (edge_op == 'add')
    fetch: jnp.ndarray | None = None,  # (p, R, T) int32 dynamic fetch map
    *,
    num_rows: int,  # rows per core (= vertices_per_core)
    vb: int,
    src_bits: int = 16,
    kind: str = "min",
    edge_op: str = "none",
    identity: float = 0.0,
    interpret: bool = True,
) -> jnp.ndarray:
    """All-cores accumulator over the COMPRESSED edge stream: grid (p, R, T)
    -> (p, num_rows) reductions.

    Each edge slot arrives as one bit-packed word (two in the 32-bit fallback;
    see module docstring) and is decoded in registers. ``counts`` is scalar-
    prefetched (SMEM before the body runs), and tiles with ``t >= counts[c, r]``
    — the 66-81% of slots that are pure padding on measured partitions — are
    skipped without gathering, reducing, or decoding anything.

    Core ``c``'s output rows [r*vb, (r+1)*vb) are revisited across the T edge
    tiles of row block r (buffered writer) and written to HBM once; VMEM holds
    one (Eb,) word tile per operand plus the (G,) scratch pad at any time.

    Hub-row splitting (two-level reduce): output rows may be VIRTUAL — a
    partition-time split of one natural hub row into even chunks, each packed
    into its own slot so no single row block carries the whole hub and T_max
    stays near the mean block load. The kernel is oblivious: it reduces each
    packed row independently (level 1; rows it never touches keep the
    ``identity`` written at t == 0, which is what makes spare slots safe for
    the combine). The engine folds the partials into natural rows afterwards
    with the problem's reduce op (level 2, ``combine_split_rows``).

    Frontier-aware dynamic skipping: passing ``fetch`` (a traced (p, R, T)
    int32 map, ``core.frontier_words.active_fetch_map`` of this iteration's
    active-tile mask) REPLACES ``counts`` as the single scalar-prefetched
    operand. ``fetch[c, r, t]`` names the last ACTIVE tile at or before t
    (-1 before the first); the kernel runs tile t iff ``fetch[c, r, t] == t``
    — which subsumes the static padding early-out, since the engine ANDs
    the frontier hit mask with ``t < counts`` before building the map — and
    the index map fetches block ``max(fetch[c, r, t], 0)``, so every skipped
    grid step re-names an already-fetched block and costs no extra HBM
    traffic (the same fetch-elision trick as the static clamp below). With
    ``fetch=None`` behavior is bit-for-bit the static path.
    """
    p, r_blocks, t_tiles, eb = word.shape
    assert r_blocks * vb == num_rows, (word.shape, vb, num_rows)
    assert counts.shape == (p, r_blocks), (counts.shape, (p, r_blocks))
    assert (word_hi is not None) == (src_bits == 32), (src_bits, word_hi is None)
    if fetch is not None:
        assert fetch.shape == (p, r_blocks, t_tiles), fetch.shape
    g = payload.shape[0]
    # Trailing lane axis (multi-query batching): payload (G, L) -> output
    # (p, num_rows, L). The word stream, counts/fetch map, and grid are
    # UNCHANGED — one tile decode serves all L lane columns.
    lane_dim = payload.shape[1] if payload.ndim == 2 else None
    has_hi = word_hi is not None
    has_w = weights is not None
    has_fetch = fetch is not None

    def kern(cnt_ref, *refs):
        refs = list(refs)
        word_ref = refs.pop(0)
        hi_ref = refs.pop(0) if has_hi else None
        w_ref = refs.pop(0) if has_w else None
        payload_ref, out_ref = refs
        c, r, t = pl.program_id(0), pl.program_id(1), pl.program_id(2)

        @pl.when(t == 0)
        def _init():
            out_ref[...] = jnp.full_like(out_ref[...], identity)

        # variable-T early-out (static: skip padding tiles) or frontier
        # early-out (dynamic: also skip real tiles with no active source)
        run = cnt_ref[c, r, t] == t if has_fetch else t < cnt_ref[c, r]

        @pl.when(run)
        def _work():
            wd = word_ref[0, 0, 0, :]
            hi = hi_ref[0, 0, 0, :] if hi_ref is not None else None
            src, dstb, val = _unpack_word(wd, hi, src_bits)
            w = w_ref[0, 0, 0, :] if w_ref is not None else None
            acc = out_ref[0]
            out_ref[0] = _accumulate(
                kind, edge_op, payload_ref[...], src, dstb, val, w, acc,
                identity, vb,
            )

    # Block-sparse fetch elision: @pl.when only predicates COMPUTE — the
    # pipeline still DMAs whatever block the index map names. Clamping the
    # tile index at the last real tile makes every skipped grid step revisit
    # the previous block, which the pipeline recognizes and does not re-fetch,
    # so padding tiles cost no HBM traffic on compiled TPU either. The
    # dynamic fetch map generalizes the clamp: skipped steps re-name the
    # LAST ACTIVE block (cummax of active tile indices), preserving the
    # no-refetch property under arbitrary per-iteration skip patterns.
    def edge_idx(c, r, t, cnt):
        if has_fetch:
            return (c, r, jnp.maximum(cnt[c, r, t], 0), 0)
        return (c, r, jnp.minimum(t, jnp.maximum(cnt[c, r] - 1, 0)), 0)

    edge_block = pl.BlockSpec((1, 1, 1, eb), edge_idx)
    if lane_dim is None:
        payload_spec = pl.BlockSpec((g,), lambda c, r, t, cnt: (0,))
        out_spec = pl.BlockSpec((1, vb), lambda c, r, t, cnt: (c, r))
        out_shape = (p, num_rows)
    else:  # scratch pad + output carry the lane axis whole
        payload_spec = pl.BlockSpec((g, lane_dim), lambda c, r, t, cnt: (0, 0))
        out_spec = pl.BlockSpec((1, vb, lane_dim), lambda c, r, t, cnt: (c, r, 0))
        out_shape = (p, num_rows, lane_dim)
    in_specs = (
        [edge_block]
        + ([edge_block] if has_hi else [])
        + ([edge_block] if has_w else [])
        + [payload_spec]  # scratch pad resident
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(p, r_blocks, t_tiles),
        in_specs=in_specs,
        out_specs=out_spec,
    )
    args = (
        (word,)
        + ((word_hi,) if has_hi else ())
        + ((weights,) if has_w else ())
        + (payload,)
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(out_shape, payload.dtype),
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("arbitrary", "arbitrary", "arbitrary"))
        )
        if not interpret
        else None,
    )((fetch if has_fetch else counts).astype(jnp.int32), *args)


@functools.partial(
    jax.jit,
    static_argnames=(
        "num_rows", "src_bits", "kind", "edge_op", "identity", "interpret"
    ),
)
def scatter_reduce_cores_pallas(
    payload: jnp.ndarray,  # (G,) phase-gathered crossbar block, shared by cores
    word: jnp.ndarray,  # (p, B, Tp, Eb) int32 packed PUSH edge words
    counts: jnp.ndarray,  # (p, B) int32 real edge tiles per (core, src block)
    word_hi: jnp.ndarray | None = None,  # (p, B, Tp, Eb) int32, src_bits=32 only
    weights: jnp.ndarray | None = None,  # (p, B, Tp, Eb) f32 (edge_op == 'add')
    fetch: jnp.ndarray | None = None,  # (p, B, Tp) int32 dynamic fetch map
    *,
    num_rows: int,  # rows per core (= vertices_per_core)
    src_bits: int = 16,
    kind: str = "min",
    edge_op: str = "none",
    identity: float = 0.0,
    interpret: bool = True,
) -> jnp.ndarray:
    """Push-mode (scatter) accumulator over the SOURCE-binned stream: grid
    (p, B, Tp) -> (p, num_rows) reductions.

    The mirror of ``gather_reduce_cores_pallas`` with the binning axis
    flipped: tiles are grouped by 32-aligned SOURCE block instead of
    destination row block, so a narrow frontier — the regime the pull
    coverage words go dense in — activates only the few blocks that contain
    frontier sources, and ``fetch`` (the frontier-ANDed active map over the
    push stream's own coverage words) elides everything else. The price is
    that a tile's destinations are arbitrary: the accumulator is the WHOLE
    per-core label row (num_rows resident in VMEM instead of vb), written
    once after the full (B, Tp) sweep, and the packed dstb field carries the
    full local row index. Only idempotent monotone reduces are admitted —
    scatter order across blocks is arbitrary, and skipped blocks rely on
    their contributions being already merged; both hold for min/or, neither
    for sum (docs/tile_layout.md §9).

    There is no level-2 fold here: hub-row splitting is a pull-layout
    construct (it caps per-row-block T), and the push accumulator's rows are
    natural rows by construction, so the engine consumes this output
    directly — the two-level shape of §5 degenerates to level 1 only.
    """
    assert kind in ("min", "or"), f"push scatter requires min/or, got {kind!r}"
    p, b_blocks, t_tiles, eb = word.shape
    assert counts.shape == (p, b_blocks), (counts.shape, (p, b_blocks))
    assert (word_hi is not None) == (src_bits == 32), (src_bits, word_hi is None)
    if fetch is not None:
        assert fetch.shape == (p, b_blocks, t_tiles), fetch.shape
    g = payload.shape[0]
    lane_dim = payload.shape[1] if payload.ndim == 2 else None
    has_hi = word_hi is not None
    has_w = weights is not None
    has_fetch = fetch is not None

    def kern(cnt_ref, *refs):
        refs = list(refs)
        word_ref = refs.pop(0)
        hi_ref = refs.pop(0) if has_hi else None
        w_ref = refs.pop(0) if has_w else None
        payload_ref, out_ref = refs
        c, b, t = pl.program_id(0), pl.program_id(1), pl.program_id(2)

        @pl.when((b == 0) & (t == 0))
        def _init():  # accumulator resident across the whole (B, Tp) sweep
            out_ref[...] = jnp.full_like(out_ref[...], identity)

        run = cnt_ref[c, b, t] == t if has_fetch else t < cnt_ref[c, b]

        @pl.when(run)
        def _work():
            wd = word_ref[0, 0, 0, :]
            hi = hi_ref[0, 0, 0, :] if hi_ref is not None else None
            src, dst, val = _unpack_word(wd, hi, src_bits)
            w = w_ref[0, 0, 0, :] if w_ref is not None else None
            acc = out_ref[0]
            out_ref[0] = _accumulate(
                kind, edge_op, payload_ref[...], src, dst, val, w, acc,
                identity, num_rows,
            )

    # same fetch-elision clamp as the pull kernel: skipped grid steps re-name
    # an already-fetched edge block, so they cost no HBM traffic.
    def edge_idx(c, b, t, cnt):
        if has_fetch:
            return (c, b, jnp.maximum(cnt[c, b, t], 0), 0)
        return (c, b, jnp.minimum(t, jnp.maximum(cnt[c, b] - 1, 0)), 0)

    edge_block = pl.BlockSpec((1, 1, 1, eb), edge_idx)
    if lane_dim is None:
        payload_spec = pl.BlockSpec((g,), lambda c, b, t, cnt: (0,))
        out_spec = pl.BlockSpec((1, num_rows), lambda c, b, t, cnt: (c, 0))
        out_shape = (p, num_rows)
    else:  # scratch pad + output carry the lane axis whole (§8)
        payload_spec = pl.BlockSpec((g, lane_dim), lambda c, b, t, cnt: (0, 0))
        out_spec = pl.BlockSpec(
            (1, num_rows, lane_dim), lambda c, b, t, cnt: (c, 0, 0)
        )
        out_shape = (p, num_rows, lane_dim)
    in_specs = (
        [edge_block]
        + ([edge_block] if has_hi else [])
        + ([edge_block] if has_w else [])
        + [payload_spec]
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(p, b_blocks, t_tiles),
        in_specs=in_specs,
        out_specs=out_spec,
    )
    args = (
        (word,)
        + ((word_hi,) if has_hi else ())
        + ((weights,) if has_w else ())
        + (payload,)
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(out_shape, payload.dtype),
        interpret=interpret,
        compiler_params=dict(
            mosaic=dict(dimension_semantics=("arbitrary", "arbitrary", "arbitrary"))
        )
        if not interpret
        else None,
    )((fetch if has_fetch else counts).astype(jnp.int32), *args)
