"""Pure-jnp oracle for the graph-core accumulator (gather + segment reduce).

Defines correctness for the Pallas kernel: per destination row, reduce (min or
sum) the mapped contributions of its incoming edges, reading source payloads
from the gathered crossbar block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["gather_reduce_reference"]


def gather_reduce_reference(
    payload: jnp.ndarray,  # (G,) gathered label block
    src_gidx: jnp.ndarray,  # (E,) int32 into payload
    dst_lidx: jnp.ndarray,  # (E,) int32 into output rows, sorted
    valid: jnp.ndarray,  # (E,) bool
    num_rows: int,
    kind: str = "min",  # reduce UDF
    identity: float = 0.0,
    weights: jnp.ndarray | None = None,  # (E,) optional saturating add (SSSP)
) -> jnp.ndarray:
    vals = jnp.take(payload, src_gidx, axis=0)
    if weights is not None:
        ident = jnp.asarray(identity, vals.dtype)
        vals = jnp.where(vals >= ident, ident, vals + weights.astype(vals.dtype))
    vals = jnp.where(valid, vals, jnp.asarray(identity, vals.dtype))
    if kind == "min":
        return jax.ops.segment_min(vals, dst_lidx, num_segments=num_rows)
    return jax.ops.segment_sum(vals, dst_lidx, num_segments=num_rows)
