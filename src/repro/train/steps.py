"""Train / serve step builders for every architecture family.

Each builder returns a pure ``step(state, batch)`` (or ``serve(params, ...)``)
suitable for ``jax.jit`` with explicit shardings — the exact functions the
multi-pod dry-run lowers and the trainers execute.

``TrainState`` is a plain dict {'params', 'opt'} so sharding rules apply
leaf-wise, and the whole state is donate-able.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.gnn import archs as gnn
from repro.models.gnn.common import GraphBatch
from repro.models.recsys import din as din_mod
from repro.train import losses
from repro.train.optim import AdamWConfig, adamw_update, init_adamw

__all__ = [
    "make_lm_train_step",
    "make_lm_prefill",
    "make_lm_decode_step",
    "make_gnn_train_step",
    "make_gnn_infer",
    "make_din_train_step",
    "make_din_serve",
    "make_din_retrieval",
    "init_train_state",
]


def init_train_state(params, opt_cfg: AdamWConfig):
    return {"params": params, "opt": init_adamw(params, opt_cfg)}


def _apply_update(state, grads, opt_cfg, grad_transform=None):
    if grad_transform is not None:
        grads = grad_transform(grads)
    new_p, new_opt = adamw_update(state["params"], grads, state["opt"], opt_cfg)
    return {"params": new_p, "opt": new_opt}


# ---------------------------------------------------------------------------
# LM family
# ---------------------------------------------------------------------------


def make_lm_train_step(
    cfg: tfm.LMConfig,
    opt_cfg: AdamWConfig,
    grad_accum: int = 1,
    grad_transform: Optional[Callable] = None,
):
    def loss_fn(params, tokens, labels):
        logits, aux = tfm.forward(params, tokens, cfg)
        if cfg.vocab_real is not None and cfg.vocab_real < cfg.vocab:
            # vocab padded for shardability: mask the padding columns
            pad_mask = jnp.arange(cfg.vocab) >= cfg.vocab_real
            logits = jnp.where(pad_mask, jnp.asarray(-1e30, logits.dtype), logits)
        return losses.softmax_xent(logits, labels) + aux

    def train_step(state, batch):
        if grad_accum == 1:
            loss, grads = jax.value_and_grad(loss_fn)(
                state["params"], batch["tokens"], batch["labels"]
            )
        else:
            b = batch["tokens"].shape[0]
            mb = b // grad_accum
            toks = batch["tokens"].reshape(grad_accum, mb, -1)
            labs = batch["labels"].reshape(grad_accum, mb, -1)

            def acc_body(carry, xs):
                loss_acc, g_acc = carry
                t, l = xs
                loss, g = jax.value_and_grad(loss_fn)(state["params"], t, l)
                return (loss_acc + loss, jax.tree.map(jnp.add, g_acc, g)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"]
            )
            (loss, grads), _ = jax.lax.scan(acc_body, (jnp.float32(0.0), zeros), (toks, labs))
            loss = loss / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
        new_state = _apply_update(state, grads, opt_cfg, grad_transform)
        return new_state, {"loss": loss}

    return train_step


def make_lm_prefill(cfg: tfm.LMConfig):
    def prefill(params, tokens):
        logits, _ = tfm.forward(params, tokens, cfg)
        return logits

    return prefill


def make_lm_decode_step(cfg: tfm.LMConfig):
    def decode(params, cache, tokens, pos):
        return tfm.decode_step(params, cache, tokens, pos, cfg)

    return decode


# ---------------------------------------------------------------------------
# GNN family — task kinds: 'node_class' | 'graph_class' | 'node_reg'
# ---------------------------------------------------------------------------


def make_gnn_train_step(
    cfg: gnn.GNNConfig,
    opt_cfg: AdamWConfig,
    task: str = "node_class",
    loss_nodes: Optional[int] = None,  # minibatch: loss only on seed nodes
    grad_transform: Optional[Callable] = None,
):
    def loss_fn(params, batch: GraphBatch, labels):
        out = gnn.apply(params, batch, cfg)
        if task == "graph_class":
            pooled = gnn.graph_readout(out, batch, "sum")
            return losses.softmax_xent(pooled, labels)
        if task == "node_reg":
            mask = batch.node_mask.astype(jnp.float32)[:, None]
            return losses.mse(out * mask, labels * mask)
        mask = batch.node_mask
        out_l, lab_l = out, labels
        if loss_nodes is not None:
            out_l, lab_l, mask = out[:loss_nodes], labels[:loss_nodes], mask[:loss_nodes]
        return losses.masked_softmax_xent(out_l, lab_l, mask.astype(jnp.float32))

    def train_step(state, batch, labels):
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch, labels)
        new_state = _apply_update(state, grads, opt_cfg, grad_transform)
        return new_state, {"loss": loss}

    return train_step


def make_gnn_infer(cfg: gnn.GNNConfig, task: str = "node_class"):
    def infer(params, batch: GraphBatch):
        out = gnn.apply(params, batch, cfg)
        if task == "graph_class":
            return gnn.graph_readout(out, batch, "sum")
        return out

    return infer


# ---------------------------------------------------------------------------
# RecSys (DIN)
# ---------------------------------------------------------------------------


def make_din_train_step(
    cfg: din_mod.DINConfig,
    opt_cfg: AdamWConfig,
    grad_transform: Optional[Callable] = None,
    lookup_fn: Optional[Callable] = None,
):
    def loss_fn(params, batch):
        logits = din_mod.score(params, batch, cfg, lookup_fn=lookup_fn)
        return losses.binary_xent(logits, batch["labels"])

    def train_step(state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        new_state = _apply_update(state, grads, opt_cfg, grad_transform)
        return new_state, {"loss": loss}

    return train_step


def make_din_serve(cfg: din_mod.DINConfig, lookup_fn: Optional[Callable] = None):
    def serve(params, batch):
        return din_mod.score(params, batch, cfg, lookup_fn=lookup_fn)

    return serve


def make_din_retrieval(cfg: din_mod.DINConfig, chunk: Optional[int] = None):
    def retrieve(params, batch):
        return din_mod.score_candidates(params, batch, cfg, chunk=chunk)

    return retrieve
