from repro.train import losses, optim, steps  # noqa: F401
