"""Loss functions (always reduced in float32)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["softmax_xent", "masked_softmax_xent", "binary_xent", "mse"]


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def masked_softmax_xent(logits, labels, mask) -> jnp.ndarray:
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    per = (lse - gold) * mask
    return per.sum() / jnp.maximum(mask.sum(), 1.0)


def binary_xent(logits, labels) -> jnp.ndarray:
    lg = logits.astype(jnp.float32)
    return jnp.mean(jnp.maximum(lg, 0) - lg * labels + jnp.log1p(jnp.exp(-jnp.abs(lg))))


def mse(pred, target) -> jnp.ndarray:
    return jnp.mean(jnp.square(pred.astype(jnp.float32) - target.astype(jnp.float32)))
