"""Optimizers (no external deps): AdamW with decoupled weight decay,
global-norm clipping, warmup-cosine schedule, and an SGD-momentum fallback.

State layout mirrors params (same sharding rules apply leaf-wise, so FSDP
sharding of the optimizer state is free).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_adamw", "adamw_update", "warmup_cosine", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    # master weights / moments dtype (params may be bf16)
    state_dtype: Any = jnp.float32


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def warmup_cosine(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def init_adamw(params, cfg: AdamWConfig) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)  # noqa: E731
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def adamw_update(params, grads, state: AdamWState, cfg: AdamWConfig):
    step = state.step + 1
    if cfg.clip_norm is not None:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    lr = warmup_cosine(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(cfg.state_dtype)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g32)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(cfg.state_dtype)
        return (p.astype(cfg.state_dtype) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)
